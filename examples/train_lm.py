"""End-to-end driver: train a ~100M-param LM for a few hundred steps with
the full production substrate — checkpointing (restart-safe), straggler
watchdog, AdamW + warmup-cosine, synthetic Zipfian data — then serve a
few generations from the trained weights.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse
import tempfile

import jax

from repro.configs.base import LMConfig
from repro.data import SyntheticTokens
from repro.models.transformer import init_lm, lm_loss
from repro.optim import adamw, warmup_cosine
from repro.serve import generate
from repro.train import Trainer, TrainerConfig

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--d-model", type=int, default=512)
ap.add_argument("--layers", type=int, default=8)
args = ap.parse_args()

# ~100M params: 8L x 512d + 32k vocab
cfg = LMConfig(name="lm100m", n_layers=args.layers, d_model=args.d_model,
               n_heads=8, n_kv_heads=4, d_ff=2048, vocab=32_000,
               attention_chunk=128)
params = init_lm(cfg, jax.random.key(0))
n_params = sum(p.size for p in jax.tree.leaves(params))
print(f"model: {n_params / 1e6:.1f}M params")

data = SyntheticTokens(vocab=cfg.vocab, batch=8, seq_len=128)
loss_fn = lambda p, b: lm_loss(p, cfg, b["tokens"], b["labels"],
                               loss_chunk=128)

with tempfile.TemporaryDirectory() as ckpt_dir:
    trainer = Trainer(
        loss_fn, adamw(warmup_cosine(3e-4, 20, args.steps)), params, data,
        TrainerConfig(total_steps=args.steps, ckpt_dir=ckpt_dir,
                      ckpt_interval=100, log_interval=25))
    params = trainer.run()
    first, last = trainer.history[0][1], trainer.history[-1][1]
    print(f"loss: {first:.3f} -> {last:.3f}")
    assert last < first, "loss did not improve"

prompts = jax.random.randint(jax.random.key(7), (2, 8), 0, cfg.vocab)
out = generate(params, cfg, prompts, n_new=16, max_len=64)
print("generated token ids:", out.tolist())
