"""Quickstart: solve SSSP with Δ-stepping on a small-world graph, verify
against Dijkstra, reconstruct a shortest path from the predecessor tree.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import DeltaConfig, DeltaSteppingSolver, dijkstra
from repro.graphs import watts_strogatz

# the paper's small-world family: ring lattice + random rewiring
g = watts_strogatz(n=5_000, k=20, p=1e-2, seed=0)
print(f"graph: |V|={g.n_nodes} |E|={g.n_edges}")

solver = DeltaSteppingSolver(g, DeltaConfig(delta=10, pred_mode="argmin"))
res = solver.solve(source=0)
print(f"Δ-stepping: {int(res.outer_iters)} buckets, "
      f"{int(res.inner_iters)} light sweeps")

# verify against the Dijkstra oracle (the paper's Boost baseline)
ref, _ = dijkstra(g, 0)
assert np.array_equal(np.asarray(res.dist, np.int64), ref)
print("distances match heap Dijkstra ✓")

# reconstruct the path to the farthest reachable vertex
dist = np.asarray(res.dist)
pred = np.asarray(res.pred)
far = int(np.argmax(np.where(dist < 2**31 - 1, dist, -1)))
path = [far]
while pred[path[-1]] >= 0:
    path.append(int(pred[path[-1]]))
print(f"farthest vertex {far} at distance {dist[far]}, "
      f"path length {len(path)} hops")

# batched multi-source solve: one program for a whole batch of sources
many = solver.solve_many([0, 1, 2, 3])
assert np.array_equal(np.asarray(many.dist[0]), dist)
print(f"solve_many: batch of {many.dist.shape[0]} sources, "
      f"{[int(o) for o in many.outer_iters]} buckets each")

# auto-tuning: config="auto" picks Δ from graph statistics (the paper's
# hand-swept Fig. 1 knob, estimated as Δ ≈ c·w̄/d̄ with zero measurement).
# Answers never change — only time does.
auto = DeltaSteppingSolver(g, "auto")
res_auto = auto.solve(source=0)
assert np.array_equal(np.asarray(res_auto.dist), dist)
print(f"config='auto': Δ={auto.config.delta} "
      f"({auto.config.strategy}), same distances ✓")
# tune_cache="tuning.json" reuses records a measured search persisted —
# run `python -m repro.launch.sssp --tune --tune-cache tuning.json`
# (repro.tune.tune) once to populate it; "auto" alone never measures.

# mesh-sharded backend (DESIGN.md §9): relaxation partitioned over every
# local device under shard_map, tentative distances merged with an
# all-reduce min each sweep. Min on tent words is associative, so the
# distances (and, in pred_mode="packed", the predecessors) are bitwise
# identical to the single-device engine for any shard count. Run under
#   XLA_FLAGS=--xla_force_host_platform_device_count=8
# to fake an 8-device host mesh on CPU, or use the CLI:
#   python -m repro.launch.sssp --strategy sharded_edge --verify
import jax

sharded = DeltaSteppingSolver(
    g, DeltaConfig(delta=10, strategy="sharded_edge", pred_mode="argmin"))
res_sh = sharded.solve(source=0)
assert np.array_equal(np.asarray(res_sh.dist), dist)
assert np.array_equal(np.asarray(res_sh.pred), pred)
print(f"sharded_edge over {jax.device_count()} device(s): "
      f"same distances ✓")
