"""Quickstart: the Query/Plan façade (repro.api, DESIGN.md §10/§11) on
a small-world graph — plan once, then dispatch every query kind against
the same pre-lowered engine; verify against Dijkstra.

    PYTHONPATH=src python examples/quickstart.py

This script is façade-only and runs clean under
``-W error::DeprecationWarning`` (CI enforces it). The pre-façade entry
points (``repro.core.DeltaSteppingSolver``, ``delta_stepping``,
``serve.SSSPServer``) survive as deprecated thin shims with
bitwise-identical results, but new code should never import them.
"""
import numpy as np

from repro.api import (
    BoundedRadius,
    Engine,
    ManyToMany,
    MultiSource,
    PointToPoint,
    SingleSource,
    UpdateBatch,
)
from repro.core import DeltaConfig, dijkstra
from repro.dynamic import apply_weight_update
from repro.graphs import watts_strogatz

# the paper's small-world family: ring lattice + random rewiring
g = watts_strogatz(n=5_000, k=20, p=1e-2, seed=0)
print(f"graph: |V|={g.n_nodes} |E|={g.n_edges}")

# plan once: config resolution, backend build, jitted drivers — then
# every query kind dispatches against the same compiled engine
plan = Engine(g, DeltaConfig(delta=10, pred_mode="argmin")).plan()
res = plan.solve(SingleSource(0))
print(f"Δ-stepping: {int(res.telemetry.buckets)} buckets, "
      f"{int(res.telemetry.inner_iters)} light sweeps")

# verify against the Dijkstra oracle (the paper's Boost baseline)
ref, _ = dijkstra(g, 0)
assert np.array_equal(np.asarray(res.dist, np.int64), ref)
print("distances match heap Dijkstra ✓")

# point-to-point with early exit (Kainer–Träff): the solve stops as
# soon as the target's bucket settles — strictly fewer buckets than the
# full solve whenever the target is not among the farthest vertices
dist = np.asarray(res.dist)
far = int(np.argmax(np.where(dist < 2**31 - 1, dist, -1)))
p2p = plan.solve(PointToPoint(0, far))
assert p2p.distance == int(ref[far])
print(f"p2p 0->{far}: dist={p2p.distance}, "
      f"path {len(p2p.path) - 1} hops, "
      f"{int(p2p.telemetry.buckets)} buckets (early exit)")

# bounded radius (nearest-POI workloads): everything farther than r
# reports as unreachable, and the solve stops at bucket r // Δ
r = int(np.median(ref[ref < 2**31 - 1]))
ball = plan.solve(BoundedRadius(0, r))
n_in = int((np.asarray(ball.dist) < 2**31 - 1).sum())
print(f"bounded radius {r}: {n_in} vertices within, "
      f"{int(ball.telemetry.buckets)} buckets")

# batched multi-source: one vmapped program for the whole batch; lane i
# is bitwise identical to SingleSource(sources[i])
many = plan.solve(MultiSource([0, 1, 2, 3]))
assert np.array_equal(np.asarray(many.dist[0]), dist)
print(f"solve_many: batch of {many.dist.shape[0]} sources, "
      f"{[int(o) for o in many.telemetry.buckets]} buckets each")

# many-to-many distance matrix, assembled from tiled multi-source runs
mm = plan.solve(ManyToMany(sources=[0, 1, 2], targets=[10, 20, 30],
                           tile=2))
assert np.array_equal(mm.matrix[0], ref[[10, 20, 30]])
print(f"many-to-many: {mm.matrix.shape} matrix via tiled solves ✓")

# auto-tuning: tuning="auto" picks Δ from graph statistics (the paper's
# hand-swept Fig. 1 knob, estimated as Δ ≈ c·w̄/d̄ with zero
# measurement). The TuningRecord attaches to the plan. Answers never
# change — only time does.
auto_plan = Engine(g, tuning="auto").plan()
res_auto = auto_plan.solve(SingleSource(0))
assert np.array_equal(np.asarray(res_auto.dist), dist)
print(f"tuning='auto': Δ={auto_plan.config.delta} "
      f"({auto_plan.config.strategy}), same distances ✓")
# tuning=Tuning(cache="tuning.json") reuses records a measured search
# (tuning=Tuning(measure=True)) persisted — run
# `python -m repro.launch.sssp --tune --tune-cache tuning.json` once to
# populate it; "auto" alone never measures.

# mesh-sharded backend (DESIGN.md §9): relaxation partitioned over every
# local device under shard_map, tentative distances merged with an
# all-reduce min each sweep — bitwise identical to single-device for
# any shard count, and a first-class façade citizen like every other
# strategy. Run under
#   XLA_FLAGS=--xla_force_host_platform_device_count=8
# to fake an 8-device host mesh on CPU, or use the CLI:
#   python -m repro.launch.sssp --strategy sharded_edge --verify
import jax

sh_plan = Engine(g, DeltaConfig(delta=10, strategy="sharded_edge",
                                pred_mode="argmin")).plan()
res_sh = sh_plan.solve(SingleSource(0))
assert np.array_equal(np.asarray(res_sh.dist), dist)
assert np.array_equal(np.asarray(res_sh.pred), np.asarray(res.pred))
print(f"sharded_edge over {jax.device_count()} device(s): "
      f"same distances ✓")

# dynamic edge costs (repro.dynamic, DESIGN.md §11): traffic weights
# change, topology doesn't. update() swaps costs on the resident plan;
# resolve(warm=True) repairs from the previous answer — decreases drop
# into their new bucket, increases reset the predecessor-tree cone —
# and is bitwise identical to a cold solve of the updated graph.
rng = np.random.default_rng(0)
ids = rng.choice(g.n_edges, size=g.n_edges // 100, replace=False)
new_w = np.clip(np.asarray(plan.graph.w)[ids]
                + rng.integers(-5, 6, size=ids.size), 1, None)
warm = plan.solve(UpdateBatch(ids, new_w))
cold_ref, _ = dijkstra(apply_weight_update(g, ids, new_w), 0)
assert np.array_equal(np.asarray(warm.dist, np.int64), cold_ref)
print(f"dynamic update of {ids.size} edges: warm re-solve repaired "
      f"{warm.telemetry.repaired} vertices over "
      f"{int(warm.telemetry.buckets)} buckets "
      f"(cold solve: {int(res.telemetry.buckets)}) ✓")

# async serving tier (repro.serve.Server, DESIGN.md §13): submit()
# returns a future-style Ticket; the batch former packs consecutive
# lane-able queries into one padded multi-source solve, so every answer
# is bitwise what a serial plan.solve stream would give. UpdateBatch
# rides the same submit path and applies between microbatches.
from repro.serve import Server

with Server(g, config=DeltaConfig(delta=10, pred_mode="argmin"),
            lane_width=4) as srv:
    tickets = [srv.submit(SingleSource(s)) for s in (0, 1, 2)]
    hop = srv.submit(PointToPoint(0, 42))
    assert np.array_equal(np.asarray(tickets[0].result().dist), dist)
    assert hop.result().distance == int(dist[42])
stats = srv.stats()
print(f"serving tier: {stats['completed']} queries in "
      f"{sum(stats['batches'].values())} microbatches "
      f"(occupancy {stats['mean_occupancy']:.2f}, "
      f"p50 {stats['latency_p50_ms']:.0f} ms) ✓")
