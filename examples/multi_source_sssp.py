"""Batched multi-source SSSP — the paper's betweenness-centrality regime
at two scales:

1. single device: ``DeltaSteppingSolver.solve_many`` vmaps the unified
   bucket loop over a batch of sources (batched tent/explored state,
   per-source bucket counters, lanes freeze as they converge);
2. SPMD: the distributed engine (vertex partition over 'model', sources
   over 'data'), exactly the configuration the multi-pod dry-run
   compiles at 512 devices, here on 8 forced host devices.

    PYTHONPATH=src python examples/multi_source_sssp.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np  # noqa: E402

from repro.compat import make_mesh  # noqa: E402
from repro.core import (  # noqa: E402
    DeltaConfig, DeltaSteppingSolver, dijkstra)
from repro.core.distributed import (  # noqa: E402
    DistDeltaConfig, build_distributed_solver)
from repro.graphs import partition_edges, watts_strogatz  # noqa: E402

g = watts_strogatz(2_000, 12, 1e-2, seed=3)
sources = np.array([0, 11, 503, 1999], np.int32)
refs = np.stack([dijkstra(g, int(s))[0] for s in sources])

# --- single-device batched path -------------------------------------------
solver = DeltaSteppingSolver(g, DeltaConfig(delta=10))
res = solver.solve_many(sources)
assert np.array_equal(np.asarray(res.dist, np.int64), refs)
for i, s in enumerate(sources):
    one = solver.solve(int(s))
    assert np.array_equal(np.asarray(res.dist[i]), np.asarray(one.dist))
    assert int(res.outer_iters[i]) == int(one.outer_iters)
print(f"solve_many: {len(sources)} sources in one program, "
      f"{[int(o) for o in res.outer_iters]} buckets per source — "
      f"bitwise equal to per-source solve ✓")

# --- distributed path ------------------------------------------------------
mesh = make_mesh((2, 4), ("data", "model"))
part = partition_edges(g, 4)
print(f"|V|={g.n_nodes} |E|={g.n_edges}, mesh {dict(mesh.shape)}, "
      f"{part.edges_per_shard} edges/shard")

for combine in ("allreduce", "reduce_scatter"):
    solve = build_distributed_solver(
        part, mesh, DistDeltaConfig(delta=10, combine=combine,
                                    local_steps=2))
    dist, outer, inner = solve(sources)
    assert np.array_equal(np.asarray(dist, np.int64), refs), combine
    print(f"{combine:>15s}: {int(outer)} buckets, {int(inner)} light "
          f"phases — all {len(sources)} sources match Dijkstra ✓")
