"""Distributed batched multi-source SSSP — the paper's workload on the
framework's SPMD engine (vertex partition over 'model', sources over
'data'), exactly the configuration the multi-pod dry-run compiles at
512 devices, here on 8 forced host devices.

    PYTHONPATH=src python examples/multi_source_sssp.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import dijkstra  # noqa: E402
from repro.core.distributed import (  # noqa: E402
    DistDeltaConfig, build_distributed_solver)
from repro.graphs import partition_edges, watts_strogatz  # noqa: E402

g = watts_strogatz(2_000, 12, 1e-2, seed=3)
mesh = jax.make_mesh((2, 4), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
part = partition_edges(g, 4)
print(f"|V|={g.n_nodes} |E|={g.n_edges}, mesh {dict(mesh.shape)}, "
      f"{part.edges_per_shard} edges/shard")

for combine in ("allreduce", "reduce_scatter"):
    solve = build_distributed_solver(
        part, mesh, DistDeltaConfig(delta=10, combine=combine,
                                    local_steps=2))
    sources = np.array([0, 11, 503, 1999], np.int32)
    dist, outer, inner = solve(sources)
    for i, s in enumerate(sources):
        ref, _ = dijkstra(g, int(s))
        assert np.array_equal(np.asarray(dist[i], np.int64), ref), (combine, s)
    print(f"{combine:>15s}: {int(outer)} buckets, {int(inner)} light "
          f"phases — all {len(sources)} sources match Dijkstra ✓")
