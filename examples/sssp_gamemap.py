"""Game-map SSSP (paper §4 'Game Maps'): occupancy grid, straight moves
cost 10, diagonals 14, Δ=13 — runs both the generic engine and the
grid-stencil engine (the Pallas kernel's oracle backend on CPU) and
renders a small ASCII path.

    PYTHONPATH=src python examples/sssp_gamemap.py
"""
import numpy as np

from repro.core import DeltaConfig, DeltaSteppingSolver
from repro.core.grid import GridDeltaConfig, GridDeltaSolver
from repro.graphs import grid_map

H = W = 40
g, free = grid_map(H, W, obstacle_frac=0.15, seed=7)
src = int(np.flatnonzero(free.ravel())[0])

# generic edge-centric engine
res = DeltaSteppingSolver(g, DeltaConfig(delta=13)).solve(src)
dist_edge = np.asarray(res.dist).reshape(H, W)

# grid-stencil engine (same bucket semantics, min-plus stencil sweeps)
grid = GridDeltaSolver(free, GridDeltaConfig(backend="ref"))
gres = grid.solve((src // W, src % W))
dist_grid = np.asarray(gres.dist)
assert np.array_equal(dist_edge, dist_grid), "engines disagree!"
print(f"engines agree; {int(gres.outer_iters)} buckets, "
      f"{int(gres.inner_iters)} light sweeps")

# ASCII render: walls '#', unreachable '.', else distance band
INF = 2**31 - 1
band = np.where(dist_grid < INF, dist_grid // 80, -1)
chars = np.full((H, W), "#", dtype="<U1")
for r in range(H):
    for c in range(W):
        if free[r, c]:
            b = band[r, c]
            chars[r, c] = "." if b < 0 else "0123456789abcdefghijklmnop"[
                min(int(b), 25)]
chars[src // W, src % W] = "S"
print("\n".join("".join(row) for row in chars[:20]))
