"""Serving-tier load benchmark: open-loop mixed traffic against the
async ``repro.serve.Server`` at swept arrival rates — the repo's first
latency-percentile (p50/p99) trajectory.

An open-loop generator submits a seeded mix of single-source /
point-to-point / bounded-radius / many-to-many / update traffic over
two tenant graphs at fixed arrival rates (0.4x / 0.8x / 1.6x of a
measured closed-loop capacity probe), with a 5 s deadline budget and a
bounded queue, and records per-rate p50/p99 latency (informational:
latency under load is scheduling-noise dominated), sustained throughput
(gated), shed rate and mean batch occupancy. The 1.6x point
deliberately overloads the server: admission control must shed with
typed rejections while throughput holds near capacity — overload
degrades, never collapses (DESIGN.md §13).
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import row, scaled

LANE_WIDTH = 8
MAX_QUEUE = 16
DEADLINE_S = 5.0
UPDATE_EDGES = 16      # fixed update width: one compiled update shape
RATE_MULTIPLIERS = (0.4, 0.8, 1.6)


def _graphs():
    from repro.graphs import square_lattice, watts_strogatz

    return {
        "smallworld": watts_strogatz(scaled(10_000), 12, 1e-2, seed=0),
        "lattice": square_lattice(scaled(48, floor=16), weighted=True,
                                  seed=4),
    }


def _traffic(graphs, n_requests, seed=0):
    """Seeded mixed workload: ~55% single-source, ~25% p2p, ~12%
    bounded-radius, ~4% many-to-many, ~4% weight updates, spread over
    both tenants."""
    from repro.api import (
        BoundedRadius,
        ManyToMany,
        PointToPoint,
        SingleSource,
        UpdateBatch,
    )

    rng = np.random.default_rng(seed)
    names = sorted(graphs)
    out = []
    for _ in range(n_requests):
        name = names[int(rng.integers(len(names)))]
        g = graphs[name]
        src = int(rng.integers(g.n_nodes))
        kind = rng.random()
        if kind < 0.55:
            q = SingleSource(src)
        elif kind < 0.80:
            q = PointToPoint(src, int(rng.integers(g.n_nodes)))
        elif kind < 0.92:
            q = BoundedRadius(src, int(rng.integers(20, 200)))
        elif kind < 0.96:
            srcs = rng.integers(g.n_nodes, size=LANE_WIDTH).tolist()
            tgts = rng.integers(g.n_nodes, size=LANE_WIDTH).tolist()
            q = ManyToMany(srcs, tgts, tile=LANE_WIDTH)
        else:
            ids = rng.choice(g.n_edges, size=UPDATE_EDGES, replace=False)
            neww = np.clip(
                np.asarray(g.w)[ids] + rng.integers(-3, 4, UPDATE_EDGES),
                1, None)
            q = UpdateBatch(ids, neww)
        out.append((name, q))
    return out


def _make_server(graphs):
    """Fresh server + compile/warm every shape the traffic mix hits
    (lane batch, update, many-to-many tile) on both tenants."""
    from repro.api import ManyToMany, SingleSource, UpdateBatch
    from repro.serve import Server

    srv = Server(dict(graphs), lane_width=LANE_WIDTH, max_queue=MAX_QUEUE)
    for name, g in graphs.items():
        srv.submit(SingleSource(0), graph=name)
        srv.submit(ManyToMany([0] * LANE_WIDTH, [0] * LANE_WIDTH,
                              tile=LANE_WIDTH), graph=name)
        ids = np.arange(UPDATE_EDGES)
        srv.submit(UpdateBatch(ids, np.asarray(g.w)[ids]), graph=name)
    srv.drain()
    return srv


def _measure_capacity(graphs, n_requests, seed) -> float:
    """Closed-loop service rate (queries/s): burst-submit, drain inline
    — an upper bound the open-loop sweep is anchored to."""
    srv = _make_server(graphs)
    work = _traffic(graphs, n_requests, seed=seed)
    t0 = time.perf_counter()
    for i, (name, q) in enumerate(work):
        srv.submit(q, graph=name)
        if (i + 1) % (MAX_QUEUE // 2) == 0:
            srv.drain()                       # stay under the queue cap
    srv.drain()
    dt = time.perf_counter() - t0
    done = srv.stats()["completed"]
    return done / dt


def _run_rate(graphs, rate_qps, n_requests, seed):
    """Open-loop: arrivals on a fixed schedule regardless of completion
    (the generator never waits on the server — overload pressure is
    real), served by the threaded batch loop."""
    srv = _make_server(graphs)
    work = _traffic(graphs, n_requests, seed=seed)
    srv.start()
    t0 = time.perf_counter()
    for i, (name, q) in enumerate(work):
        target = t0 + i / rate_qps
        delay = target - time.perf_counter()
        if delay > 0:
            time.sleep(delay)
        srv.submit(q, graph=name, deadline=DEADLINE_S)
    srv.close(drain=True)                     # answer everything accepted
    wall = time.perf_counter() - t0
    return srv.stats(), wall


def main():
    graphs = _graphs()
    n_requests = scaled(320, floor=64)

    capacity = _measure_capacity(graphs, n_requests, seed=1)
    row("serving/capacity", 1.0 / capacity,
        f"qps={capacity:.1f};tenants={len(graphs)};lanes={LANE_WIDTH}",
        gate=False)

    for mult in RATE_MULTIPLIERS:
        rate = capacity * mult
        stats, wall = _run_rate(graphs, rate, n_requests, seed=2)
        completed = max(1, stats["completed"])
        shed = sum(stats["shed"].values())
        shed_rate = shed / max(1, stats["submitted"])
        occ = stats["mean_occupancy"] or 0.0
        tag = (f"rate={rate:.1f}qps;qps={completed / wall:.1f};"
               f"shed_rate={shed_rate:.3f};occupancy={occ:.2f};"
               f"tenants={len(graphs)}")
        # percentile rows are informational (latency under open-loop
        # load is scheduler-noise dominated; the noise protocol keeps
        # them out of the gate) — the sustained-throughput row is gated
        row(f"serving/rate_{mult}x/p50",
            (stats["latency_p50_ms"] or 0.0) / 1e3, tag, gate=False)
        row(f"serving/rate_{mult}x/p99",
            (stats["latency_p99_ms"] or 0.0) / 1e3, tag, gate=False)
        row(f"serving/rate_{mult}x/throughput", wall / completed, tag)


if __name__ == "__main__":
    import os
    import sys

    _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    if _root not in sys.path:
        sys.path.insert(0, _root)
    main()
