"""Paper Fig. 4: preprocessing (light/heavy split) vs main loop time.

The paper parallelizes the split with a static OpenMP for; our edge-
centric strategy evaluates the light mask on the fly (zero preprocessing)
while the ELL strategy pays an explicit split+pad — both are timed.
"""
from __future__ import annotations

import time

from benchmarks.common import row, scaled, time_fn, tuned_solver, tuned_tag
from repro.core import DeltaConfig, DeltaSteppingSolver
from repro.graphs import watts_strogatz
from repro.graphs.structures import coo_to_csr, csr_to_ell, light_heavy_split


def main():
    g = watts_strogatz(scaled(10_000), 12, 1e-2, seed=0)
    t0 = time.perf_counter()
    csr = coo_to_csr(g)
    light, heavy = light_heavy_split(csr, 10)
    csr_to_ell(light), csr_to_ell(heavy)
    t_pre = time.perf_counter() - t0
    row("fig4/preprocess_ell", t_pre, "")

    for strat in ("edge", "ell"):
        solver = DeltaSteppingSolver(
            g, DeltaConfig(delta=10, strategy=strat, pred_mode="none"))
        t = time_fn(lambda: solver.solve(0).dist, reps=2)
        row(f"fig4/mainloop_{strat}", t,
            f"pre_frac={(t_pre / (t_pre + t)) if strat == 'ell' else 0:.2f}")

    # the tuner is itself preprocessing: one-time measured search at
    # graph-load, amortized across solves (serve.SSSPServer's regime).
    # use_cache=False: this row times the real search, not a cache hit.
    t0 = time.perf_counter()
    rec, tuned = tuned_solver(g, use_cache=False)
    t_tune = time.perf_counter() - t0
    t = time_fn(lambda: tuned.solve(0).dist, reps=2)
    row("fig4/tune_search", t_tune, tuned_tag(rec), gate=False)
    row("fig4/mainloop_tuned", t,
        f"amortize_solves={t_tune / max(t, 1e-9):.0f}", gate=False)


if __name__ == "__main__":
    main()
