"""Paper Fig. 1: runtime vs Δ for small-world graphs at several rewiring
probabilities p. Reproduces the qualitative shape: an optimum Δ that
grows as p shrinks, and monotone improvement with Δ at p=0 (pure ring).

The derived column reports bucket counts (outer iterations) — the
mechanism behind the curve: larger Δ ⇒ fewer buckets ⇒ fewer phases,
against more re-relaxation work per phase.

After each manual sweep, one ``delta_auto`` row records the auto-tuner
(repro.tune measured search) against the best hand-swept Δ — the
acceptance bar is tuned time within 1.1x of the best manual row.
"""
from __future__ import annotations

from benchmarks.common import row, scaled, time_fn, tuned_solver, tuned_tag
from repro.core import DeltaConfig, DeltaSteppingSolver
from repro.graphs import watts_strogatz


def main():
    k = 12
    for p in (0.0, 1e-4, 1e-2):
        # p=0 is the pure ring: diameter ~ n/2, thousands of buckets —
        # keep it small so the monotone-in-Δ curve stays measurable.
        n = scaled(1_000, floor=128) if p == 0.0 else scaled(10_000)
        g = watts_strogatz(n, k, p, seed=0)
        best = None
        for delta in (1, 3, 5, 10, 20, 40):
            solver = DeltaSteppingSolver(
                g, DeltaConfig(delta=delta, pred_mode="none"))
            res = solver.solve(0)
            t = time_fn(lambda: solver.solve(0).dist, reps=1)
            best = t if best is None else min(best, t)
            row(f"fig1/p{p:g}/delta{delta}", t,
                f"buckets={int(res.outer_iters)};"
                f"light_sweeps={int(res.inner_iters)}")
        rec, tuned = tuned_solver(g)
        t_tu = time_fn(lambda: tuned.solve(0).dist, reps=1)
        row(f"fig1/p{p:g}/delta_auto", t_tu,
            f"{tuned_tag(rec)};vs_best_manual={t_tu / best:.2f}",
            gate=False)


if __name__ == "__main__":
    main()
