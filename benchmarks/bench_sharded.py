"""Mesh-sharded backends vs their single-device twins (DESIGN.md §9).

Times one SSSP solve per backend on the small-world family: ``edge`` vs
``sharded_edge``, ``ell`` vs ``sharded_ell`` and the fused frontier
kernel pair ``fused`` vs ``sharded_fused`` (DESIGN.md §12), plus a
batched multi-source row through the sharded engine. Shard width is
every local device — 1 on plain CPU CI (which still exercises the full
shard_map + all-reduce-min machinery, so the gate tracks its overhead);
run under ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` for
multi-shard numbers (the derived column records the width either way).
The multi-shard *scaling curve* lives in bench_scaling_shards.py.

The fused rows run at the strategy's natural operating point: a
compacted-frontier capacity probed from the instance's bucket census
(max bucket population + power-of-two headroom), so the per-iteration
gather is O(cap·deg) instead of O(|V|·deg) — the measured content of
the fusion claim. The solve is checked overflow-free at that cap. A
``gate=False`` roofline row reports how far the fused program sits
from the memory-bandwidth limit (repro.analysis.roofline)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, scaled, time_fn
from repro.core import DeltaConfig, DeltaSteppingSolver
from repro.graphs import watts_strogatz

_DELTA = 10


def _probed_cap(g, dist) -> int:
    """Max final bucket population, rounded up to a 128-lane multiple —
    a true upper bound on any light frontier and per-bucket settled set
    for w >= 1 graphs (while bucket i is current, a member's tent can
    only move within bucket i, so every transient member is a final
    member), hence the capped run is overflow-free. The solve below
    still asserts the flag."""
    d = np.asarray(dist, np.int64)
    finite = d[d < np.iinfo(np.int32).max]
    pop = int(np.bincount(finite // _DELTA).max()) if finite.size else 1
    return min(-(-pop // 128) * 128, g.n_nodes)


def _roofline_row(g, cfg, seconds):
    """gate=False: distance of the measured fused solve from the HBM
    bandwidth floor of its own compiled program (napkin MODEL_FLOPS:
    one compare+add per directed edge per settled bucket)."""
    from repro.analysis import analyze
    from repro.core import make_backend
    from repro.core.delta_stepping import _run_one

    backend = make_backend(g, cfg)
    compiled = _run_one.lower(
        backend, jnp.int32(0), n=g.n_nodes, packed=False).compile()
    rep = analyze(compiled, arch="sssp-fused", shape=f"n{g.n_nodes}",
                  mesh_name="1", n_devices=1,
                  model_flops=2.0 * g.src.shape[0])
    x_over_bw = seconds / rep.memory_s if rep.memory_s > 0 else float("inf")
    row("sharded/fused/roofline", rep.memory_s,
        f"dominant={rep.dominant};peak_frac={rep.peak_fraction:.3f};"
        f"measured_over_bw={x_over_bw:.1f}", gate=False)


def main():
    g = watts_strogatz(scaled(10_000), 12, 1e-2, seed=0)
    shards = jax.device_count()
    tag = f"shards={shards}"
    times = {}
    for strategy in ("edge", "sharded_edge", "ell", "sharded_ell"):
        solver = DeltaSteppingSolver(
            g, DeltaConfig(delta=_DELTA, strategy=strategy,
                           pred_mode="none"))
        t = time_fn(lambda: solver.solve(0).dist, reps=3)
        times[strategy] = t
        derived = tag if strategy.startswith("sharded") else ""
        if strategy == "sharded_edge":
            derived += f";vs_edge={times['edge'] / t:.2f}"
        elif strategy == "sharded_ell":
            derived += f";vs_ell={times['ell'] / t:.2f}"
        row(f"sharded/{strategy}/solve", t, derived)
    # fused pair at the probed compacted-frontier capacity
    ref = DeltaSteppingSolver(
        g, DeltaConfig(delta=_DELTA, strategy="edge",
                       pred_mode="none")).solve(0)
    cap = _probed_cap(g, ref.dist)
    fused_cfg = None
    for strategy in ("fused", "sharded_fused"):
        cfg = DeltaConfig(delta=_DELTA, strategy=strategy, pred_mode="none",
                          frontier_cap=cap)
        solver = DeltaSteppingSolver(g, cfg)
        res = solver.solve(0)
        assert not bool(res.overflow), (strategy, cap)
        t = time_fn(lambda: solver.solve(0).dist, reps=3)
        times[strategy] = t
        if strategy == "fused":
            fused_cfg = cfg
            derived = f"cap={cap};vs_ell={times['ell'] / t:.2f}"
        else:
            derived = (f"{tag};cap={cap};"
                       f"vs_fused={times['fused'] / t:.2f}")
        row(f"sharded/{strategy}/solve", t, derived)
    _roofline_row(g, fused_cfg, times["fused"])
    # batched multi-source through the sharded engine (vmapped shard_map)
    batch = 8
    srcs = np.arange(batch, dtype=np.int32)
    solver = DeltaSteppingSolver(
        g, DeltaConfig(delta=_DELTA, strategy="sharded_edge",
                       pred_mode="none"))
    t_bat = time_fn(lambda: solver.solve_many(srcs).dist, reps=2)
    row("sharded/sharded_edge/batched", t_bat / batch,
        f"{tag};batch={batch}")


if __name__ == "__main__":
    main()
