"""Mesh-sharded backends vs their single-device twins (DESIGN.md §9).

Times one SSSP solve per backend on the small-world family: ``edge`` vs
``sharded_edge`` and ``ell`` vs ``sharded_ell``, plus a batched
multi-source row through the sharded engine. Shard width is every
local device — 1 on plain CPU CI (which still exercises the full
shard_map + all-reduce-min machinery, so the gate tracks its overhead);
run under ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` for
multi-shard numbers (the derived column records the width either way).
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import row, scaled, time_fn
from repro.core import DeltaConfig, DeltaSteppingSolver
from repro.graphs import watts_strogatz


def main():
    g = watts_strogatz(scaled(10_000), 12, 1e-2, seed=0)
    shards = jax.device_count()
    tag = f"shards={shards}"
    times = {}
    for strategy in ("edge", "sharded_edge", "ell", "sharded_ell"):
        solver = DeltaSteppingSolver(
            g, DeltaConfig(delta=10, strategy=strategy, pred_mode="none"))
        t = time_fn(lambda: solver.solve(0).dist, reps=3)
        times[strategy] = t
        derived = tag if strategy.startswith("sharded") else ""
        if strategy == "sharded_edge":
            derived += f";vs_edge={times['edge'] / t:.2f}"
        elif strategy == "sharded_ell":
            derived += f";vs_ell={times['ell'] / t:.2f}"
        row(f"sharded/{strategy}/solve", t, derived)
    # batched multi-source through the sharded engine (vmapped shard_map)
    batch = 8
    srcs = np.arange(batch, dtype=np.int32)
    solver = DeltaSteppingSolver(
        g, DeltaConfig(delta=10, strategy="sharded_edge", pred_mode="none"))
    t_bat = time_fn(lambda: solver.solve_many(srcs).dist, reps=2)
    row("sharded/sharded_edge/batched", t_bat / batch,
        f"{tag};batch={batch}")


if __name__ == "__main__":
    main()
