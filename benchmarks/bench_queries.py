"""Query-kind benchmarks over the Query/Plan façade (DESIGN.md §10).

Three comparisons the new query algebra is supposed to win:

* point-to-point early exit vs the full single-source solve on a long-
  diameter lattice (near and far targets; the derived column records
  the bucket counts, the measurable early-exit evidence);
* bounded-radius vs the full solve (nearest-POI regime);
* many-to-many tile throughput (distance-matrix assembly from tiled
  multi-source programs) in source-target pairs per second.

Plus one tuner-resolved plan row (``gate: false`` per the PR 2
convention — the tuner's stochastic winner must not flap CI).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import row, scaled, time_fn
from repro.api import (
    BoundedRadius,
    Engine,
    ManyToMany,
    PointToPoint,
    SingleSource,
)
from repro.core import DeltaConfig
from repro.graphs import square_lattice, watts_strogatz


def main():
    # long-diameter family: the regime where early exit pays
    side = int(np.sqrt(scaled(40_000)))
    lat = square_lattice(side, weighted=True)
    plan = Engine(lat, DeltaConfig(delta=10, pred_mode="none")).plan()
    full = plan.solve(SingleSource(0))
    b_full = int(full.telemetry.buckets)
    t_full = time_fn(lambda: plan.solve(SingleSource(0)).dist)
    row("queries/lattice/full_solve", t_full, f"buckets={b_full}")

    # near / far p2p targets: quarter-diagonal vs opposite corner
    near = (side // 4) * side + side // 4
    far = side * side - 1
    for name, tgt in (("near", near), ("far", far)):
        res = plan.solve(PointToPoint(0, tgt))
        t = time_fn(
            lambda: plan.solve(PointToPoint(0, tgt)).telemetry.buckets)
        row(f"queries/lattice/p2p_{name}", t,
            f"speedup={t_full / t:.2f};buckets={int(res.telemetry.buckets)}"
            f"/{b_full};dist={res.distance}")

    # bounded radius: an 1/8-diameter ball around the source
    dist = np.asarray(full.dist, np.int64)
    radius = int(np.max(dist[dist < 2**31 - 1]) // 8)
    res = plan.solve(BoundedRadius(0, radius))
    t = time_fn(lambda: plan.solve(BoundedRadius(0, radius)).dist)
    row("queries/lattice/bounded_radius", t,
        f"speedup={t_full / t:.2f};r={radius};"
        f"buckets={int(res.telemetry.buckets)}/{b_full}")

    # many-to-many tile throughput on the small-world family
    g = watts_strogatz(scaled(10_000), 12, 1e-2, seed=0)
    sw_plan = Engine(g, DeltaConfig(delta=10, pred_mode="none")).plan()
    srcs = list(range(16))
    tgts = list(range(0, g.n_nodes, max(1, g.n_nodes // 16)))[:16]
    q = ManyToMany(srcs, tgts, tile=8)
    sw_plan.solve(q)                         # warm up / compile
    t_mm = time_fn(lambda: sw_plan.solve(q).matrix, reps=2, warmup=0)
    pairs = len(srcs) * len(tgts)
    row("queries/smallworld/many_to_many", t_mm / pairs,
        f"pairs={pairs};tile=8;pairs_per_s={pairs / t_mm:.0f}")

    # tuner-resolved plan (measured search; informational only)
    from repro.api import Tuning
    rec_plan = Engine(g, DeltaConfig(pred_mode="none"),
                      tuning=Tuning(measure=True)).plan(sources=(0,))
    rec = rec_plan.record
    t_tuned = time_fn(lambda: rec_plan.solve(SingleSource(0)).dist)
    row("queries/smallworld/tuned_plan", t_tuned,
        f"tuned_delta={rec.delta};tuned_strategy={rec.strategy};"
        f"record={rec.source}", gate=False)


if __name__ == "__main__":
    main()
