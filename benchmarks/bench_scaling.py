"""Paper Figs. 2/3: parallel scaling on small-world graphs.

The paper scales OpenMP threads on one node; our parallel axis is
devices. On this 1-core container real multi-device timing is
meaningless, so this bench measures the two *scalable* quantities the
roofline model consumes, mirroring the paper's speedup mechanics:

  * work per sweep (edges relaxed) and number of synchronization points
    (bucket phases) — the numerator/denominator of the paper's speedup;
  * the ``local_steps`` trade (paper §4 'Delta': removing the barrier in
    the light phase) measured as sweeps vs collectives on a virtual
    multi-device mesh (subprocess-free: single device mesh runs the same
    program).

Real per-device collective volumes for 256/512 chips are in
EXPERIMENTS.md §Roofline from the dry-run.
"""
from __future__ import annotations

from benchmarks.common import row, scaled, time_fn, tuned_solver, tuned_tag
from repro.core import DeltaConfig, DeltaSteppingSolver
from repro.graphs import watts_strogatz


def main():
    n, k = scaled(10_000), 12
    for p in (1e-4, 1e-2):
        g = watts_strogatz(n, k, p, seed=0)
        for delta in (1, 10):
            solver = DeltaSteppingSolver(
                g, DeltaConfig(delta=delta, pred_mode="none"))
            res = solver.solve(0)
            t = time_fn(lambda: solver.solve(0).dist, reps=2)
            sweeps = int(res.inner_iters) + int(res.outer_iters)
            work = sweeps * g.n_edges
            row(f"fig23/p{p:g}/delta{delta}", t,
                f"sync_points={sweeps};edge_relaxations={work};"
                f"par_work_per_sync={g.n_edges}")
        if p == 1e-2:
            # tuned variant: fewer sync points is exactly what the tuner
            # buys (its Δ search trades phases against re-relaxation)
            rec, tuned = tuned_solver(g)
            res = tuned.solve(0)
            t = time_fn(lambda: tuned.solve(0).dist, reps=2)
            sweeps = int(res.inner_iters) + int(res.outer_iters)
            row(f"fig23/p{p:g}/tuned", t,
                f"{tuned_tag(rec)};sync_points={sweeps}", gate=False)


if __name__ == "__main__":
    main()
