"""Benchmark driver: one module per paper table/figure. Prints
``name,us_per_call,derived`` CSV and writes one machine-readable
``BENCH_<name>.json`` perf record per bench module (rows + status), so
the performance trajectory across PRs can be diffed by tooling
(``benchmarks/check_regression.py`` gates CI on exactly these records).
Roofline terms come from the dry-run (launch.dryrun → EXPERIMENTS.md),
not from here.

Exit status is the CI contract: non-zero whenever any bench module
fails, so the gate can trust a green run (pinned by
tests/test_bench_gate.py). ``BENCH_SMOKE=1`` shrinks instances ~8x
(the gate regime); ``BENCH_OUT_DIR`` redirects the JSON records;
``--only smallworld,rmat`` restricts the module list.
"""
from __future__ import annotations

import argparse
import json
import os
import platform
import sys
import traceback


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, metavar="NAMES",
                    help="comma-separated bench names to run "
                         "(e.g. smallworld,rmat); default: all")
    args = ap.parse_args(argv)

    from benchmarks import (
        bench_delta_sweep,
        bench_dynamic,
        bench_gamemap,
        bench_multisource,
        bench_p2p,
        bench_policies,
        bench_preprocess,
        bench_queries,
        bench_rmat,
        bench_scaling,
        bench_scaling_shards,
        bench_serving,
        bench_sharded,
        bench_smallworld,
    )
    from benchmarks.common import SMOKE, drain_records

    modules = {}
    for mod in (bench_smallworld, bench_delta_sweep, bench_scaling,
                bench_preprocess, bench_rmat, bench_gamemap,
                bench_multisource, bench_sharded, bench_scaling_shards,
                bench_queries, bench_p2p, bench_dynamic, bench_serving,
                bench_policies):
        modules[mod.__name__.rsplit(".", 1)[-1].removeprefix("bench_")] = mod
    if args.only is not None:
        names = [s.strip() for s in args.only.split(",") if s.strip()]
        unknown = sorted(set(names) - set(modules))
        if unknown:
            print(f"unknown bench module(s) {unknown}; "
                  f"known: {sorted(modules)}", file=sys.stderr)
            return 2
        modules = {n: modules[n] for n in names}

    out_dir = os.environ.get("BENCH_OUT_DIR", ".")
    os.makedirs(out_dir, exist_ok=True)
    print("name,us_per_call,derived")
    failed = []
    for name, mod in modules.items():
        status = "ok"
        try:
            mod.main()
        except Exception:
            failed.append(mod.__name__)
            status = "failed"
            traceback.print_exc()
        record = {
            "bench": name,
            "status": status,
            "smoke": SMOKE,
            "python": platform.python_version(),
            "machine": platform.machine(),
            "rows": drain_records(),
        }
        path = os.path.join(out_dir, f"BENCH_{name}.json")
        with open(path, "w") as f:
            json.dump(record, f, indent=1)
    if failed:
        print(f"FAILED: {failed}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    # make `python benchmarks/run.py` work from anywhere: the bench
    # modules import as `benchmarks.*` from the repo root
    _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    if _root not in sys.path:
        sys.path.insert(0, _root)
    sys.exit(main())
