"""Benchmark driver: one module per paper table/figure. Prints
``name,us_per_call,derived`` CSV and writes one machine-readable
``BENCH_<name>.json`` perf record per bench module (rows + status), so
the performance trajectory across PRs can be diffed by tooling.
Roofline terms come from the dry-run (launch.dryrun → EXPERIMENTS.md),
not from here.
"""
from __future__ import annotations

import json
import os
import platform
import sys
import traceback


def main() -> None:
    from benchmarks import (
        bench_delta_sweep,
        bench_gamemap,
        bench_multisource,
        bench_preprocess,
        bench_rmat,
        bench_scaling,
        bench_smallworld,
    )
    from benchmarks.common import drain_records

    out_dir = os.environ.get("BENCH_OUT_DIR", ".")
    print("name,us_per_call,derived")
    failed = []
    for mod in (bench_smallworld, bench_delta_sweep, bench_scaling,
                bench_preprocess, bench_rmat, bench_gamemap,
                bench_multisource):
        name = mod.__name__.rsplit(".", 1)[-1].removeprefix("bench_")
        status = "ok"
        try:
            mod.main()
        except Exception:
            failed.append(mod.__name__)
            status = "failed"
            traceback.print_exc()
        record = {
            "bench": name,
            "status": status,
            "python": platform.python_version(),
            "machine": platform.machine(),
            "rows": drain_records(),
        }
        path = os.path.join(out_dir, f"BENCH_{name}.json")
        with open(path, "w") as f:
            json.dump(record, f, indent=1)
    if failed:
        print(f"FAILED: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
