"""Benchmark driver: one module per paper table/figure. Prints
``name,us_per_call,derived`` CSV. Roofline terms come from the dry-run
(launch.dryrun → EXPERIMENTS.md), not from here.
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (
        bench_delta_sweep,
        bench_gamemap,
        bench_preprocess,
        bench_rmat,
        bench_scaling,
        bench_smallworld,
    )

    print("name,us_per_call,derived")
    failed = []
    for mod in (bench_smallworld, bench_delta_sweep, bench_scaling,
                bench_preprocess, bench_rmat, bench_gamemap):
        try:
            mod.main()
        except Exception:
            failed.append(mod.__name__)
            traceback.print_exc()
    if failed:
        print(f"FAILED: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
