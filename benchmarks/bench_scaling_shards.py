"""Multi-shard scaling curve: shards ∈ {1, 2, 4, 8} for every
mesh-sharded strategy (``sharded_edge``, ``sharded_ell``,
``sharded_fused``) on the small-world family, with parallel efficiency
E(k) = T(1) / (k·T(k)) recorded next to the raw timings.

Each shard width runs in a fresh subprocess that forces an 8-device
host platform *before* JAX initializes — so the sweep produces the
same rows on any host, including the single-device bench-gate runner
(where the "mesh" is 8 XLA host devices over the same cores: expect
E(k) ≈ 1/k there; the curve is about the trend on real meshes, which
is why the efficiency rows are ``gate=False``). Timing rows are gated
like every other bench row.

``sharded_fused`` runs at the census-probed compacted-frontier cap
(bench_sharded._probed_cap), its natural operating point; the child
asserts the solve is overflow-free at that cap."""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap

from benchmarks.bench_sharded import _DELTA, _probed_cap
from benchmarks.common import row, scaled
from repro.core import DeltaConfig, DeltaSteppingSolver
from repro.graphs import watts_strogatz

SHARD_COUNTS = (1, 2, 4, 8)
STRATEGIES = ("sharded_edge", "sharded_ell", "sharded_fused")

_CHILD = textwrap.dedent("""
    import os, sys, warnings
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    warnings.filterwarnings("ignore", category=DeprecationWarning)
    n, shards, cap = (int(a) for a in sys.argv[1:4])
    from benchmarks.common import time_fn
    from repro.core import DeltaConfig, DeltaSteppingSolver
    from repro.graphs import watts_strogatz

    g = watts_strogatz(n, 12, 1e-2, seed=0)
    for strategy in ("sharded_edge", "sharded_ell", "sharded_fused"):
        kw = {"frontier_cap": cap} if strategy == "sharded_fused" else {}
        solver = DeltaSteppingSolver(
            g, DeltaConfig(delta=%d, strategy=strategy, n_shards=shards,
                           pred_mode="none", **kw))
        res = solver.solve(0)
        assert not bool(res.overflow), (strategy, shards, cap)
        t = time_fn(lambda: solver.solve(0).dist, reps=3)
        print(f"RESULT,{strategy},{t:.9f}", flush=True)
""" % _DELTA)


def _run_width(n: int, shards: int, cap: int) -> dict:
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (root, os.path.join(root, "src"),
                    env.get("PYTHONPATH")) if p)
    out = subprocess.run(
        [sys.executable, "-c", _CHILD, str(n), str(shards), str(cap)],
        env=env, capture_output=True, text=True, timeout=1800)
    if out.returncode != 0:
        raise RuntimeError(
            f"shards={shards} child failed:\n{out.stderr[-4000:]}")
    times = {}
    for line in out.stdout.splitlines():
        if line.startswith("RESULT,"):
            _, strat, t = line.split(",")
            times[strat] = float(t)
    missing = set(STRATEGIES) - set(times)
    if missing:
        raise RuntimeError(f"shards={shards}: no RESULT for {missing}")
    return times


def main():
    n = scaled(10_000)
    g = watts_strogatz(n, 12, 1e-2, seed=0)
    ref = DeltaSteppingSolver(
        g, DeltaConfig(delta=_DELTA, strategy="edge",
                       pred_mode="none")).solve(0)
    cap = _probed_cap(g, ref.dist)
    times = {}
    for k in SHARD_COUNTS:
        for strat, t in _run_width(n, k, cap).items():
            times[(strat, k)] = t
            derived = f"shards={k}"
            if strat == "sharded_fused":
                derived += f";cap={cap}"
            row(f"scaling_shards/{strat}/k{k}", t, derived)
    # parallel efficiency, the sweep's derived quantity: E(k) =
    # T(1) / (k·T(k)) per strategy (gate=False: host-dependent trend)
    for strat in STRATEGIES:
        for k in SHARD_COUNTS[1:]:
            eff = times[(strat, 1)] / (k * times[(strat, k)])
            row(f"scaling_shards/{strat}/eff_k{k}", times[(strat, k)],
                f"shards={k};efficiency={eff:.3f}", gate=False)


if __name__ == "__main__":
    import sys as _sys

    _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    if _root not in _sys.path:
        _sys.path.insert(0, _root)
    main()
