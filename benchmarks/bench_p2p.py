"""Point-to-point mode benchmarks (repro.landmarks, DESIGN.md §14).

The preprocessing-vs-query-latency trade the ALT subsystem buys: a
one-time landmark distance-table build (gate:false — it is a capacity
cost, paid once per tenant under the Server LRU) against per-query
speedups of the goal-directed modes over the early-exit unidirectional
solve. Three families spanning the paper's regimes:

* ``lattice``   — long diameter, the early-exit worst case (a far
  target settles nearly every bucket); goal direction + meeting in the
  middle collapse the explored cone.
* ``gamemap``   — the paper's grid-with-obstacles family; near-metric
  structure is where landmark potentials are tightest.
* ``smallworld``— low diameter; the regime where *bidirectional*
  search carries the win and ALT adds little (the derived column keeps
  the evidence).

Every timed mode first asserts its distance bitwise equal to the
early-exit answer — a bench row can never report a speedup for a wrong
answer (the full differential matrix lives in tests/test_landmarks.py).
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import row, scaled, time_fn
from repro.api import Engine, PointToPoint
from repro.core import DeltaConfig
from repro.graphs import grid_map, square_lattice, watts_strogatz

MODES = ("alt", "bidirectional", "alt_bidirectional")


def _families():
    side = int(np.sqrt(scaled(40_000)))
    lat = square_lattice(side, weighted=True)
    gm_side = int(np.sqrt(scaled(22_500)))
    gm, free = grid_map(gm_side, gm_side, 0.1, seed=0)
    sw = watts_strogatz(scaled(10_000), 12, 1e-2, seed=0)
    # far targets: opposite corner on the grids, antipode-ish on the ring
    yield "lattice", lat, None, 10, side * side - 1
    yield "gamemap", gm, free, 13, gm_side * gm_side - 1
    yield "smallworld", sw, None, 10, sw.n_nodes // 2


def main():
    for name, g, free, delta, target in _families():
        cfg = DeltaConfig(delta=delta, strategy="ell", pred_mode="none")
        plan = Engine(g, cfg, free_mask=free).plan()
        base = plan.solve(PointToPoint(0, target))
        t0 = time.perf_counter()
        plan.prepare_landmarks(k=4)
        row(f"p2p/{name}/preprocess", time.perf_counter() - t0,
            f"k={plan.landmark_tables.k};n={g.n_nodes}", gate=False)
        t_base = time_fn(
            lambda: plan.solve(PointToPoint(0, target)).distance)
        row(f"p2p/{name}/early_exit", t_base,
            f"dist={base.distance};"
            f"buckets={int(base.telemetry.buckets)}")
        for mode in MODES:
            q = PointToPoint(0, target, mode=mode)
            res = plan.solve(q)
            assert res.distance == base.distance, (name, mode)
            t = time_fn(lambda: plan.solve(q).distance)
            row(f"p2p/{name}/{mode}", t,
                f"speedup={t_base / t:.2f};dist={res.distance};"
                f"buckets={int(res.telemetry.buckets)}")


if __name__ == "__main__":
    main()
