"""Batched multi-source SSSP — the regime of the paper's betweenness-
centrality citation: many independent sources over one preprocessed
graph. Compares a per-source ``solve`` loop against one batched
``solve_many`` program (vmapped state, shared bucket loop) on the
unified engine; the derived column records the batching speedup, the
number the serving path (serve.SSSPServer) rides on.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import row, scaled, time_fn, tuned_solver, tuned_tag
from repro.core import DeltaConfig, DeltaSteppingSolver
from repro.graphs import watts_strogatz


def main():
    g = watts_strogatz(scaled(10_000), 12, 1e-2, seed=0)
    batch = 8
    srcs = np.arange(batch, dtype=np.int32)
    t_best_bat = None
    for strategy in ("edge", "ell"):
        solver = DeltaSteppingSolver(
            g, DeltaConfig(delta=10, strategy=strategy, pred_mode="none"))
        t_seq = time_fn(
            lambda: [solver.solve(int(s)).dist for s in srcs], reps=2)
        t_bat = time_fn(lambda: solver.solve_many(srcs).dist, reps=2)
        t_best_bat = (t_bat if t_best_bat is None
                      else min(t_best_bat, t_bat))
        row(f"multisource/{strategy}/sequential", t_seq / batch,
            f"batch={batch}")
        row(f"multisource/{strategy}/batched", t_bat / batch,
            f"batch={batch};speedup_vs_sequential={t_seq / t_bat:.2f}")
    # tuned variant: the config the serving path would pick at load time,
    # run through the same batched multi-source program (the tuner
    # probes sources 0-1; the cap is validated against all 8 lanes)
    rec, tuned = tuned_solver(g, sources=tuple(int(s) for s in srcs[:2]),
                              validate_sources=tuple(int(s) for s in srcs))
    t_tu = time_fn(lambda: tuned.solve_many(srcs).dist, reps=2)
    row("multisource/tuned/batched", t_tu / batch,
        f"batch={batch};{tuned_tag(rec)};"
        f"vs_best_untuned={t_best_bat / t_tu:.2f}", gate=False)


if __name__ == "__main__":
    main()
