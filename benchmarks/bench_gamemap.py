"""Paper Figs. 6/7: game maps (occupancy grids, 10% obstacles), Δ=13,
increasing resolution. Compares the generic edge-centric engine, the
grid-stencil engine (the paper's SIMD observation → our Pallas kernel,
exercised here via its jnp oracle backend on CPU), and heap Dijkstra —
the paper reports Δ-stepping 3-5x slower than Dijkstra sequentially on
this family (it wins only with parallelism); the derived column records
that honest ratio.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import SMOKE, row, time_fn, time_host, tuned_solver, tuned_tag
from repro.core import DeltaConfig, DeltaSteppingSolver, dijkstra
from repro.core.grid import GridDeltaConfig, GridDeltaSolver
from repro.graphs import grid_map


def main():
    # grid area scales quadratically in the side, so smoke divides the
    # side by ~3 (≈1/9 the work), not the usual 8.
    sides = (27, 54, 80) if SMOKE else (80, 160, 240)
    for side in sides:
        g, free = grid_map(side, side, 0.1, seed=0)
        src = int(np.flatnonzero(free.ravel())[0])
        rc = (src // side, src % side)

        t_dj = time_host(dijkstra, g, src)

        edge = DeltaSteppingSolver(
            g, DeltaConfig(delta=13, pred_mode="none"))
        t_edge = time_fn(lambda: edge.solve(src).dist, reps=2)

        grid = GridDeltaSolver(free, GridDeltaConfig(backend="ref"))
        t_grid = time_fn(lambda: grid.solve(rc).dist, reps=2)

        row(f"fig67/map{side}/edge", t_edge,
            f"vs_dijkstra={t_dj / t_edge:.2f}")
        row(f"fig67/map{side}/grid_stencil", t_grid,
            f"vs_dijkstra={t_dj / t_grid:.2f};vs_edge={t_edge / t_grid:.2f}")
        # oracle reference row — informational, same reasoning as
        # bench_smallworld's dijkstra rows
        row(f"fig67/map{side}/dijkstra", t_dj, "", gate=False)
        if side == sides[0]:
            # tuned variant (generic backends; the grid stencil above is
            # the family-specific specialist the tuner competes with)
            rec, tuned = tuned_solver(g, sources=(src,), free_mask=free)
            t_tu = time_fn(lambda: tuned.solve(src).dist, reps=2)
            row(f"fig67/map{side}/tuned", t_tu,
                f"{tuned_tag(rec)};vs_edge={t_edge / t_tu:.2f};"
                f"vs_dijkstra={t_dj / t_tu:.2f}", gate=False)


if __name__ == "__main__":
    main()
