"""Paper Figs. 6/7: game maps (occupancy grids, 10% obstacles), Δ=13,
increasing resolution. Compares the generic edge-centric engine, the
grid-stencil engine (the paper's SIMD observation → our Pallas kernel,
exercised here via its jnp oracle backend on CPU), and heap Dijkstra —
the paper reports Δ-stepping 3-5x slower than Dijkstra sequentially on
this family (it wins only with parallelism); the derived column records
that honest ratio.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import row, time_fn
from repro.core import DeltaConfig, DeltaSteppingSolver, dijkstra
from repro.core.grid import GridDeltaConfig, GridDeltaSolver
from repro.graphs import grid_map


def main():
    for side in (80, 160, 240):
        g, free = grid_map(side, side, 0.1, seed=0)
        src = int(np.flatnonzero(free.ravel())[0])
        rc = (src // side, src % side)

        t0 = time.perf_counter()
        dijkstra(g, src)
        t_dj = time.perf_counter() - t0

        edge = DeltaSteppingSolver(
            g, DeltaConfig(delta=13, pred_mode="none"))
        t_edge = time_fn(lambda: edge.solve(src).dist, reps=2)

        grid = GridDeltaSolver(free, GridDeltaConfig(backend="ref"))
        t_grid = time_fn(lambda: grid.solve(rc).dist, reps=2)

        row(f"fig67/map{side}/edge", t_edge,
            f"vs_dijkstra={t_dj / t_edge:.2f}")
        row(f"fig67/map{side}/grid_stencil", t_grid,
            f"vs_dijkstra={t_dj / t_grid:.2f};vs_edge={t_edge / t_grid:.2f}")
        row(f"fig67/map{side}/dijkstra", t_dj, "")


if __name__ == "__main__":
    main()
