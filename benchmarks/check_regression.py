"""Benchmark-regression gate: compare fresh BENCH_*.json perf records
against the committed baselines under ``benchmarks/baselines/``.

CI runs the bench suite in smoke mode (``BENCH_SMOKE=1``, small
instances) and fails the ``bench-gate`` job when any row's
``us_per_call`` exceeds its baseline by more than ``--tolerance``
(default 1.5x — wide enough for shared-runner noise, tight enough to
catch a real 2x slowdown). Tiny rows are exempted by an absolute floor
(``--min-us``): a 40 µs row doubling to 80 µs is scheduler noise, not a
regression.

Row accounting, per baseline file:

* current record missing or ``status != ok``  → FAIL
* smoke-mode mismatch (comparing apples to oranges) → FAIL
* row in baseline but not in current run      → FAIL (coverage loss)
* row regressed past tolerance + floor        → FAIL
* row only in current run                     → noted, passes (new
  coverage lands first, gets a baseline on refresh)
* row marked ``gate: false`` (informational, e.g. one-time tuning-search
  cost — compile-noise dominated) → never compared

Baselines are **hardware-specific** absolute times: records compare
meaningfully only against baselines from comparable machines. On new CI
hardware (or a first run on a different runner class), expect a red
gate and refresh the baselines from the uploaded ``bench-records``
workflow artifact — that is the calibration step, not a code fix.

Refreshing baselines (the documented path, used when a slowdown is
intended or hardware changed):

    BENCH_SMOKE=1 BENCH_OUT_DIR=/tmp/bench python benchmarks/run.py
    python benchmarks/check_regression.py --bench-dir /tmp/bench --update
    git add benchmarks/baselines/ && git commit

``--update`` expects a *full-suite* bench dir; it warns about baselines
with no current record (a renamed/removed bench leaves an orphan that
fails every future gate run) and deletes them when ``--prune`` is also
given.

Self-test (the injected-slowdown drill): ``--inject-slowdown 2.0``
multiplies every current row's time before comparing — the gate must go
red. tests/test_bench_gate.py pins this behaviour.
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import shutil
import sys

DEFAULT_TOLERANCE = 1.5
DEFAULT_MIN_US = 2_000.0

_HERE = os.path.dirname(os.path.abspath(__file__))
DEFAULT_BASELINE_DIR = os.path.join(_HERE, "baselines")


def _load(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


def _rows(record: dict) -> dict:
    return {r["name"]: r for r in record.get("rows", [])}


def compare_records(
    baseline: dict,
    current: dict,
    tolerance: float = DEFAULT_TOLERANCE,
    min_us: float = DEFAULT_MIN_US,
    inject_slowdown: float = 1.0,
):
    """Compare one bench record pair. Returns ``(failures, notes)`` —
    lists of human-readable strings; empty ``failures`` means pass."""
    failures, notes = [], []
    name = baseline.get("bench", "?")
    if current is None:
        return [f"{name}: no current BENCH record (bench did not run)"], notes
    if current.get("status") != "ok":
        failures.append(f"{name}: status={current.get('status')!r}")
    if bool(baseline.get("smoke")) != bool(current.get("smoke")):
        failures.append(
            f"{name}: smoke-mode mismatch (baseline smoke="
            f"{baseline.get('smoke')}, current smoke={current.get('smoke')})"
        )
        return failures, notes
    base_rows, cur_rows = _rows(baseline), _rows(current)
    for row_name, base in sorted(base_rows.items()):
        cur = cur_rows.get(row_name)
        # the BASELINE flag is authoritative: a current run cannot exempt
        # a gated row by flipping its own flag (or dropping the row)
        if not base.get("gate", True):
            continue  # informational row (e.g. one-time tuning cost)
        if cur is None:
            failures.append(f"{name}: row {row_name!r} disappeared")
            continue
        base_us = float(base["us_per_call"])
        cur_us = float(cur["us_per_call"]) * inject_slowdown
        ratio = cur_us / base_us if base_us > 0 else float("inf")
        regressed = ratio > tolerance and (cur_us - base_us) > min_us
        line = (
            f"{name}: {row_name} {base_us:.0f}us -> "
            f"{cur_us:.0f}us ({ratio:.2f}x)"
        )
        if regressed:
            failures.append(line + f" > {tolerance}x tolerance")
        elif ratio > tolerance:
            notes.append(line + f" (within {min_us:.0f}us noise floor)")
    for row_name in sorted(set(cur_rows) - set(base_rows)):
        notes.append(f"{name}: new row {row_name!r} (no baseline yet)")
    return failures, notes


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Gate BENCH_*.json records against committed baselines."
    )
    ap.add_argument(
        "--bench-dir",
        default=".",
        help="directory holding the fresh BENCH_*.json records",
    )
    ap.add_argument(
        "--baseline-dir",
        default=DEFAULT_BASELINE_DIR,
        help="directory of committed baseline records",
    )
    ap.add_argument(
        "--tolerance",
        type=float,
        default=DEFAULT_TOLERANCE,
        help="max allowed current/baseline time ratio (default 1.5)",
    )
    ap.add_argument(
        "--min-us",
        type=float,
        default=DEFAULT_MIN_US,
        help="absolute regression floor in microseconds (default 2000)",
    )
    ap.add_argument(
        "--inject-slowdown",
        type=float,
        default=1.0,
        metavar="FACTOR",
        help="self-test: multiply current times by FACTOR (2.0 must fail)",
    )
    ap.add_argument(
        "--update",
        action="store_true",
        help="refresh baselines from --bench-dir instead of comparing",
    )
    ap.add_argument(
        "--prune",
        action="store_true",
        help="with --update: delete baselines that have no current record "
        "(renamed/removed benches)",
    )
    ap.add_argument(
        "--allow-full",
        action="store_true",
        help="with --update: accept non-smoke (full-size) records",
    )
    args = ap.parse_args(argv)

    if args.update:
        os.makedirs(args.baseline_dir, exist_ok=True)
        fresh = sorted(glob.glob(os.path.join(args.bench_dir, "BENCH_*.json")))
        # validate everything before copying anything: a failed record
        # must not leave a half-refreshed (mixed old/new) baseline set
        for path in fresh:
            record = _load(path)
            if record.get("status") != "ok":
                print(f"refusing to baseline failed record {path}", file=sys.stderr)
                return 2
            if not record.get("smoke") and not args.allow_full:
                print(
                    f"refusing to baseline non-smoke record {path}: the CI "
                    "gate runs BENCH_SMOKE=1 and would fail every run on "
                    "smoke-mode mismatch (--allow-full overrides)",
                    file=sys.stderr,
                )
                return 2
        updated = []
        for path in fresh:
            shutil.copy(path, os.path.join(args.baseline_dir, os.path.basename(path)))
            updated.append(os.path.basename(path))
        print(f"updated {len(updated)} baseline(s): {', '.join(updated)}")
        for path in sorted(glob.glob(os.path.join(args.baseline_dir, "BENCH_*.json"))):
            fname = os.path.basename(path)
            if fname in updated:
                continue
            if args.prune:
                os.unlink(path)
                print(f"pruned orphaned baseline {fname}")
            else:
                print(
                    f"warning: baseline {fname} has no current record "
                    "(orphan — will fail the gate; re-run with --prune "
                    "after a full-suite bench run to remove it)",
                    file=sys.stderr,
                )
        return 0

    baselines = sorted(glob.glob(os.path.join(args.baseline_dir, "BENCH_*.json")))
    if not baselines:
        print(f"no baselines under {args.baseline_dir}", file=sys.stderr)
        return 2
    all_failures, all_notes = [], []
    for path in baselines:
        baseline = _load(path)
        cur_path = os.path.join(args.bench_dir, os.path.basename(path))
        current = _load(cur_path) if os.path.exists(cur_path) else None
        failures, notes = compare_records(
            baseline,
            current,
            tolerance=args.tolerance,
            min_us=args.min_us,
            inject_slowdown=args.inject_slowdown,
        )
        all_failures += failures
        all_notes += notes
    for note in all_notes:
        print(f"note: {note}")
    if all_failures:
        print(f"\n{len(all_failures)} benchmark regression(s):", file=sys.stderr)
        for failure in all_failures:
            print(f"  REGRESSION {failure}", file=sys.stderr)
        print(
            "\nIf intentional, refresh baselines (see module docstring):\n"
            "  BENCH_SMOKE=1 BENCH_OUT_DIR=/tmp/bench python benchmarks/run.py\n"
            "  python benchmarks/check_regression.py --bench-dir /tmp/bench "
            "--update",
            file=sys.stderr,
        )
        return 1
    print(
        f"bench-gate OK: {len(baselines)} record(s) within "
        f"{args.tolerance}x of baseline"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
