"""Shared benchmark utilities: wall-clock timing with warmup, CSV rows,
and an in-process record of every row so the driver can emit
machine-readable ``BENCH_<name>.json`` perf records.

Sizes are scaled down from the paper's (single CPU core here vs 24-core
Xeon there) but keep the paper's *structure*: same graph families, same
parameter grids, same comparisons. Each bench prints
``name,us_per_call,derived`` CSV rows.
"""
from __future__ import annotations

import os
import time
from typing import Dict, List

import jax
import numpy as np

# rows recorded since the last drain_records() call, in emit order
_RECORDS: List[Dict] = []

# BENCH_SMOKE=1 shrinks every instance ~8x: the CI bench-gate regime
# (benchmarks/check_regression.py compares like-for-like, so baselines
# under benchmarks/baselines/ are recorded in this mode too).
SMOKE = os.environ.get("BENCH_SMOKE", "") not in ("", "0")


def scaled(n: int, floor: int = 256) -> int:
    """Instance size for the current mode: full-size normally, ~1/8
    (floored) under BENCH_SMOKE so the CI gate finishes in minutes."""
    return max(floor, n // 8) if SMOKE else n


def time_fn(fn, *args, reps: int = 3, warmup: int = 1):
    """Median wall time of fn(*args) in seconds (jax-blocking)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def time_host(fn, *args, reps: int = 3):
    """Median wall time of a host-side (non-jax) callable. The single
    un-warmed measurement the oracle rows used before is the gate's
    noisiest input — one GC pause or a racing XLA compile thread reads
    as a 2x 'regression' — so oracle baselines get the same median-of-
    reps discipline as the jax rows."""
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn(*args)
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def row(name: str, seconds: float, derived: str = "", gate: bool = True):
    """Emit one CSV row and record it for the JSON perf record.
    ``gate=False`` marks informational rows (e.g. one-time tuning-search
    cost, which is compile-noise dominated) that the CI bench-gate
    reports but does not apply its regression tolerance to."""
    print(f"{name},{seconds * 1e6:.0f},{derived}")
    _RECORDS.append({"name": name, "us_per_call": round(seconds * 1e6),
                     "derived": derived, "gate": gate})


# in-process tuning cache shared across bench modules: several benches
# construct the identical graph (same fingerprint), so one run.py
# invocation searches it once, not four times
_TUNE_CACHE = None


def tuned_solver(g, *, sources=(0,), free_mask=None, use_cache=True,
                 validate_sources=None):
    """Measured-tuned solver plus its ``TuningRecord`` (pred_mode='none',
    the benches' timing config). Every bench records one of these next
    to its hand-picked config so BENCH_*.json carries untuned-vs-tuned
    side by side (DESIGN.md §7). ``use_cache=False`` forces a fresh
    search (for timing the search itself); ``validate_sources`` lists
    the sources the *caller* will solve — ``tune.build_safe_solver``
    drops a tuned frontier cap they overflow (a cached record can come
    from a same-fingerprint graph the cap was never validated on)."""
    global _TUNE_CACHE
    from repro.core import DeltaConfig
    from repro.tune import TuningCache, build_safe_solver, tune

    if _TUNE_CACHE is None:
        _TUNE_CACHE = TuningCache(None)
    base = DeltaConfig(pred_mode="none")
    rec = tune(g, base, sources=sources, free_mask=free_mask,
               cache=_TUNE_CACHE if use_cache else None)
    if not use_cache:
        _TUNE_CACHE.put(rec)                    # later benches still reuse
    _, solver = build_safe_solver(
        g, rec.to_config(base),
        sources=validate_sources if validate_sources is not None else sources,
        free_mask=free_mask)
    return rec, solver


def tuned_tag(rec) -> str:
    """Derived-column fragment describing a tuned operating point."""
    cap = "none" if rec.frontier_cap is None else rec.frontier_cap
    return f"tuned_delta={rec.delta};tuned_strategy={rec.strategy};cap={cap}"


def drain_records() -> List[Dict]:
    """Rows recorded since the last drain (the driver calls this after
    each bench module to build its JSON perf record)."""
    out = list(_RECORDS)
    _RECORDS.clear()
    return out
