"""Shared benchmark utilities: wall-clock timing with warmup, CSV rows.

Sizes are scaled down from the paper's (single CPU core here vs 24-core
Xeon there) but keep the paper's *structure*: same graph families, same
parameter grids, same comparisons. Each bench prints
``name,us_per_call,derived`` CSV rows.
"""
from __future__ import annotations

import time

import jax
import numpy as np


def time_fn(fn, *args, reps: int = 3, warmup: int = 1):
    """Median wall time of fn(*args) in seconds (jax-blocking)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def row(name: str, seconds: float, derived: str = ""):
    print(f"{name},{seconds * 1e6:.0f},{derived}")
