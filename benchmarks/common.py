"""Shared benchmark utilities: wall-clock timing with warmup, CSV rows,
and an in-process record of every row so the driver can emit
machine-readable ``BENCH_<name>.json`` perf records.

Sizes are scaled down from the paper's (single CPU core here vs 24-core
Xeon there) but keep the paper's *structure*: same graph families, same
parameter grids, same comparisons. Each bench prints
``name,us_per_call,derived`` CSV rows.
"""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import numpy as np

# rows recorded since the last drain_records() call, in emit order
_RECORDS: List[Dict] = []


def time_fn(fn, *args, reps: int = 3, warmup: int = 1):
    """Median wall time of fn(*args) in seconds (jax-blocking)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def row(name: str, seconds: float, derived: str = ""):
    print(f"{name},{seconds * 1e6:.0f},{derived}")
    _RECORDS.append({"name": name, "us_per_call": round(seconds * 1e6),
                     "derived": derived})


def drain_records() -> List[Dict]:
    """Rows recorded since the last drain (the driver calls this after
    each bench module to build its JSON perf record)."""
    out = list(_RECORDS)
    _RECORDS.clear()
    return out
