"""Frontier-policy family sweep (DESIGN.md §15): ρ-stepping and
radius-stepping vs measured-tuned Δ-stepping, per graph family.

The policy axis trades round structure against per-round sweep cost.
The headline: on the paper's small-world family, ρ-stepping with a
small batch (ρ ≈ |V|/64) keeps every frontier inside a tight compaction
cap (3ρ) that Δ-stepping's wide buckets cannot use — the gated
``rho`` row's derived column records the speedup over the tuned
Δ-stepping reference. On R-MAT the hub structure favors Δ's one-bucket
schedule and Δ-stepping stays ahead; the sweep records that honestly.

Rows: ``delta_tuned`` (the delta-only measured search — the reference),
``rho`` / ``radius`` (hand-picked policy operating points, gated),
``auto`` (the full-axis search including policies — which policy the
tuner itself picks; tuner-chosen rows are informational, gate=False).
"""
from __future__ import annotations

from benchmarks.common import row, scaled, time_fn
from repro.api import Engine, SingleSource
from repro.core import DeltaConfig


def _plan_for(g, cfg):
    plan = Engine(g, cfg).plan()
    t = time_fn(lambda: plan.solve(SingleSource(0)).dist, reps=3)
    res = plan.solve(SingleSource(0))
    assert not bool(res.telemetry.overflow), cfg
    return t, res


def main():
    from repro.graphs import rmat, watts_strogatz
    from repro.tune import tune

    n_ws = scaled(16_000)
    n_rm = scaled(16_000)
    families = {
        "smallworld": (watts_strogatz(n_ws, 12, 1e-2, seed=0),
                       "ell", True),
        # ELL padding explodes on R-MAT hubs: policies ride 'edge', and
        # the compaction cap does not apply (edge has no compaction)
        "rmat": (rmat(n_rm, 12 * n_rm, seed=0), "edge", False),
    }
    for fam, (g, strat, capped) in families.items():
        n = g.n_nodes
        base = DeltaConfig(pred_mode="none")
        # the Δ-stepping reference: measured search over the classic
        # (Δ, backend, cap) space only — the policy axis held out
        rec = tune(g, base, policies=("delta",))
        t_delta, _ = _plan_for(g, rec.to_config(base))
        cap = "none" if rec.frontier_cap is None else rec.frontier_cap
        row(f"policies/{fam}/delta_tuned", t_delta,
            f"delta={rec.delta};strategy={rec.strategy};cap={cap}",
            gate=False)

        # ρ-stepping at a batch small enough that every value-closed
        # round fits a 3ρ compaction cap (small-world; edge on rmat)
        rho = max(128, n // 64)
        cfg = DeltaConfig(pred_mode="none", strategy=strat, delta=10,
                          policy="rho", rho=rho,
                          frontier_cap=min(n, 3 * rho) if capped else None)
        t_rho, res = _plan_for(g, cfg)
        row(f"policies/{fam}/rho", t_rho,
            f"rho={rho};strategy={strat};"
            f"rounds={int(res.telemetry.buckets)};"
            f"speedup_vs_delta_tuned={t_delta / t_rho:.2f}")

        # radius-stepping: k-th-out-weight radii, full-width frontier
        cfg = DeltaConfig(pred_mode="none", strategy=strat, delta=10,
                          policy="radius", radius_k=4)
        t_rad, res = _plan_for(g, cfg)
        row(f"policies/{fam}/radius", t_rad,
            f"radius_k=4;strategy={strat};"
            f"rounds={int(res.telemetry.buckets)};"
            f"speedup_vs_delta_tuned={t_delta / t_rad:.2f}")

        # the full-axis search: (Δ, backend, cap, policy) compete in one
        # halving loop — records which algorithm the tuner itself picks
        auto = tune(g, base)
        t_auto, _ = _plan_for(g, auto.to_config(base))
        row(f"policies/{fam}/auto", t_auto,
            f"policy={auto.policy};strategy={auto.strategy};"
            f"delta={auto.delta};"
            f"speedup_vs_delta_tuned={t_delta / t_auto:.2f}",
            gate=False)


if __name__ == "__main__":
    main()
