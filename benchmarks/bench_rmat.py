"""Paper Fig. 5: RMat scale-free graphs (a=.5 b=.25 c=.1 d=.15), Δ=10.
The paper observes ~4 bucket iterations and high timing variance from
CAS contention; the contention-free scatter-min here removes the
variance mechanism — the derived column records bucket count (the
paper's '4 iterations' check) and the max/min timing spread.

A manual mini-sweep over Δ plus the auto-tuned variant (repro.tune)
makes the untuned-vs-tuned comparison concrete on the scale-free family
(tuned must land within 1.1x of the best manual row).
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import row, scaled, time_fn, tuned_solver, tuned_tag
from repro.core import DeltaConfig, DeltaSteppingSolver
from repro.graphs import rmat


def main():
    # full size matches the PR 1 record exactly (30k, 400k); smoke keeps
    # the same edge/vertex ratio at 1/8 scale
    n, m = scaled(30_000), scaled(400_000)
    g = rmat(n, m, seed=0)
    solver = DeltaSteppingSolver(g, DeltaConfig(delta=10, pred_mode="none"))
    res = solver.solve(0)
    times = []
    for _ in range(6):                      # paper: 40 repeats
        t0 = time.perf_counter()
        import jax
        jax.block_until_ready(solver.solve(0).dist)
        times.append(time.perf_counter() - t0)
    times = np.asarray(times)
    row("fig5/rmat", float(np.median(times)),
        f"buckets={int(res.outer_iters)};"
        f"spread={(times.max() - times.min()) / times.mean():.3f}")

    # manual Δ mini-sweep (the by-hand protocol the tuner replaces)
    best = None
    for delta in (5, 10, 20, 40):
        s = DeltaSteppingSolver(g, DeltaConfig(delta=delta, pred_mode="none"))
        t = time_fn(lambda: s.solve(0).dist, reps=1)
        best = t if best is None else min(best, t)
        row(f"fig5/rmat_delta{delta}", t, "")
    rec, tuned = tuned_solver(g)
    t_tu = time_fn(lambda: tuned.solve(0).dist, reps=1)
    row("fig5/rmat_tuned", t_tu,
        f"{tuned_tag(rec)};vs_best_manual={t_tu / best:.2f}", gate=False)


if __name__ == "__main__":
    main()
