"""Paper Fig. 5: RMat scale-free graphs (a=.5 b=.25 c=.1 d=.15), Δ=10.
The paper observes ~4 bucket iterations and high timing variance from
CAS contention; the contention-free scatter-min here removes the
variance mechanism — the derived column records bucket count (the
paper's '4 iterations' check) and the max/min timing spread.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import row
from repro.core import DeltaConfig, DeltaSteppingSolver
from repro.graphs import rmat


def main():
    n, m = 30_000, 400_000
    g = rmat(n, m, seed=0)
    solver = DeltaSteppingSolver(g, DeltaConfig(delta=10, pred_mode="none"))
    res = solver.solve(0)
    times = []
    for _ in range(6):                      # paper: 40 repeats
        t0 = time.perf_counter()
        import jax
        jax.block_until_ready(solver.solve(0).dist)
        times.append(time.perf_counter() - t0)
    times = np.asarray(times)
    row("fig5/rmat", float(np.median(times)),
        f"buckets={int(res.outer_iters)};"
        f"spread={(times.max() - times.min()) / times.mean():.3f}")


if __name__ == "__main__":
    main()
