"""Paper Tables 2/3: sequential Δ-stepping vs (Boost-class) heap Dijkstra
on Watts-Strogatz small-world graphs over the paper's (p, k) grid.

The paper's headline: Δ-stepping with Δ=10 beats Dijkstra 2-100x on
low-diameter graphs even single-threaded. Sizes reduced (paper: 0.5M-6M
vertices on a 24-core Xeon; here: 20k-60k on one CPU core) — the
derived column reports the speedup ratio, the paper-comparable number.

The auto-tuned variant (repro.tune measured search) is recorded for the
representative p=1e-2, k=12 instance next to the hand-picked Δ=10 row.
"""
from __future__ import annotations


from benchmarks.common import row, scaled, time_fn, time_host, tuned_solver, tuned_tag
from repro.core import DeltaConfig, DeltaSteppingSolver, dijkstra
from repro.graphs import watts_strogatz


def main():
    for p in (1e-4, 1e-2):
        for k in (12, 20):
            for n in (scaled(10_000), scaled(30_000)):
                g = watts_strogatz(n, k, p, seed=0)
                solver = DeltaSteppingSolver(
                    g, DeltaConfig(delta=10, pred_mode="none"))
                t_ds = time_fn(lambda: solver.solve(0).dist, reps=2)
                t_dj = time_host(dijkstra, g, 0)
                tag = f"smallworld_p{p:g}_k{k}_n{n}"
                row(f"tab2/{tag}/delta", t_ds,
                    f"speedup_vs_dijkstra={t_dj / t_ds:.2f}")
                # oracle reference, not engine code: host-side heapq is
                # the suite's noisiest timing (races XLA compile threads
                # in-process) and only exists for the derived ratio
                row(f"tab2/{tag}/dijkstra", t_dj, "", gate=False)
                if p == 1e-2 and k == 12 and n == scaled(10_000):
                    rec, tuned = tuned_solver(g)
                    t_tu = time_fn(lambda: tuned.solve(0).dist, reps=2)
                    row(f"tab2/{tag}/delta_tuned", t_tu,
                        f"{tuned_tag(rec)};vs_untuned={t_ds / t_tu:.2f};"
                        f"speedup_vs_dijkstra={t_dj / t_tu:.2f}",
                        gate=False)


if __name__ == "__main__":
    main()
