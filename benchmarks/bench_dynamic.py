"""Dynamic-update benchmarks: warm-start re-solve vs cold solve
(repro.dynamic, DESIGN.md §11).

The production claim being measured: after a k-edge cost perturbation,
``plan.resolve(warm=True)`` repairs the resident answer instead of
re-solving from scratch, and wins by a growing margin as k shrinks —
the k-sweep records 0.1% and 1% of |E| on the paper's small-world
family (mixed-sign, in-range perturbations under pred_mode='argmin',
whose tree the increase cone needs), plus a long-diameter lattice row
showing the unsettled-only bucket scan skipping the untouched prefix
of the bucket sequence.

Protocol notes (bench-gate noise discipline): every perturbation batch
is deterministic (seeded ids + absolute replacement weights), batches
cycle across reps, and one full warm-up pass over the batch cycle
pre-compiles every repair-twin cap class before timing starts — cap
compiles are a once-per-workload cost, not a per-update cost, and must
not pollute the steady-state medians the gate compares.
"""
from __future__ import annotations

import itertools

import numpy as np

from benchmarks.common import row, scaled, time_fn
from repro.api import Engine, SingleSource
from repro.core import DeltaConfig
from repro.graphs import square_lattice, watts_strogatz


def _batches(rng, n_edges, w0, k, n_batches=6):
    """Deterministic mixed-sign batches: each id set carries two
    distinct absolute weight assignments (phase A, then an elementwise-
    different phase B), and the cycle interleaves them — so re-applying
    the cycle always performs a *real* weight change, never a no-op the
    warm path would short-circuit (which would fake the timing)."""
    sets = []
    for _ in range(n_batches):
        ids = rng.choice(n_edges, size=k, replace=False)
        wa = np.clip(w0[ids] + rng.integers(-5, 6, size=k), 1, 20)
        wb = np.where(wa < 20, wa + 1, wa - 1)
        sets.append((ids, wa, wb))
    return [(ids, wa) for ids, wa, _ in sets] + \
           [(ids, wb) for ids, _, wb in sets]


def main():
    n = scaled(20_000)
    g = watts_strogatz(n, 12, 1e-2, seed=0)
    e = g.n_edges
    plan = Engine(g, DeltaConfig(delta=10, pred_mode="argmin")).plan()
    plan.solve(SingleSource(0))

    rng = np.random.default_rng(5)
    w0 = np.asarray(g.w)
    ks = {"k0.1pct": max(1, e // 1000), "k1pct": max(2, e // 100)}
    cycles = {
        name: itertools.cycle(_batches(rng, e, w0, k))
        for name, k in ks.items()
    }

    def bump(cycle, warm):
        ids, neww = next(cycle)
        plan.update(ids, neww)
        return plan.resolve(warm=warm)

    # cold reference: update + full re-solve per call (the pre-dynamic
    # serving reality), on the same batch protocol
    cold_cycle = itertools.cycle(_batches(rng, e, w0, ks["k1pct"]))
    t_cold = time_fn(lambda: bump(cold_cycle, False).dist)
    b_cold = int(plan.resolve(warm=False).telemetry.buckets)
    row("dynamic/smallworld/cold_resolve", t_cold,
        f"n={n};edges={e};buckets={b_cold}")

    for name, k in ks.items():
        cycle = cycles[name]
        # warm-up: two full cycles. The first leaves the weight state
        # periodic; the second visits every periodic batch *transition*
        # once, compiling every repair-twin cap class the timed reps can
        # encounter (a cap class first seen mid-measurement would charge
        # a one-time XLA compile to the steady-state median)
        for _ in range(24):
            bump(cycle, True)
        res = bump(cycle, True)
        assert res.telemetry.repaired, "no-op batch would fake the timing"
        t_warm = time_fn(lambda: bump(cycle, True).dist)
        # the k0.1pct row sits at single-digit ms in smoke mode — below
        # reliable gate territory on shared runners; the speedup itself
        # is the record
        row(f"dynamic/smallworld/warm_{name}", t_warm,
            f"k={k};speedup={t_cold / t_warm:.2f};"
            f"repaired={res.telemetry.repaired};"
            f"buckets={int(res.telemetry.buckets)}/{b_cold}",
            gate=(name != "k0.1pct"))

    # long-diameter lattice: a far-end perturbation exercises the
    # unsettled-only next-bucket scan — warm visits a handful of
    # buckets out of hundreds
    side = int(np.sqrt(scaled(40_000)))
    lat = square_lattice(side, weighted=True)
    lplan = Engine(lat, DeltaConfig(delta=10, pred_mode="argmin")).plan()
    lplan.solve(SingleSource(0))
    t_lcold = time_fn(lambda: lplan.resolve(warm=False).dist)
    lb_cold = int(lplan.resolve(warm=False).telemetry.buckets)
    far_edge = int(np.argmax(np.asarray(lat.dst)))
    wf = int(np.asarray(lat.w)[far_edge])
    flip = itertools.cycle([wf + 7, wf])          # increase, restore, ...

    def far_bump():
        lplan.update([far_edge], [next(flip)])
        return lplan.resolve(warm=True)

    far_bump()                                     # compile warm-up
    res = far_bump()
    t_lwarm = time_fn(lambda: far_bump().dist)
    row("dynamic/lattice/warm_far_edge", t_lwarm,
        f"speedup={t_lcold / t_lwarm:.2f};"
        f"buckets={int(res.telemetry.buckets)}/{lb_cold};"
        f"repaired={res.telemetry.repaired}",
        gate=False)  # single-digit ms: below reliable gate territory
    row("dynamic/lattice/cold_resolve", t_lcold,
        f"side={side};buckets={lb_cold}")


if __name__ == "__main__":
    main()
