"""Substrate tests: optimizers, checkpointing, compression, sampler,
elastic planning, data determinism, serving."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import AsyncWriter, latest_step, restore, save
from repro.data import SyntheticClicks, SyntheticTokens
from repro.distributed import (
    compress_grads,
    dequantize_int8,
    plan_remesh,
    quantize_int8,
    topk_sparsify,
)
from repro.graphs import coo_to_csr, random_graph
from repro.graphs.sampler import sample_khop
from repro.optim import adafactor, adamw, clip_by_global_norm, sgdm


# ---------------------------------------------------------------- optimizers
@pytest.mark.parametrize("make_opt", [lambda: adamw(5e-2),
                                      lambda: adafactor(1e-1),
                                      lambda: sgdm(1e-2)])
def test_optimizer_reduces_quadratic(make_opt):
    opt = make_opt()
    params = {"w": jnp.array([3.0, -2.0, 1.5]), "b": jnp.zeros(())}

    def loss(p):
        return jnp.sum(p["w"] ** 2) + p["b"] ** 2

    state = opt.init(params)
    l0 = float(loss(params))
    step = jax.jit(lambda p, s: opt.update(jax.grad(loss)(p), s, p))
    for _ in range(200):
        params, state = step(params, state)
    assert float(loss(params)) < 0.2 * l0


def test_adafactor_factored_state_is_small():
    opt = adafactor(1e-2)
    params = {"big": jnp.zeros((256, 512))}
    st = opt.init(params)
    n_state = sum(x.size for x in jax.tree.leaves(st["f"]))
    assert n_state == 256 + 512          # vr + vc, not 256*512


def test_adafactor_chunked_update_matches_unchunked():
    """The lax.map layer-chunked path (big stacked params) must be
    numerically identical to the direct path."""
    opt = adafactor(1e-2)
    key = jax.random.key(0)
    # big enough to trigger chunking: ndim>=3 and >2^28 bytes/4
    p_big = {"w": jax.random.normal(key, (4, 300, 300)) * 0.1}
    g = {"w": jax.random.normal(jax.random.key(1), (4, 300, 300)) * 0.01}
    st = opt.init(p_big)
    new_chunked, _ = opt.update(g, st, p_big)
    # replicate math manually via the non-chunked branch on small slices
    # (consistency check: each layer slice updated independently)
    sliced = []
    for i in range(4):
        pi = {"w": p_big["w"][i]}
        gi = {"w": g["w"][i]}
        sti = opt.init(pi)
        npi, _ = opt.update(gi, sti, pi)
        sliced.append(npi["w"])
    # NOTE: global-norm clipping couples slices; disable by comparing
    # only when clip doesn't trigger (norms well below 1.0 here).
    _, gnorm = clip_by_global_norm(g, 1.0)
    if float(gnorm) < 1.0:
        np.testing.assert_allclose(np.asarray(new_chunked["w"]),
                                   np.stack([np.asarray(s) for s in sliced]),
                                   rtol=2e-5)


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(20.0)
    assert float(jnp.linalg.norm(clipped["a"])) == pytest.approx(1.0, rel=1e-5)


# -------------------------------------------------------------- checkpointing
def test_checkpoint_roundtrip_and_gc():
    tree = {"a": jnp.arange(5), "nested": {"b": jnp.ones((2, 3))}}
    with tempfile.TemporaryDirectory() as d:
        for step in [1, 2, 3, 4]:
            save(d, step, tree, keep=2)
        assert latest_step(d) == 4
        assert sorted(os.listdir(d)) == ["step_3", "step_4"]
        restored, step = restore(d, tree)
        assert step == 4
        np.testing.assert_array_equal(np.asarray(restored["a"]),
                                      np.arange(5))


def test_async_writer_persists():
    tree = {"x": jnp.full((4,), 7.0)}
    with tempfile.TemporaryDirectory() as d:
        w = AsyncWriter(d, keep=2)
        w.submit(10, tree)
        w.wait()
        restored, step = restore(d, tree)
        assert step == 10
        np.testing.assert_array_equal(np.asarray(restored["x"]),
                                      np.full(4, 7.0))


# --------------------------------------------------------------- compression
def test_int8_quantization_bounded_error():
    g = jax.random.normal(jax.random.key(0), (1000,))
    q, s = quantize_int8(g)
    assert q.dtype == jnp.int8
    err = jnp.abs(dequantize_int8(q, s) - g).max()
    assert float(err) <= float(s) * 0.5 + 1e-6


def test_topk_keeps_largest():
    g = jnp.asarray([0.1, -5.0, 0.2, 3.0, -0.05])
    kept, resid = topk_sparsify(g, 0.4)
    np.testing.assert_array_equal(np.asarray(kept != 0),
                                  [False, True, False, True, False])
    np.testing.assert_allclose(np.asarray(kept + resid), np.asarray(g))


def test_error_feedback_accumulates():
    """With error feedback, the sum of compressed grads over steps tracks
    the sum of raw grads (residual never lost)."""
    grads = {"w": jnp.full((8,), 0.3)}
    residuals = None
    total = jnp.zeros((8,))
    for _ in range(10):
        comp, residuals = compress_grads(grads, residuals, scheme="topk",
                                         topk_frac=0.25)
        total = total + comp["w"]
    drift = jnp.abs(total + residuals["w"] - 3.0).max()
    assert float(drift) < 1e-5


# ------------------------------------------------------------------- sampler
def test_sampler_shapes_and_membership():
    g = random_graph(200, 3000, seed=0)
    csr = coo_to_csr(g)
    seeds = jnp.arange(16, dtype=jnp.int32)
    nodes, blocks = sample_khop(jax.random.key(0), csr.row_ptr, csr.col,
                                seeds, (5, 3))
    assert blocks[0].n_dst == 16
    assert nodes.shape[0] == 16 + 16 * 5 + (16 + 80) * 3
    # sampled neighbors are real neighbors (or self loops for deg-0)
    rp = np.asarray(csr.row_ptr)
    col = np.asarray(csr.col)
    nb = np.asarray(nodes)
    for i, s in enumerate(np.asarray(seeds)):
        samp = nb[16 + i * 5: 16 + (i + 1) * 5]
        neigh = set(col[rp[s]:rp[s + 1]]) | {s}
        assert set(samp.tolist()) <= neigh


# -------------------------------------------------------------------- elastic
def test_plan_remesh():
    assert plan_remesh(512, 16) == (32, 16)
    assert plan_remesh(480, 16) == (30, 16)   # lost a node: shrink data dim
    with pytest.raises(ValueError):
        plan_remesh(8, 16)


# ----------------------------------------------------------------------- data
def test_data_is_step_indexed_and_deterministic():
    d = SyntheticTokens(vocab=100, batch=2, seq_len=8, seed=3)
    a = d.batch_at(5)
    b = d.batch_at(5)
    c = d.batch_at(6)
    np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                  np.asarray(b["tokens"]))
    assert not np.array_equal(np.asarray(a["tokens"]),
                              np.asarray(c["tokens"]))
    clicks = SyntheticClicks((50, 20), 13, batch=4)
    cb = clicks.batch_at(0)
    assert cb["sparse"].shape == (4, 2)
    assert (np.asarray(cb["sparse"]) < np.array([50, 20])).all()
