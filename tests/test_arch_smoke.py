"""Per-architecture smoke tests: instantiate the REDUCED config of each
assigned arch, run one forward/train step on CPU, assert output shapes
and absence of NaNs. (Full configs are exercised compile-only by the
dry-run.)"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, family_of, get_config
from repro.data import SyntheticClicks, SyntheticTokens, gnn_full_batch
from repro.graphs import random_graph
from repro.models import dlrm as dlrm_lib
from repro.models import gnn as gnn_lib
from repro.models import transformer as tf_lib
from repro.optim import adamw
from repro.train import make_train_step

LM_ARCHS = [a for a in ARCH_IDS if family_of(a) == "lm"]
GNN_ARCHS = [a for a in ARCH_IDS if family_of(a) == "gnn"]


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke_train_step(arch):
    cfg = get_config(arch, smoke=True)
    params = tf_lib.init_lm(cfg, jax.random.key(0))
    data = SyntheticTokens(vocab=cfg.vocab, batch=2, seq_len=32)
    loss_fn = lambda p, b: tf_lib.lm_loss(p, cfg, b["tokens"], b["labels"],
                                          loss_chunk=16)
    step = make_train_step(loss_fn, adamw(1e-3), donate=False)
    opt = adamw(1e-3)
    st = opt.init(params)
    p2, st, _, metrics = step(params, st, None, data.batch_at(0))
    assert jnp.isfinite(metrics["loss"]), arch
    # params actually changed
    moved = any(
        not np.allclose(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)))
    assert moved, arch


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke_serve(arch):
    cfg = get_config(arch, smoke=True)
    params = tf_lib.init_lm(cfg, jax.random.key(0))
    cache = tf_lib.init_cache(cfg, 2, 64)
    toks = jax.random.randint(jax.random.key(1), (2, 16), 0, cfg.vocab)
    logits, cache = tf_lib.prefill(params, cfg, toks, cache)
    assert logits.shape == (2, 1, cfg.vocab)
    logits, cache = tf_lib.decode_step(params, cfg, cache, toks[:, :1])
    assert logits.shape == (2, 1, cfg.vocab)
    assert bool(jnp.isfinite(logits).all()), arch
    assert int(cache["len"]) == 17


@pytest.mark.parametrize("arch", GNN_ARCHS)
def test_gnn_smoke_train_step(arch):
    cfg = get_config(arch, smoke=True)
    g = random_graph(60, 240, seed=3)
    params = gnn_lib.init_gnn(cfg, jax.random.key(0))
    if cfg.arch == "schnet":
        from repro.data import molecule_batch
        mb = molecule_batch(4, 10, 24, seed=1)
        inputs = dict(atom_z=mb["atom_z"], pos=mb["pos"], src=mb["src"],
                      dst=mb["dst"], mol_id=mb["mol_id"])
        loss, _ = gnn_lib.gnn_loss(params, cfg, inputs, mb["energy"])
        grads = jax.grad(lambda p: gnn_lib.gnn_loss(
            p, cfg, inputs, mb["energy"])[0])(params)
    else:
        fb = gnn_full_batch(60, cfg.d_in, cfg.n_classes, seed=1)
        inputs = dict(x=fb["x"], src=g.src, dst=g.dst)
        loss, _ = gnn_lib.gnn_loss(params, cfg, inputs, fb["labels"],
                                   fb["label_mask"])
        grads = jax.grad(lambda p: gnn_lib.gnn_loss(
            p, cfg, inputs, fb["labels"], fb["label_mask"])[0])(params)
    assert jnp.isfinite(loss)
    assert all(bool(jnp.isfinite(l).all()) for l in jax.tree.leaves(grads))


def test_dlrm_smoke_train_step():
    cfg = get_config("dlrm-mlperf", smoke=True)
    params = dlrm_lib.init_dlrm(cfg, jax.random.key(0))
    data = SyntheticClicks(cfg.vocab_sizes, cfg.n_dense, batch=32)
    b = data.batch_at(0)
    loss, _ = dlrm_lib.dlrm_loss(params, cfg, b["dense"], b["sparse"],
                                 b["labels"])
    assert jnp.isfinite(loss)
    logits = dlrm_lib.apply_dlrm(params, cfg, b["dense"], b["sparse"])
    assert logits.shape == (32,)
    cand = jax.random.normal(jax.random.key(2), (500, cfg.embed_dim))
    s, i = dlrm_lib.retrieval_score(params, cfg, b["dense"][:1],
                                    b["sparse"][:1], cand, top_k=7)
    assert s.shape == (1, 7) and bool((i < 500).all())


def test_full_configs_param_counts():
    """Sanity-check the headline parameter counts of the full configs
    (the 1T MoE must actually be ~1T; active ~32B)."""
    kimi = get_config("kimi-k2-1t-a32b")
    assert 0.9e12 < kimi.n_params() < 1.2e12, kimi.n_params()
    assert 20e9 < kimi.n_active_params() < 40e9, kimi.n_active_params()
    # NOTE: the assigned spec (48L x 64e x d_ff 1408) arithmetically gives
    # ~28B total / ~4B active; the '16b' in the name corresponds to the
    # released 27-layer Moonlight. We implement the assigned spec verbatim.
    moonshot = get_config("moonshot-v1-16b-a3b")
    assert 20e9 < moonshot.n_params() < 35e9
    assert 2e9 < moonshot.n_active_params() < 6e9
    granite = get_config("granite-20b")
    assert 15e9 < granite.n_params() < 25e9
    yi = get_config("yi-34b")
    assert 30e9 < yi.n_params() < 40e9
    gemma = get_config("gemma2-9b")
    assert 8e9 < gemma.n_params() < 12e9
