"""API-stability snapshot (DESIGN.md §10): the exported surface of the
Query/Plan façade (``repro.api``) and the deprecated aliases it
subsumes are pinned here, so a refactor cannot silently drop an entry
point — the failure mode that let the public surface fracture into four
overlapping entry points in the first place. Additions are fine (extend
the snapshot); removals/renames must be deliberate."""
import inspect

import repro.api as api
import repro.core as core
import repro.serve as serve
import repro.tune as tune

# the façade surface: the one public entry point set
API_EXPORTS = {
    "BoundedRadius",
    "BoundedRadiusResult",
    "Engine",
    "ManyToMany",
    "ManyToManyResult",
    "MultiSource",
    "MultiSourceResult",
    "Plan",
    "PointToPoint",
    "PointToPointResult",
    "Query",
    "Result",
    "SingleSource",
    "SingleSourceResult",
    "Telemetry",
    "Tuning",
    "UpdateBatch",
    "UpdateRefused",
    "LandmarkRefused",
    "extract_path",
    "stitch_bidirectional_path",
}

# the async serving tier (DESIGN.md §13)
SERVE_EXPORTS = {"Server", "Ticket", "RequestRejected", "RequestTrace",
                 "UpdateApplied"}

# deprecated aliases: the pre-façade entry points kept as thin shims
# under the bitwise-parity contract (tests/test_api_queries.py)
CORE_DEPRECATED = {"DeltaSteppingSolver", "delta_stepping"}
SERVE_DEPRECATED = {"SSSPServer", "SSSPQuery"}

# the tuning surface the façade resolves through
TUNE_REQUIRED = {"resolve_record", "resolve_config", "build_safe_solver",
                 "TuningRecord", "TuningCache", "tune", "tune_p2p"}


def test_api_export_snapshot():
    assert set(api.__all__) == API_EXPORTS
    for name in api.__all__:
        assert hasattr(api, name), name


def test_serve_export_snapshot():
    for name in SERVE_EXPORTS:
        assert name in serve.__all__, name
        assert hasattr(serve, name), name


def test_server_surface():
    """The serving tier's load-bearing signatures (DESIGN.md §13)."""
    assert list(inspect.signature(serve.Server.__init__).parameters) == [
        "self", "graphs", "config", "tuning", "lane_width", "max_resident",
        "max_queue", "clock", "landmarks"]
    assert list(inspect.signature(serve.Server.submit).parameters) == [
        "self", "query", "graph", "deadline"]
    assert list(inspect.signature(serve.Server.admit).parameters) == [
        "self", "name", "graph", "config", "free_mask"]
    for attr in ("submit", "admit", "plan", "stats", "pump", "drain",
                 "start", "close"):
        assert hasattr(serve.Server, attr), attr
    for attr in ("result", "done", "exception"):
        assert hasattr(serve.Ticket, attr), attr
    assert [f for f in serve.RequestTrace.__dataclass_fields__] == [
        "tenant", "kind", "t_submit", "t_batch", "t_solve", "t_done",
        "batch_occupancy", "shed"]


def test_deprecated_aliases_still_exported():
    for name in CORE_DEPRECATED:
        assert name in core.__all__, name
        assert hasattr(core, name), name
    for name in SERVE_DEPRECATED:
        assert name in serve.__all__, name
        assert hasattr(serve, name), name
    for name in TUNE_REQUIRED:
        assert name in tune.__all__, name
        assert hasattr(tune, name), name


def test_deprecated_signatures_frozen():
    """The shim signatures are the parity contract: old call sites must
    keep working verbatim."""
    assert list(inspect.signature(
        core.DeltaSteppingSolver.__init__).parameters) == [
        "self", "graph", "config", "free_mask", "tune_cache"]
    assert list(inspect.signature(
        core.delta_stepping).parameters) == ["graph", "source", "config"]
    assert list(inspect.signature(
        serve.SSSPServer.__init__).parameters) == [
        "self", "graph", "config", "batch_size", "free_mask", "tune",
        "tune_cache"]
    # solve/solve_many keep returning the legacy SSSPResult tuple
    assert core.SSSPResult._fields == (
        "dist", "pred", "outer_iters", "inner_iters", "overflow")


def test_engine_and_plan_surface():
    """The façade's own load-bearing methods/attributes."""
    import jax.numpy as jnp
    from repro.graphs.structures import COOGraph

    assert list(inspect.signature(api.Engine.__init__).parameters) == [
        "self", "graph", "config", "free_mask", "tuning", "tune",
        "tune_cache"]
    assert [f for f in api.Tuning.__dataclass_fields__] == [
        "measure", "cache"]
    assert list(inspect.signature(api.Engine.plan).parameters) == [
        "self", "sources", "fallback"]
    assert list(inspect.signature(api.Plan.solve).parameters) == [
        "self", "query"]
    g = COOGraph(jnp.array([0], jnp.int32), jnp.array([1], jnp.int32),
                 jnp.array([3], jnp.int32), 2)
    plan = api.Engine(g, core.DeltaConfig(delta=4)).plan()
    for attr in ("config", "graph", "backend", "record", "solve",
                 "explain", "update", "resolve", "prepare_landmarks",
                 "landmark_tables"):
        assert hasattr(plan, attr), attr
    assert list(inspect.signature(api.Plan.update).parameters) == [
        "self", "edge_ids", "new_weights"]
    assert list(inspect.signature(api.Plan.resolve).parameters) == [
        "self", "warm"]
    assert plan.record is None              # no tuning inputs, no record
    assert isinstance(plan.explain(), dict)


def test_query_algebra_fields():
    """Query constructors are the wire format of the façade — pin their
    field names."""
    assert [f for f in api.SingleSource.__dataclass_fields__] == ["source"]
    assert [f for f in api.MultiSource.__dataclass_fields__] == ["sources"]
    assert [f for f in api.PointToPoint.__dataclass_fields__] == [
        "source", "target", "mode"]
    assert [f for f in api.BoundedRadius.__dataclass_fields__] == [
        "source", "radius"]
    assert [f for f in api.ManyToMany.__dataclass_fields__] == [
        "sources", "targets", "tile"]
    assert [f for f in api.UpdateBatch.__dataclass_fields__] == [
        "edge_ids", "new_weights", "warm"]
    assert [f for f in api.Telemetry.__dataclass_fields__] == [
        "buckets", "inner_iters", "overflow", "fallback", "warm",
        "repaired", "cone"]
