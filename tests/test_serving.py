"""The async serving tier (repro.serve.Server, DESIGN.md §13): mixed
async traffic must stay bitwise identical to serial execution through
``Engine.plan()``; tenancy (LRU eviction + cache-identical re-admission)
and admission control (queue caps, deadlines, structured update refusal)
must degrade by typed rejection, never by dropping or corrupting an
accepted request."""
import threading

import numpy as np
import pytest

from repro.api import (
    BoundedRadius,
    Engine,
    ManyToMany,
    PointToPoint,
    SingleSource,
    Tuning,
    UpdateBatch,
    UpdateRefused,
)
from repro.core import DeltaConfig, dijkstra
from repro.graphs import square_lattice, watts_strogatz
from repro.serve import RequestRejected, Server, UpdateApplied

CFG = DeltaConfig(delta=10, pred_mode="argmin")


def _edge_weights(g):
    """(u, v) -> min edge weight lookup for path-cost validation."""
    w = {}
    src, dst, ws = np.asarray(g.src), np.asarray(g.dst), np.asarray(g.w)
    for u, v, c in zip(src, dst, ws):
        key = (int(u), int(v))
        w[key] = min(w[key], int(c)) if key in w else int(c)
    return w


def _path_cost(g, path):
    ew = _edge_weights(g)
    return sum(ew[(path[i], path[i + 1])] for i in range(len(path) - 1))


def test_mixed_async_bitwise_vs_serial():
    """The acceptance pin: mixed query types over two tenant graphs,
    served through the threaded batch loop, answer bitwise what a serial
    ``plan.solve`` stream answers."""
    g1 = watts_strogatz(200, 6, 0.05, seed=7)
    g2 = square_lattice(12, weighted=True, seed=3)
    queries = {
        "ws": [SingleSource(0), PointToPoint(0, 37), BoundedRadius(5, 30),
               SingleSource(11), ManyToMany([0, 1], [9, 10, 11], tile=3)],
        "lat": [SingleSource(2), BoundedRadius(0, 40),
                PointToPoint(0, 100), SingleSource(60)],
    }
    serial = {
        name: [Engine(g, CFG).plan(fallback=True).solve(q) for q in qs]
        for (name, g), qs in zip([("ws", g1), ("lat", g2)],
                                 [queries["ws"], queries["lat"]])
    }
    with Server({"ws": g1, "lat": g2}, config=CFG, lane_width=3) as srv:
        tickets = {name: [srv.submit(q, graph=name) for q in qs]
                   for name, qs in queries.items()}
        results = {name: [t.result(timeout=300) for t in ts]
                   for name, ts in tickets.items()}
    for name, g in (("ws", g1), ("lat", g2)):
        for q, got, ref in zip(queries[name], results[name], serial[name]):
            if isinstance(q, (SingleSource, BoundedRadius)):
                np.testing.assert_array_equal(
                    np.asarray(got.dist), np.asarray(ref.dist), err_msg=repr(q))
                np.testing.assert_array_equal(
                    np.asarray(got.pred), np.asarray(ref.pred), err_msg=repr(q))
            elif isinstance(q, ManyToMany):
                np.testing.assert_array_equal(
                    np.asarray(got.matrix), np.asarray(ref.matrix))
            else:  # PointToPoint: distance is bitwise; the path is any
                # shortest one (batched lanes settle every vertex, the
                # serial early exit does not — ties may resolve apart)
                assert got.distance == ref.distance, repr(q)
                assert (got.path is None) == (ref.path is None), repr(q)
                if got.path is not None:
                    assert got.path[0] == q.source
                    assert got.path[-1] == q.target
                    assert _path_cost(g, got.path) == got.distance
    stats = srv.stats()
    assert stats["completed"] == 9
    assert stats["shed"] == {}
    assert stats["batches"]["lanes"] >= 2
    assert stats["batches"]["solo"] == 1     # the ManyToMany


def test_per_tenant_order_survives_concurrent_submitters():
    """Two threads hammer two tenants concurrently; every ticket
    resolves, and per-tenant answers match the per-tenant serial
    stream (cross-tenant interleaving is free, intra-tenant is FIFO)."""
    g1 = watts_strogatz(150, 6, 0.05, seed=1)
    g2 = watts_strogatz(150, 6, 0.05, seed=2)
    refs = {"a": dijkstra(g1, 3)[0], "b": dijkstra(g2, 4)[0]}
    out = {}

    def client(srv, name, source):
        ts = [srv.submit(SingleSource(source), graph=name) for _ in range(5)]
        out[name] = [t.result(timeout=300) for t in ts]

    with Server({"a": g1, "b": g2}, config=CFG, lane_width=4) as srv:
        threads = [threading.Thread(target=client, args=(srv, "a", 3)),
                   threading.Thread(target=client, args=(srv, "b", 4))]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
    for name in ("a", "b"):
        for r in out[name]:
            np.testing.assert_array_equal(
                np.asarray(r.dist, np.int64), refs[name])


def test_lru_evicts_coldest_and_cache_readmission_is_bitwise(tmp_path):
    """max_resident bounds the plan LRU: building a third plan evicts
    the coldest (least recently served) tenant, and the evicted tenant's
    next request re-resolves through the tuning cache into a bitwise-
    identical plan and answer."""
    graphs = {"a": watts_strogatz(200, 6, 0.05, seed=0),
              "b": watts_strogatz(220, 6, 0.05, seed=1),
              "c": watts_strogatz(240, 6, 0.05, seed=2)}
    cache = str(tmp_path / "serve_cache.json")
    srv = Server(graphs, config=DeltaConfig(pred_mode="argmin"),
                 tuning=Tuning(measure=True, cache=cache),
                 lane_width=2, max_resident=2)
    first = {}
    for name in ("a", "b", "a", "c"):   # c's build must evict b (coldest)
        t = srv.submit(SingleSource(0), graph=name)
        srv.drain()
        first.setdefault(name, t.result())
    stats = srv.stats()
    assert stats["evictions"] == 1
    assert stats["resident"] == ["a", "c"]
    assert stats["plans_built"] == 3
    record0 = srv.plan("a").record
    assert record0 is not None and record0.source == "measured"

    # re-admission: b's next request rebuilds its plan; the fingerprint-
    # keyed cache record resolves it without re-measuring, bitwise equal
    t = srv.submit(SingleSource(0), graph="b")
    srv.drain()
    again = t.result()
    stats = srv.stats()
    assert stats["plans_built"] == 4
    assert stats["evictions"] == 2           # a or c paid for b's slot
    rec = srv.plan("b").record
    assert rec is not None
    # measure=True re-marks a fingerprint hit as source="cache": the
    # rebuild resolved from the persisted record, it did not re-measure
    assert rec.source == "cache"
    np.testing.assert_array_equal(np.asarray(again.dist),
                                  np.asarray(first["b"].dist))
    np.testing.assert_array_equal(np.asarray(again.pred),
                                  np.asarray(first["b"].pred))
    assert srv.plan("b").config == Engine(
        graphs["b"], tuning=Tuning(cache=cache)).plan().config


def test_queue_cap_sheds_typed_and_never_drops_accepted():
    g = watts_strogatz(150, 6, 0.05, seed=0)
    srv = Server(g, config=CFG, lane_width=4, max_queue=4)
    tickets = [srv.submit(SingleSource(i)) for i in range(10)]
    shed = [t for t in tickets if t.done()]
    accepted = [t for t in tickets if not t.done()]
    assert len(accepted) == 4 and len(shed) == 6
    for t in shed:
        exc = t.exception(0)
        assert isinstance(exc, RequestRejected)
        assert exc.reason == "queue_full"
        assert t.trace.shed == "queue_full"
        with pytest.raises(RequestRejected, match="queue_full"):
            t.result(0)
    srv.drain()
    # every accepted request completes with a real answer
    for t, src in zip(tickets, range(10)):
        assert t.done()
        if t.exception(0) is None:
            ref, _ = dijkstra(g, src)
            np.testing.assert_array_equal(
                np.asarray(t.result().dist, np.int64), ref)
    stats = srv.stats()
    assert stats["submitted"] == 10
    assert stats["completed"] == 4
    assert stats["shed"] == {"queue_full": 6}
    assert stats["queued"] == 0


def test_deadline_sheds_at_batch_formation():
    """Deadline-based shedding under an injected clock: requests whose
    budget expired while queued are shed when the next batch forms;
    requests without a deadline (or with slack) are served."""
    g = watts_strogatz(150, 6, 0.05, seed=0)
    now = [0.0]
    srv = Server(g, config=CFG, lane_width=4, clock=lambda: now[0])
    t_fast = srv.submit(SingleSource(0), deadline=0.5)
    t_slow = srv.submit(SingleSource(1), deadline=60.0)
    t_none = srv.submit(SingleSource(2))
    now[0] = 1.0                              # t_fast's budget expires
    srv.drain()
    exc = t_fast.exception(0)
    assert isinstance(exc, RequestRejected) and exc.reason == "deadline"
    for t, src in ((t_slow, 1), (t_none, 2)):
        ref, _ = dijkstra(g, src)
        np.testing.assert_array_equal(
            np.asarray(t.result(0).dist, np.int64), ref)
    assert srv.stats()["shed"] == {"deadline": 1}


def test_interleaved_updates_bitwise_vs_serial():
    """UpdateBatch rides the same submit path as queries; interleaved
    update/query traffic on two tenants answers bitwise what the serial
    update-then-solve sequence answers on each tenant's own plan."""
    graphs = {"x": watts_strogatz(180, 6, 0.05, seed=4),
              "y": square_lattice(10, weighted=True, seed=5)}
    rng = np.random.default_rng(0)
    program = {}
    for name, g in graphs.items():
        ids = rng.choice(g.n_edges, size=12, replace=False)
        neww = np.clip(np.asarray(g.w)[ids] + 7, 1, None)
        program[name] = [SingleSource(0), UpdateBatch(ids, neww),
                         SingleSource(0), PointToPoint(0, 50)]
    serial = {}
    for name, g in graphs.items():
        plan = Engine(g, CFG).plan(fallback=True)
        outs = []
        for q in program[name]:
            if isinstance(q, UpdateBatch):
                plan.update(q.edge_ids, q.new_weights)
                outs.append(None)
            else:
                outs.append(plan.solve(q))
        serial[name] = outs
    srv = Server(graphs, config=CFG, lane_width=4)
    tickets = {}
    # interleave the two tenants' submissions
    for qx, qy in zip(program["x"], program["y"]):
        tickets.setdefault("x", []).append(srv.submit(qx, graph="x"))
        tickets.setdefault("y", []).append(srv.submit(qy, graph="y"))
    srv.drain()
    for name in graphs:
        for q, t, ref in zip(program[name], tickets[name], serial[name]):
            got = t.result(0)
            if isinstance(q, UpdateBatch):
                # lanes never establish residency, so the tier acks the
                # weight swap instead of re-solving a resident problem
                assert got == UpdateApplied(n_edges=12)
            elif isinstance(q, SingleSource):
                np.testing.assert_array_equal(
                    np.asarray(got.dist), np.asarray(ref.dist))
                np.testing.assert_array_equal(
                    np.asarray(got.pred), np.asarray(ref.pred))
            else:
                assert got.distance == ref.distance
    assert srv.stats()["batches"]["update"] == 2


def test_grid_update_refusal_sheds_request_not_loop():
    """Satellite: the grid-stencil plan's structured refusal
    (UpdateRefused, reason='grid_costs') sheds the one offending update
    ticket; the batch loop survives and keeps serving the tenant."""
    from repro.graphs import grid_map

    g, free = grid_map(8, 8, seed=0)
    grid_cfg = DeltaConfig(delta=13, strategy="pallas", interpret=True,
                           pred_mode="none")
    srv = Server(lane_width=2)
    srv.admit("map", g, config=grid_cfg, free_mask=free)
    t_upd = srv.submit(UpdateBatch([0], [5]), graph="map")
    t_query = srv.submit(SingleSource(0), graph="map")
    srv.drain()
    exc = t_upd.exception(0)
    assert isinstance(exc, RequestRejected)
    assert exc.reason == "update_refused"
    assert "grid" in str(exc)
    res = t_query.result(0)                  # the loop kept serving
    ref = Engine(g, grid_cfg, free_mask=free).plan().solve(SingleSource(0))
    np.testing.assert_array_equal(np.asarray(res.dist), np.asarray(ref.dist))
    assert srv.stats()["shed"] == {"update_refused": 1}

    # the structured refusal itself: a ValueError subclass carrying the
    # machine-readable reason tag
    plan = Engine(g, grid_cfg, free_mask=free).plan()
    with pytest.raises(UpdateRefused, match="grid") as ei:
        plan.update([0], [5])
    assert ei.value.reason == "grid_costs"
    assert isinstance(ei.value, ValueError)  # old except-clauses still work


def test_invalid_and_unknown_tenant_reject_without_poisoning():
    g = watts_strogatz(120, 6, 0.05, seed=0)
    srv = Server({"g": g}, config=CFG, lane_width=4)
    bad_src = srv.submit(SingleSource(10_000), graph="g")
    bad_tenant = srv.submit(SingleSource(0), graph="nope")
    good = srv.submit(SingleSource(0), graph="g")
    srv.drain()
    assert bad_src.exception(0).reason == "invalid"
    assert bad_tenant.exception(0).reason == "invalid"
    ref, _ = dijkstra(g, 0)
    np.testing.assert_array_equal(
        np.asarray(good.result(0).dist, np.int64), ref)


def test_close_without_drain_sheds_typed():
    g = watts_strogatz(120, 6, 0.05, seed=0)
    srv = Server(g, config=CFG)
    t = srv.submit(SingleSource(0))
    srv.close(drain=False)
    assert t.exception(0).reason == "closed"
    late = srv.submit(SingleSource(1))
    assert late.exception(0).reason == "closed"


def test_engine_tuning_parameter_and_deprecation_shims(tmp_path):
    """Satellite: the three legacy knobs (config='auto', tune=,
    tune_cache=) collapse into tuning=; the shims warn and resolve to
    the same plan the new spelling resolves to."""
    g = watts_strogatz(200, 6, 0.05, seed=0)
    new = Engine(g, tuning="auto").plan()
    with pytest.deprecated_call(match="config='auto' is deprecated"):
        old = Engine(g, "auto").plan()
    assert old.config == new.config
    assert old.record.delta == new.record.delta

    cache = str(tmp_path / "t.json")
    with pytest.deprecated_call(match="tune=/tune_cache= are deprecated"):
        old = Engine(g, tune=True, tune_cache=cache).plan(sources=(0,))
    new = Engine(g, tuning=Tuning(measure=True, cache=cache)).plan(
        sources=(0,))
    assert old.config == new.config
    assert new.record.source in ("measured", "cache")

    # tuning='measure' shorthand and validation
    from repro.api.engine import _normalize_tuning
    assert _normalize_tuning("measure") == Tuning(measure=True)
    with pytest.raises(ValueError, match="tuning must be"):
        Engine(g, tuning=3.14).plan()
    with pytest.raises(ValueError, match="unknown config string"):
        Engine(g, "fastest")
