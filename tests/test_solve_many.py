"""Batched multi-source solve (DeltaSteppingSolver.solve_many): every
lane must be bitwise identical to the corresponding per-source solve and
equal to the Dijkstra oracle, across graph families, strategies and
pred modes — the contract the serving path (serve.SSSPServer) and the
benchmarks rely on."""
import numpy as np
import pytest

from repro.compat import enable_x64
from repro.core import DeltaConfig, DeltaSteppingSolver, dijkstra
from repro.graphs import rmat, square_lattice, watts_strogatz


def _graphs():
    return {
        "smallworld": watts_strogatz(300, 6, 0.05, seed=0),
        "rmat": rmat(256, 2500, seed=2),
        "lattice": square_lattice(17, weighted=True, seed=4),
    }


GRAPHS = _graphs()
SOURCES = [0, 3, 17, 101]      # batch of >= 4 sources


class _null:
    def __enter__(self):
        return self

    def __exit__(self, *a):
        return False


def _assert_lanes_match_solve(solver, res, sources):
    for i, s in enumerate(sources):
        one = solver.solve(int(s))
        np.testing.assert_array_equal(np.asarray(res.dist[i]),
                                      np.asarray(one.dist))
        np.testing.assert_array_equal(np.asarray(res.pred[i]),
                                      np.asarray(one.pred))
        assert int(res.outer_iters[i]) == int(one.outer_iters)
        assert int(res.inner_iters[i]) == int(one.inner_iters)
        assert bool(res.overflow[i]) == bool(one.overflow)


@pytest.mark.parametrize("name", sorted(GRAPHS))
@pytest.mark.parametrize("pred_mode", ["none", "argmin", "packed"])
def test_solve_many_bitwise_equals_solve_and_dijkstra(name, pred_mode):
    g = GRAPHS[name]
    ctx = enable_x64() if pred_mode == "packed" else _null()
    with ctx:
        solver = DeltaSteppingSolver(
            g, DeltaConfig(delta=10, pred_mode=pred_mode))
        res = solver.solve_many(SOURCES)
        assert res.dist.shape == (len(SOURCES), g.n_nodes)
        _assert_lanes_match_solve(solver, res, SOURCES)
        for i, s in enumerate(SOURCES):
            dref, _ = dijkstra(g, s)
            np.testing.assert_array_equal(
                np.asarray(res.dist[i], np.int64), dref)


@pytest.mark.parametrize("strategy", ["ell", "pallas"])
def test_solve_many_strategies(strategy):
    """The batched path through the other backends (ell vmaps, pallas
    runs the batch under lax.map) must match per-source solve too."""
    g = GRAPHS["smallworld"]
    solver = DeltaSteppingSolver(
        g, DeltaConfig(delta=10, strategy=strategy, interpret=True))
    res = solver.solve_many(SOURCES)
    _assert_lanes_match_solve(solver, res, SOURCES)


def test_solve_many_overflow_is_per_lane():
    """A lane whose frontier exceeds the cap flags overflow without
    poisoning the other lanes' flags."""
    g = GRAPHS["smallworld"]
    solver = DeltaSteppingSolver(
        g, DeltaConfig(delta=10, strategy="ell", frontier_cap=40))
    res = solver.solve_many(SOURCES)
    for i, s in enumerate(SOURCES):
        assert bool(res.overflow[i]) == bool(solver.solve(int(s)).overflow)


def test_solve_many_rejects_bad_shapes():
    g = GRAPHS["smallworld"]
    solver = DeltaSteppingSolver(g, DeltaConfig(delta=10))
    with pytest.raises(ValueError):
        solver.solve_many(np.zeros((2, 2), np.int32))
