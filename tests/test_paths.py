"""Direct coverage of ``api.extract_path`` edge cases (previously only
covered indirectly through SSSPServer): trivial source==target paths,
unreachable targets, and the n-hop cycle guard that turns an off-tree
predecessor cycle (the pred_mode='argmin' zero-weight hazard) into
``None`` instead of an infinite walk.
"""
import numpy as np
import pytest

from repro.api import Engine, PointToPoint, extract_path
from repro.core import DeltaConfig
from repro.graphs.structures import COOGraph


def test_source_equals_target_is_single_vertex_path():
    pred = np.array([-1, 0, 1], np.int32)
    assert extract_path(pred, 0, 0, 3) == [0]
    # even a bogus pred entry at the source cannot matter
    assert extract_path(np.array([2, 0, 1], np.int32), 1, 1, 3) == [1]


def test_unreachable_target_returns_none():
    pred = np.array([-1, 0, -1], np.int32)   # vertex 2 off-tree
    assert extract_path(pred, 0, 2, 3) is None


def test_chain_not_reaching_source_returns_none():
    """A valid-looking chain rooted at the *wrong* vertex must not be
    reported as a path."""
    pred = np.array([-1, -1, 1], np.int32)   # 2 -> 1 -> root(1), not 0
    assert extract_path(pred, 0, 2, 3) is None


def test_cycle_guard_trips():
    """An off-tree predecessor cycle (possible under argmin recovery
    with zero-weight ties) terminates at the n-hop bound with None."""
    pred = np.array([-1, 2, 1, 2], np.int32)  # 1 <-> 2 cycle, 3 hangs off
    assert extract_path(pred, 0, 3, 4) is None
    assert extract_path(pred, 0, 1, 4) is None


def test_exact_n_hop_chain_is_returned():
    """A Hamiltonian-path tree needs exactly n-1 hops — the guard must
    not clip the longest legitimate chain."""
    n = 64
    pred = np.arange(-1, n - 1, dtype=np.int32)   # pred[i] = i - 1
    path = extract_path(pred, 0, n - 1, n)
    assert path == list(range(n))


def test_facade_p2p_uses_same_semantics():
    """The PointToPoint dispatch routes through the same extract_path:
    unreachable target -> distance INF32, path None."""
    import jax.numpy as jnp

    g = COOGraph(jnp.array([0], jnp.int32), jnp.array([1], jnp.int32),
                 jnp.array([4], jnp.int32), 3)   # vertex 2 isolated
    plan = Engine(g, DeltaConfig(delta=4, pred_mode="argmin")).plan()
    res = plan.solve(PointToPoint(0, 2))
    assert res.distance == 2**31 - 1 and res.path is None
    res01 = plan.solve(PointToPoint(0, 1))
    assert res01.distance == 4 and res01.path == [0, 1]
    same = plan.solve(PointToPoint(0, 0))
    assert same.distance == 0 and same.path == [0]


def test_rejects_oob_ids_upstream():
    """extract_path itself trusts its inputs; the façade validates ids
    before it is ever reached."""
    import jax.numpy as jnp

    g = COOGraph(jnp.array([0], jnp.int32), jnp.array([1], jnp.int32),
                 jnp.array([4], jnp.int32), 2)
    plan = Engine(g, DeltaConfig(delta=4, pred_mode="argmin")).plan()
    with pytest.raises(ValueError, match="out of range"):
        plan.solve(PointToPoint(0, 5))
