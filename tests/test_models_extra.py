"""Extra model-layer tests: EP-vs-dense MoE equivalence under a real
mesh (subprocess — device count must be set before jax init), gemma2
feature path, embedding bag, trainer fault-injection with compression,
window masking."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import LMConfig
from repro.models.embedding import embedding_bag, multi_hot_lookup
from repro.models.layers import gqa_attention
from repro.models.transformer import apply_lm, init_lm


def test_embedding_bag_modes():
    table = jnp.arange(20, dtype=jnp.float32).reshape(10, 2)
    idx = jnp.asarray([1, 2, 3, 7], jnp.int32)
    seg = jnp.asarray([0, 0, 1, 1], jnp.int32)
    s = embedding_bag(table, idx, seg, 2, mode="sum")
    np.testing.assert_allclose(np.asarray(s[0]), [2 + 4, 3 + 5])
    m = embedding_bag(table, idx, seg, 2, mode="mean")
    np.testing.assert_allclose(np.asarray(m[1]), [(6 + 14) / 2, (7 + 15) / 2])
    mx = embedding_bag(table, idx, seg, 2, mode="max")
    np.testing.assert_allclose(np.asarray(mx[1]), [14, 15])


def test_multi_hot_lookup_padding():
    table = jnp.ones((5, 3))
    hot = jnp.asarray([[0, 1, -1], [2, -1, -1]], jnp.int32)
    out = multi_hot_lookup(table, hot)
    np.testing.assert_allclose(np.asarray(out[:, 0]), [2.0, 1.0])


def test_sliding_window_masks_old_tokens():
    """With window w, a query must not attend to keys >= w behind it."""
    b, s, h, dh = 1, 8, 2, 4
    q = jnp.ones((b, s, h, dh))
    k = jax.random.normal(jax.random.key(0), (b, s, h, dh))
    v = jnp.eye(s)[None, :, None, :].repeat(h, 2).astype(jnp.float32)
    # v rows are one-hot over positions → output shows attention weights
    out_full = gqa_attention(q, k, v[..., :dh * 0 + s][..., :s].reshape(
        b, s, h, s)[..., :dh] if False else v.reshape(b, s, h, s)[..., :dh],
        q_offset=0, window=None)
    out_win = gqa_attention(q, k, v.reshape(b, s, h, s)[..., :dh],
                            q_offset=0, window=2)
    # can't reuse one-hot trick cleanly with dh != s; just check shape+finite
    assert out_win.shape == (b, s, h, dh)
    assert bool(jnp.isfinite(out_win).all())
    # direct mask check: position 7 with window 2 ignores keys 0..5 → its
    # output differs from the unwindowed one
    assert not np.allclose(np.asarray(out_full[0, 7]),
                           np.asarray(out_win[0, 7]))


def test_gemma2_softcap_bounds_logits():
    cfg = LMConfig(name="g", n_layers=2, d_model=32, n_heads=4,
                   n_kv_heads=2, head_dim=8, d_ff=64, vocab=64,
                   attn_softcap=50.0, logit_softcap=5.0,
                   tie_embeddings=True, window=4, window_pattern=2)
    params = init_lm(cfg, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 8), 0, 64)
    logits, _ = apply_lm(params, cfg, toks)
    assert float(jnp.abs(logits).max()) <= 5.0 + 1e-4
    assert "post_attn_norm" in params["layers"]


_EP_SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses, jax, jax.numpy as jnp
    from repro.compat import make_mesh
    from repro.configs.base import LMConfig, MoEConfig
    from repro.models.transformer import init_lm, apply_lm
    from repro.models.sharding import sharding_rules

    mesh = make_mesh((2,2,2), ('pod','data','model'))
    moe = MoEConfig(n_experts=8, top_k=2, d_ff_expert=32,
                    capacity_factor=4.0)
    cfg_d = LMConfig(name='m', n_layers=2, d_model=32, n_heads=4,
                     n_kv_heads=2, head_dim=8, d_ff=32, vocab=128,
                     moe=dataclasses.replace(moe, impl='dense'))
    cfg_e = dataclasses.replace(
        cfg_d, moe=dataclasses.replace(moe, impl='ep'))
    params = init_lm(cfg_d, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (4, 16), 0, 128)
    mapping = {"dp": ("pod","data"), "fsdp": ("pod","data"),
               "tp": "model", "sp": "model", "ep_cap": ("pod","data"),
               "act_seq": "model"}
    with sharding_rules(mesh, mapping):
        ld, _ = jax.jit(lambda p, t: apply_lm(p, cfg_d, t))(params, toks)
        le, _ = jax.jit(lambda p, t: apply_lm(p, cfg_e, t))(params, toks)
    assert jnp.allclose(ld, le, atol=2e-3), float(jnp.abs(ld-le).max())
    # grads must flow through the shard_map region too
    from repro.models.transformer import lm_loss
    with sharding_rules(mesh, mapping):
        g = jax.jit(jax.grad(lambda p: lm_loss(p, cfg_e, toks, toks,
                                               loss_chunk=16)[0]))(params)
    assert all(bool(jnp.isfinite(x).all()) for x in jax.tree.leaves(g))
    print("EP-OK")
""")


def test_moe_ep_matches_dense_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    out = subprocess.run([sys.executable, "-c", _EP_SUBPROC], env=env,
                         capture_output=True, text=True, timeout=600)
    assert "EP-OK" in out.stdout, out.stdout + out.stderr
