"""Analysis-layer tests: HLO collective parsing on synthetic HLO text and
roofline term arithmetic on a real compiled program."""
import jax
import jax.numpy as jnp

from repro.analysis import collective_stats, estimate_model_flops
from repro.analysis.roofline import V5E, analyze
from repro.configs import get_config, get_shape

_FAKE_HLO = """
HloModule jit_step
  %x = f32[128,256]{1,0} parameter(0)
  %ar = f32[128,256]{1,0} all-reduce(f32[128,256]{1,0} %x), replica_groups={}
  %ag = bf16[64,512]{1,0} all-gather(bf16[4,512]{1,0} %y), dimensions={0}
  %rs = f32[32,16]{1,0} reduce-scatter(f32[512,16]{1,0} %z), dimensions={0}
  %aa = s32[1024]{0} all-to-all(s32[1024]{0} %w)
  %cp = f32[8]{0} collective-permute(f32[8]{0} %v)
  %dot = f32[128,256]{1,0} dot(f32[128,256]{1,0} %x, f32[256,256]{1,0} %m)
"""


def test_collective_stats_parses_all_types():
    st = collective_stats(_FAKE_HLO)
    by = st["by_op"]
    assert by["all-reduce"]["count"] == 1
    assert by["all-reduce"]["operand_bytes"] == 128 * 256 * 4
    assert by["all-reduce"]["wire_bytes"] == 2 * 128 * 256 * 4
    # all-gather wire uses the RESULT size (gathered tensor)
    assert by["all-gather"]["wire_bytes"] == 64 * 512 * 2
    assert by["reduce-scatter"]["operand_bytes"] == 512 * 16 * 4
    assert by["all-to-all"]["count"] == 1
    assert by["collective-permute"]["count"] == 1
    assert st["total"]["count"] == 5
    # the dot is not a collective
    assert st["total"]["operand_bytes"] < 10 * 128 * 256 * 4


def test_roofline_on_real_compiled_program():
    @jax.jit
    def f(a, b):
        return a @ b

    a = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    compiled = f.lower(a, a).compile()
    rep = analyze(compiled, arch="toy", shape="matmul", mesh_name="1",
                  n_devices=1, model_flops=2 * 256**3)
    assert rep.flops_per_dev > 0
    assert rep.compute_s == rep.flops_per_dev / V5E["peak_flops"]
    assert rep.dominant in ("compute", "memory", "collective")
    assert 0 < rep.useful_ratio <= 1.5


def test_model_flops_estimates_sane():
    kimi = get_config("kimi-k2-1t-a32b")
    tr = estimate_model_flops("lm", kimi, get_shape("kimi-k2-1t-a32b",
                                                    "train_4k"))
    # 6 * 32.1e9 active * 1.05e6 tokens ~ 2.0e17
    assert 1e17 < tr < 4e17
    dec = estimate_model_flops("lm", kimi, get_shape("kimi-k2-1t-a32b",
                                                     "decode_32k"))
    assert dec < tr / 1000
    dl = estimate_model_flops(
        "recsys", get_config("dlrm-mlperf"),
        get_shape("dlrm-mlperf", "train_batch"))
    assert dl > 1e11
