"""Property-based tests (hypothesis) for the Δ-stepping engine.

Invariants checked on arbitrary random digraphs:
  1. distances equal two independent oracles (heap Dijkstra, Bellman-Ford);
  2. triangle inequality along every edge: dist[v] <= dist[u] + w(u,v)
     whenever dist[u] is finite;
  3. every finite distance is witnessed by a valid predecessor tree;
  4. the result is invariant to Δ and to the relaxation strategy.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # dev-only dep, see requirements-dev.txt
from hypothesis import given, settings, strategies as st

from repro.core import (
    DeltaConfig,
    bellman_ford,
    delta_stepping,
    dijkstra,
    validate_pred_tree,
)
from repro.graphs import random_graph
from repro.graphs.structures import INF32

graph_params = st.tuples(
    st.integers(min_value=2, max_value=60),      # n
    st.integers(min_value=0, max_value=240),     # m
    st.integers(min_value=0, max_value=2**31 - 1),  # seed
    st.integers(min_value=1, max_value=40),      # delta
    st.integers(min_value=1, max_value=25),      # max weight
)


@settings(max_examples=60, deadline=None)
@given(graph_params)
def test_matches_both_oracles(params):
    n, m, seed, delta, w_hi = params
    g = random_graph(n, m, seed=seed, w_lo=1, w_hi=w_hi)
    src = seed % n
    res = delta_stepping(g, src, DeltaConfig(delta=delta))
    d = np.asarray(res.dist, np.int64)
    np.testing.assert_array_equal(d, dijkstra(g, src)[0])
    np.testing.assert_array_equal(d, bellman_ford(g, src))


@settings(max_examples=40, deadline=None)
@given(graph_params)
def test_triangle_inequality_and_pred(params):
    n, m, seed, delta, w_hi = params
    g = random_graph(n, m, seed=seed, w_lo=1, w_hi=w_hi)
    src = (seed // 7) % n
    res = delta_stepping(g, src, DeltaConfig(delta=delta))
    d = np.asarray(res.dist, np.int64)
    es, ed = np.asarray(g.src), np.asarray(g.dst)
    ew = np.asarray(g.w, np.int64)
    fin = d[es] < int(INF32)
    assert (d[ed][fin] <= d[es][fin] + ew[fin]).all()
    assert validate_pred_tree(g, src, d, np.asarray(res.pred))


@settings(max_examples=25, deadline=None)
@given(graph_params)
def test_strategy_equivalence(params):
    n, m, seed, delta, w_hi = params
    g = random_graph(n, m, seed=seed, w_lo=1, w_hi=w_hi)
    src = seed % n
    d_edge = np.asarray(
        delta_stepping(g, src, DeltaConfig(delta=delta, strategy="edge")).dist)
    d_ell = np.asarray(
        delta_stepping(g, src, DeltaConfig(delta=delta, strategy="ell")).dist)
    np.testing.assert_array_equal(d_edge, d_ell)
