"""Property-based tests for the Δ-stepping engine.

Invariants checked on arbitrary random digraphs:
  1. distances equal two independent oracles (heap Dijkstra, Bellman-Ford);
  2. triangle inequality along every edge: dist[v] <= dist[u] + w(u,v)
     whenever dist[u] is finite;
  3. every finite distance is witnessed by a valid predecessor tree;
  4. the result is invariant to Δ and to the relaxation strategy.

Hypothesis drives the parameter draws when installed (the CI tier-1
install includes it via requirements-dev.txt, so these never skip in
CI); without it the same properties run over a deterministic sweep of
the identical parameter space (shared driver:
tests/_property_driver.py, which replaced the old ``importorskip``).
"""
from functools import partial

import numpy as np

from _property_driver import drive
from repro.core import (
    DeltaConfig,
    bellman_ford,
    delta_stepping,
    dijkstra,
    validate_pred_tree,
    walk_pred_tree,
)
from repro.graphs import random_graph
from repro.graphs.structures import INF32

# (n, m, seed, delta, max weight)
_RANGES = ((2, 60), (0, 240), (0, 2**31 - 1), (1, 40), (1, 25))

drive_params = partial(
    drive,
    strategy=lambda st: st.tuples(
        *(st.integers(min_value=lo, max_value=hi) for lo, hi in _RANGES)),
    fallback_draw=lambda rng: tuple(
        int(rng.integers(lo, hi + 1)) for lo, hi in _RANGES))


@drive_params(max_examples=60, fallback_examples=12)
def test_matches_both_oracles(params):
    n, m, seed, delta, w_hi = params
    g = random_graph(n, m, seed=seed, w_lo=1, w_hi=w_hi)
    src = seed % n
    res = delta_stepping(g, src, DeltaConfig(delta=delta))
    d = np.asarray(res.dist, np.int64)
    np.testing.assert_array_equal(d, dijkstra(g, src)[0])
    np.testing.assert_array_equal(d, bellman_ford(g, src))


@drive_params(max_examples=40, fallback_examples=8)
def test_triangle_inequality_and_pred(params):
    n, m, seed, delta, w_hi = params
    g = random_graph(n, m, seed=seed, w_lo=1, w_hi=w_hi)
    src = (seed // 7) % n
    res = delta_stepping(g, src, DeltaConfig(delta=delta))
    d = np.asarray(res.dist, np.int64)
    es, ed = np.asarray(g.src), np.asarray(g.dst)
    ew = np.asarray(g.w, np.int64)
    fin = d[es] < int(INF32)
    assert (d[ed][fin] <= d[es][fin] + ew[fin]).all()
    assert validate_pred_tree(g, src, d, np.asarray(res.pred))
    # stronger: walk every pred chain to the source — acyclic, and the
    # accumulated weights reproduce dist exactly (the C3/C4 torn-write
    # class of bugs leaves edges locally consistent but breaks this)
    assert walk_pred_tree(g, src, d, np.asarray(res.pred))


@drive_params(max_examples=25, fallback_examples=6)
def test_strategy_equivalence(params):
    n, m, seed, delta, w_hi = params
    g = random_graph(n, m, seed=seed, w_lo=1, w_hi=w_hi)
    src = seed % n
    d_edge = np.asarray(
        delta_stepping(g, src, DeltaConfig(delta=delta, strategy="edge")).dist)
    d_ell = np.asarray(
        delta_stepping(g, src, DeltaConfig(delta=delta, strategy="ell")).dist)
    np.testing.assert_array_equal(d_edge, d_ell)
