"""Auto-tuning subsystem (repro.tune, DESIGN.md §7): estimator
finiteness on degenerate graphs, JSON cache round-trips, successive-
halving search behaviour (deterministic injected costs), and the
guarantee that tuning never changes answers — a tuned solve is bitwise
identical to ``delta_stepping`` with the same explicit config."""
import dataclasses
import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    DeltaConfig,
    DeltaSteppingSolver,
    EdgeBackend,
    delta_stepping,
    dijkstra,
    make_backend,
)
from repro.graphs import random_graph, watts_strogatz
from repro.graphs.structures import COOGraph
from repro.tune import (
    TuningCache,
    TuningRecord,
    candidate_configs,
    estimate_delta,
    fingerprint,
    graph_stats,
    resolve_config,
    tune,
)


def _empty_graph(n):
    z = jnp.zeros((0,), jnp.int32)
    return COOGraph(z, z, z, n)


# ---------------------------------------------------------------------------
# estimator
# ---------------------------------------------------------------------------

DEGENERATE = {
    "single_vertex": _empty_graph(1),
    "no_edges": _empty_graph(64),
    "self_edge_free_pair": COOGraph(
        jnp.array([0], jnp.int32), jnp.array([1], jnp.int32),
        jnp.array([0], jnp.int32), 2),
    "uniform_weights": watts_strogatz(64, 4, 0.0, seed=0, w_lo=7, w_hi=7),
    "disconnected": random_graph(150, 60, seed=5),
}


@pytest.mark.parametrize("name", sorted(DEGENERATE))
def test_estimator_finite_on_degenerate_graphs(name):
    g = DEGENERATE[name]
    stats = graph_stats(g)
    delta = estimate_delta(stats)
    assert isinstance(delta, int)
    assert np.isfinite(delta)
    assert delta >= 1
    # a DeltaConfig must accept it and the solve must terminate
    res = delta_stepping(g, 0, DeltaConfig(delta=delta, pred_mode="none"))
    dref, _ = dijkstra(g, 0)
    np.testing.assert_array_equal(np.asarray(res.dist, np.int64), dref)


def test_estimator_matches_paper_smallworld_pick():
    """Calibration pin: on the paper's small-world family (U(1,20)
    weights, k=12) the c·w̄/d̄ rule lands on the paper's Δ=10."""
    g = watts_strogatz(1_000, 12, 1e-2, seed=0)
    assert estimate_delta(graph_stats(g)) == 10


def test_fingerprint_includes_mesh_width():
    """DESIGN.md §9: the mesh-sharded backends are in the tuner's search
    space, so the cache key must carry the device count — a sharded
    winner measured on an 8-device mesh must not be served to a 1-device
    host with the same graph."""
    import jax
    fp = fingerprint(graph_stats(watts_strogatz(200, 6, 0.05, seed=0)))
    assert fp.endswith(f":dev={jax.device_count()}")


def test_tuner_can_pick_sharded_winner():
    """A planted sharded_edge winner comes back with the measured mesh
    width pinned in the record, survives the JSON round trip, and turns
    into an exact engine config."""
    import jax
    g = watts_strogatz(200, 6, 0.05, seed=0)

    def fake_measure(delta, strat, cap, policy, reps):
        return 1.0e-6 if strat == "sharded_edge" else 1.0e-3

    rec = tune(g, deltas=(5, 10),
               strategies=("edge", "ell", "sharded_edge"),
               measure_fn=fake_measure)
    assert rec.strategy == "sharded_edge"
    assert rec.n_shards == jax.device_count()
    assert TuningRecord.from_json(rec.to_json()) == rec
    cfg = rec.to_config(DeltaConfig())
    assert cfg.n_shards == rec.n_shards
    res = DeltaSteppingSolver(g, cfg).solve(0)
    dref, _ = dijkstra(g, 0)
    np.testing.assert_array_equal(np.asarray(res.dist, np.int64), dref)


def test_fingerprint_distinguishes_structure():
    a = graph_stats(watts_strogatz(300, 6, 0.05, seed=0))
    b = graph_stats(watts_strogatz(300, 8, 0.05, seed=0))
    same = graph_stats(watts_strogatz(300, 6, 0.05, seed=0))
    assert fingerprint(a) != fingerprint(b)
    assert fingerprint(a) == fingerprint(same)


def test_fingerprint_sees_diameter_not_just_degrees():
    """Regression: p=1e-4 and p=1e-2 Watts-Strogatz graphs have the
    identical degree histogram but order-of-magnitude different
    diameters — and different optimal Δ (paper Fig. 1). The cache key
    must separate them or tuned records cross-contaminate."""
    long_d = graph_stats(watts_strogatz(2_000, 12, 1e-4, seed=0))
    short_d = graph_stats(watts_strogatz(2_000, 12, 1e-2, seed=0))
    assert long_d.degree_hist == short_d.degree_hist
    assert long_d.ecc0 > short_d.ecc0
    assert fingerprint(long_d) != fingerprint(short_d)


# ---------------------------------------------------------------------------
# cache
# ---------------------------------------------------------------------------

def _record(fp="v1:n=10:m=20:deg=1:w=1-5"):
    return TuningRecord(
        fingerprint=fp, delta=7, strategy="ell", frontier_cap=128,
        source="measured", us_per_solve=123.4,
        trials=((7, "ell", 128, "delta", 123.4),
                (3, "edge", -1, "rho", 456.7)))


def test_cache_round_trips_through_json(tmp_path):
    path = str(tmp_path / "tune_cache.json")
    cache = TuningCache(path)
    rec = _record()
    cache.put(rec)
    cache.save()
    # a fresh cache object reads the identical record back
    reloaded = TuningCache(path)
    assert len(reloaded) == 1
    assert reloaded.get(rec.fingerprint) == rec
    # and the file itself is valid, versioned JSON
    with open(path) as f:
        payload = json.load(f)
    assert payload["version"] == 1
    assert rec.fingerprint in payload["records"]


def test_cache_in_memory_and_stale_schema(tmp_path):
    mem = TuningCache(None)
    mem.put(_record())
    mem.save()                      # no-op, must not raise
    assert _record().fingerprint in mem
    stale = tmp_path / "stale.json"
    stale.write_text('{"version": 999, "records": {"x": {}}}')
    assert len(TuningCache(str(stale))) == 0


def test_cache_survives_corrupt_file(tmp_path):
    """A truncated/garbage cache file must start fresh, not crash every
    --tune-cache run until someone deletes it by hand."""
    for garbage in ('{"version": 1, "records"', "not json at all",
                    '{"version": 1, "records": {"x": {"delta": "?"}}}'):
        path = tmp_path / "broken.json"
        path.write_text(garbage)
        cache = TuningCache(str(path))
        assert len(cache) == 0
        cache.put(_record())
        cache.save()                # and it is writable again
        assert len(TuningCache(str(path))) == 1
        path.unlink()


def test_record_json_round_trip():
    rec = _record()
    assert TuningRecord.from_json(rec.to_json()) == rec
    none_cap = dataclasses.replace(rec, frontier_cap=None, trials=())
    assert TuningRecord.from_json(none_cap.to_json()) == none_cap
    # the algorithm axis survives the trip
    rho_rec = dataclasses.replace(rec, policy="rho")
    assert TuningRecord.from_json(rho_rec.to_json()).policy == "rho"


def test_record_json_tolerates_pre_policy_records():
    """Cache files written before the algorithm axis existed carry no
    ``policy`` key and 4-element trial rows. They must deserialize as
    Δ-stepping records — the only policy they could have measured —
    not crash every cache load."""
    d = _record().to_json()
    del d["policy"]
    d["trials"] = [[7, "ell", 128, 123.4]]      # legacy row shape
    rec = TuningRecord.from_json(d)
    assert rec.policy == "delta"
    assert rec.trials == ((7, "ell", 128, "delta", 123.4),)
    assert rec.to_config(DeltaConfig()).policy == "delta"


def test_stale_fingerprint_version_triggers_fresh_tune(tmp_path):
    """A record keyed under an older fingerprint version (pre-policy
    search space) must silently miss — resolve gives a fresh answer, it
    does not crash and it does not serve the stale winner."""
    g = watts_strogatz(200, 6, 0.05, seed=0)
    fp = fingerprint(graph_stats(g))
    assert fp.startswith("v5:")
    stale_fp = "v4:" + fp.split(":", 1)[1]
    path = str(tmp_path / "c.json")
    cache = TuningCache(path)
    cache.put(TuningRecord(
        fingerprint=stale_fp, delta=999, strategy="ell",
        frontier_cap=None, source="measured"))
    cache.save()
    cfg = resolve_config(g, cache_path=path)     # no measurement
    assert cfg.delta != 999                      # stale winner not served
    assert cfg.delta == estimate_delta(graph_stats(g))

    calls = []

    def fake_measure(delta, strat, cap, policy, reps):
        calls.append(delta)
        return 1.0e-3 * delta

    rec = tune(g, deltas=(2, 5), strategies=("edge",),
               cache=TuningCache(path), measure_fn=fake_measure)
    assert calls                                 # fresh search ran
    assert rec.source == "measured"
    assert rec.fingerprint == fp


def test_tuner_can_pick_policy_winner(tmp_path):
    """Planted winner on the algorithm axis: the halving loop returns a
    non-delta policy, the record round-trips through the cache file, and
    to_config carries the policy into the engine."""
    g = watts_strogatz(200, 6, 0.05, seed=0)

    def fake_measure(delta, strat, cap, policy, reps):
        if (strat, policy) == ("ell", "rho"):
            return 1.0e-6                       # planted winner
        return 1.0e-3

    path = str(tmp_path / "c.json")
    rec = tune(g, deltas=(5, 10), strategies=("edge", "ell"),
               cache=TuningCache(path), measure_fn=fake_measure)
    assert (rec.strategy, rec.policy) == ("ell", "rho")
    assert any(t[3] == "rho" for t in rec.trials)
    reloaded = TuningCache(path).get(rec.fingerprint)
    assert reloaded.policy == "rho"
    cfg = reloaded.to_config(DeltaConfig())
    assert cfg.policy == "rho"
    res = DeltaSteppingSolver(g, cfg).solve(0)
    dref, _ = dijkstra(g, 0)
    np.testing.assert_array_equal(np.asarray(res.dist, np.int64), dref)


# ---------------------------------------------------------------------------
# search
# ---------------------------------------------------------------------------

def test_candidate_grid_shape():
    stats = graph_stats(watts_strogatz(200, 6, 0.05, seed=0))
    cands = candidate_configs(stats)
    deltas = {d for d, _, _, _ in cands}
    assert len(deltas) >= 3                      # geometric grid around est
    assert estimate_delta(stats) in deltas
    assert all(d >= 1 for d in deltas)
    # edge ignores packing; ell gets one candidate per cap fraction
    assert sum(1 for _, s, c, _ in cands
               if s == "edge" and c is not None) == 0
    assert any(s == "ell" and c is not None for _, s, c, _ in cands)
    # the algorithm axis rides along: every policy appears, and the
    # non-bucketing policies enter at a single central Δ (no Δ sweep)
    assert {p for _, _, _, p in cands} == {"delta", "rho", "radius"}
    assert len({d for d, _, _, p in cands if p == "rho"}) == 1


def test_successive_halving_picks_known_winner():
    g = watts_strogatz(200, 6, 0.05, seed=0)
    calls = []

    def fake_measure(delta, strat, cap, policy, reps):
        calls.append((delta, strat, cap, policy, reps))
        if (delta, strat, policy) == (5, "ell", "delta"):
            return 1.0e-6                       # planted winner
        return 1.0e-3 * delta

    rec = tune(g, deltas=(2, 5, 11, 23), measure_fn=fake_measure)
    assert (rec.delta, rec.strategy) == (5, "ell")
    assert rec.policy == "delta"
    assert rec.source == "measured"
    assert rec.us_per_solve == pytest.approx(1.0, rel=0.5)
    # halving: later rounds re-measure fewer candidates at higher reps
    assert max(reps for *_, reps in calls) > 1
    assert rec.trials                            # evidence trail kept


def test_tuner_rejects_overflowing_candidates():
    """A frontier cap the graph overflows must never be returned."""
    g = watts_strogatz(200, 6, 0.05, seed=0)

    def fake_measure(delta, strat, cap, policy, reps):
        if cap is not None:
            return float("inf") if cap < 200 else 2.0e-3
        return 1.0e-3 * delta

    rec = tune(g, deltas=(2, 5), measure_fn=fake_measure)
    assert rec.frontier_cap is None or rec.frontier_cap >= 200


def test_tune_cache_hit_skips_search(tmp_path):
    g = watts_strogatz(200, 6, 0.05, seed=0)
    cache = TuningCache(str(tmp_path / "c.json"))
    calls = []

    def fake_measure(delta, strat, cap, policy, reps):
        calls.append(delta)
        return 1.0e-3 * delta

    first = tune(g, deltas=(2, 5), strategies=("edge",),
                 cache=cache, measure_fn=fake_measure)
    assert first.source == "measured" and calls
    calls.clear()
    again = tune(g, deltas=(2, 5), strategies=("edge",),
                 cache=cache, measure_fn=fake_measure)
    assert again.source == "cache"
    assert (again.delta, again.strategy) == (first.delta, first.strategy)
    assert not calls                             # no re-measurement
    # the persisted file feeds resolve_config without measuring
    cfg = resolve_config(g, cache_path=str(tmp_path / "c.json"))
    assert cfg.delta == first.delta


# ---------------------------------------------------------------------------
# engine integration: auto config never changes answers
# ---------------------------------------------------------------------------

def test_auto_config_bitwise_equals_explicit():
    g = watts_strogatz(300, 6, 0.05, seed=0)
    auto = delta_stepping(g, 0, "auto")
    explicit = delta_stepping(
        g, 0, DeltaConfig(delta=estimate_delta(graph_stats(g))))
    for a, b in zip(auto, explicit):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_auto_config_exact_on_all_three_families():
    """Acceptance pin: config='auto' distances are identical to the best
    hand-picked config (i.e. to the Dijkstra oracle) on the paper's
    small-world, scale-free and game-map families."""
    from repro.graphs import grid_map, rmat
    gm, free = grid_map(25, 31, 0.15, seed=3)
    graphs = {
        "smallworld": (watts_strogatz(300, 6, 0.05, seed=0), 0),
        "rmat": (rmat(256, 2500, seed=2), 0),
        "gamemap": (gm, int(np.flatnonzero(np.asarray(free).ravel())[0])),
    }
    for name, (g, src) in graphs.items():
        res = delta_stepping(g, src, "auto")
        dref, _ = dijkstra(g, src)
        np.testing.assert_array_equal(
            np.asarray(res.dist, np.int64), dref, name)


def test_measured_tuned_solve_bitwise_equals_explicit():
    g = watts_strogatz(200, 6, 0.05, seed=0)
    rec = tune(g, deltas=(5, 10), strategies=("edge",))   # real, tiny search
    cfg = rec.to_config(DeltaConfig())
    tuned = DeltaSteppingSolver(g, cfg).solve(0)
    explicit = delta_stepping(
        g, 0, DeltaConfig(delta=rec.delta, strategy=rec.strategy,
                          frontier_cap=rec.frontier_cap))
    for a, b in zip(tuned, explicit):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    dref, _ = dijkstra(g, 0)
    np.testing.assert_array_equal(np.asarray(tuned.dist, np.int64), dref)


def test_make_backend_accepts_auto():
    g = watts_strogatz(300, 6, 0.05, seed=0)
    backend = make_backend(g, "auto")
    assert isinstance(backend, EdgeBackend)
    assert backend.delta == estimate_delta(graph_stats(g))
    with pytest.raises(ValueError):
        make_backend(g, "bogus")
    with pytest.raises(ValueError):
        DeltaSteppingSolver(g, "bogus")


def test_resolve_config_revalidates_cached_cap(tmp_path):
    """A cached frontier_cap from a same-fingerprint graph must be
    re-validated before the engine gets it: overflow would mean wrong
    distances, and only the tuning layer knows the cap is tuned."""
    g = watts_strogatz(200, 6, 0.05, seed=0)
    path = str(tmp_path / "c.json")
    cache = TuningCache(path)
    cache.put(TuningRecord(
        fingerprint=fingerprint(graph_stats(g)), delta=100, strategy="ell",
        frontier_cap=2, source="measured"))      # cap the graph overflows
    cache.save()
    cfg = resolve_config(g, cache_path=path)
    assert cfg.frontier_cap is None              # dropped, not served
    res = DeltaSteppingSolver(g, cfg).solve(0)
    assert not bool(res.overflow)
    dref, _ = dijkstra(g, 0)
    np.testing.assert_array_equal(np.asarray(res.dist, np.int64), dref)
    # the core auto path cannot know its sources: cap dropped outright,
    # so solve() from ANY source stays exact
    solver = DeltaSteppingSolver(g, "auto", tune_cache=path)
    assert solver.config.frontier_cap is None
    res7 = solver.solve(7)
    assert not bool(res7.overflow)
    dref7, _ = dijkstra(g, 7)
    np.testing.assert_array_equal(np.asarray(res7.dist, np.int64), dref7)


def test_heuristic_path_skips_ecc_probe():
    g = watts_strogatz(200, 6, 0.05, seed=0)
    stats = graph_stats(g, probe_ecc=False)
    assert stats.ecc0 == -1
    assert estimate_delta(stats) == estimate_delta(graph_stats(g))
    with pytest.raises(ValueError):
        fingerprint(stats)


def test_server_overflow_falls_back_to_full_width():
    """A tuned frontier_cap validated only on the tuner's probe sources
    must not produce wrong answers for other queries: the server
    re-solves overflowing batches with an uncapped config."""
    from repro.serve import SSSPQuery, SSSPServer
    g = watts_strogatz(300, 6, 0.05, seed=0)
    cfg = DeltaConfig(delta=100, strategy="ell", frontier_cap=2,
                      pred_mode="none")
    srv = SSSPServer(g, cfg, batch_size=2)
    srv.submit(SSSPQuery(qid=0, source=0))
    (done,) = srv.run_to_completion()
    dref, _ = dijkstra(g, 0)
    np.testing.assert_array_equal(done.dist, dref)


def test_server_tunes_at_graph_load(tmp_path):
    from repro.serve import SSSPQuery, SSSPServer
    g = watts_strogatz(300, 6, 0.05, seed=0)
    srv = SSSPServer(g, "auto", batch_size=2,
                     tune_cache=str(tmp_path / "srv.json"))
    assert isinstance(srv.config, DeltaConfig)
    assert srv.config.delta == estimate_delta(graph_stats(g))
    srv.submit(SSSPQuery(qid=0, source=0))
    (done,) = srv.run_to_completion()
    dref, _ = dijkstra(g, 0)
    np.testing.assert_array_equal(done.dist, dref)
