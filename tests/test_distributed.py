"""Distributed Δ-stepping: correctness on a 1-device mesh in-process and
on a multi-device (forced host platform) mesh in a subprocess — the
device count must be set before JAX initializes, hence the subprocess."""
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
from repro.core import dijkstra
from repro.core.distributed import DistDeltaConfig, build_distributed_solver
from repro.graphs import partition_edges, watts_strogatz


def test_single_device_mesh_matches_oracle():
    g = watts_strogatz(200, 6, 0.1, seed=11)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    part = partition_edges(g, 1)
    solve = build_distributed_solver(part, mesh, DistDeltaConfig(delta=10))
    dist, outer, inner = solve(np.array([0], np.int32))
    dref, _ = dijkstra(g, 0)
    np.testing.assert_array_equal(np.asarray(dist[0], np.int64), dref)
    assert int(outer) >= 1 and int(inner) >= 1


_SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, numpy as np
    from repro.core import dijkstra
    from repro.core.distributed import DistDeltaConfig, build_distributed_solver
    from repro.graphs import partition_edges, watts_strogatz, rmat

    for gname, g in [("ws", watts_strogatz(300, 6, 0.1, seed=5)),
                     ("rmat", rmat(256, 2000, seed=9))]:
        part = partition_edges(g, 4)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        srcs = np.array([0, 3, 17, 29], np.int32)
        refs = np.stack([dijkstra(g, int(s))[0] for s in srcs])
        for combine in ["allreduce", "reduce_scatter"]:
            for ls in [1, 3]:
                cfg = DistDeltaConfig(delta=10, combine=combine, local_steps=ls)
                solve = build_distributed_solver(part, mesh, cfg)
                dist, _, _ = solve(srcs)
                assert np.array_equal(np.asarray(dist, np.int64), refs), (
                    gname, combine, ls)
    print("DIST-OK")
""")


def test_multi_device_mesh_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    out = subprocess.run([sys.executable, "-c", _SUBPROC], env=env,
                         capture_output=True, text=True, timeout=600)
    assert "DIST-OK" in out.stdout, out.stdout + out.stderr
