"""Trainer fault-tolerance: bit-identical restart after an injected
mid-run failure, deterministic data resume, straggler watchdog."""
import tempfile

import jax
import numpy as np

from repro.configs.base import LMConfig
from repro.data import SyntheticTokens
from repro.models.transformer import init_lm, lm_loss
from repro.optim import adamw, warmup_cosine
from repro.train import (
    SimulatedFailure,
    StepWatchdog,
    Trainer,
    TrainerConfig,
    run_with_restarts,
)

_CFG = LMConfig(name="t", n_layers=2, d_model=32, n_heads=2, n_kv_heads=1,
                d_ff=64, vocab=64)
_DATA = SyntheticTokens(vocab=64, batch=4, seq_len=16)


def _loss(p, b):
    return lm_loss(p, _CFG, b["tokens"], b["labels"], loss_chunk=16)


def _opt():
    return adamw(warmup_cosine(3e-3, 5, 30))


def test_restart_after_failure_is_bit_identical():
    with tempfile.TemporaryDirectory() as d1, \
            tempfile.TemporaryDirectory() as d2:
        params = init_lm(_CFG, jax.random.key(0))
        ref = Trainer(_loss, _opt(), params, _DATA,
                      TrainerConfig(total_steps=24, ckpt_dir=d1,
                                    ckpt_interval=8, log_interval=100))
        p_ref = ref.run()

        fails = [13]

        def mk():
            params = init_lm(_CFG, jax.random.key(0))
            f = fails.pop(0) if fails else None
            return Trainer(_loss, _opt(), params, _DATA,
                           TrainerConfig(total_steps=24, ckpt_dir=d2,
                                         ckpt_interval=8, log_interval=100),
                           failure_at_step=f)

        p_restart, trainer = run_with_restarts(mk)
        assert trainer.start_step == 8      # resumed from the checkpoint
        for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p_restart)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_unrecoverable_failure_raises():
    fails = [3, 3, 3, 3]

    def mk():
        params = init_lm(_CFG, jax.random.key(0))
        return Trainer(_loss, _opt(), params, _DATA,
                       TrainerConfig(total_steps=10, ckpt_dir=None,
                                     log_interval=100),
                       failure_at_step=fails.pop(0))

    try:
        run_with_restarts(mk, max_restarts=2)
        raise AssertionError("should have exhausted restarts")
    except (RuntimeError, SimulatedFailure):
        pass


def test_straggler_watchdog_flags_slow_steps():
    wd = StepWatchdog(factor=3.0, warmup=2)
    for step in range(1, 8):
        assert not wd.observe(step, 0.1)
    assert wd.observe(8, 1.0)               # 10x the EMA → straggler
    assert wd.events and wd.events[0][0] == 8
    # EMA not poisoned by the straggler observation
    assert abs(wd.ema - 0.1) < 1e-6
