"""Determinism guarantees of the engine (DESIGN.md §9, §11).

The paper's CAS loop is deterministic only up to ties (which thread wins
a same-cost race is timing-dependent). Our scatter-min / all-reduce-min
formulation is stronger: min over tent words is associative and
commutative, so in ``packed`` mode the int64 (cost, pred) words —
distances *and* predecessors — are bitwise identical across repeated
runs, across backends, and across mesh shapes (1-device vs 8-device
host meshes, checked in a subprocess because the forced device count
must be set before JAX initializes).

Tie-breaking has two regimes (DESIGN.md §11). On the canonical-ties
graph class (every weight >= 1) the packed C4 filter compares whole
(cost, pred) words, so the converged word is the schedule-independent
(dist, smallest-id tight parent) — identical to what ``argmin`` mode
recovers post hoc, pinned on a crafted two-path tie graph below. That
trajectory independence is what makes warm-started dynamic re-solves
(repro.dynamic) bitwise reproducible. Zero-weight graphs keep the
historical first-settled tie-break (the canonical rule could close a
predecessor cycle inside a zero-weight tie group), pinned on a
zero-weight twin of the tie graph.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.compat import enable_x64
from repro.core import (
    DeltaConfig,
    DeltaSteppingSolver,
    dijkstra,
    validate_pred_tree,
    walk_pred_tree,
)
from repro.core.backends import graph_is_canonical
from repro.graphs import watts_strogatz
from repro.graphs.structures import COOGraph


def _solve_packed(g, src, strategy, delta=10):
    cfg = DeltaConfig(delta=delta, strategy=strategy, pred_mode="packed")
    res = DeltaSteppingSolver(g, cfg).solve(src)
    return np.asarray(res.dist), np.asarray(res.pred)


@pytest.mark.parametrize("strategy", ["edge", "ell", "sharded_edge",
                                      "sharded_ell"])
def test_packed_solve_bitwise_repeatable(strategy):
    """Same instance, two fresh solvers: bitwise-equal (dist, pred)."""
    g = watts_strogatz(300, 6, 0.05, seed=1)
    with enable_x64():
        d1, p1 = _solve_packed(g, 0, strategy)
        d2, p2 = _solve_packed(g, 0, strategy)
    np.testing.assert_array_equal(d1, d2)
    np.testing.assert_array_equal(p1, p2)


def test_packed_bitwise_across_backends_and_shard_counts():
    """Every backend — and every shard count available in-process —
    yields the identical packed (dist, pred)."""
    g = watts_strogatz(300, 6, 0.05, seed=1)
    with enable_x64():
        base_d, base_p = _solve_packed(g, 0, "edge")
        for strategy in ("ell", "sharded_edge", "sharded_ell"):
            d, p = _solve_packed(g, 0, strategy)
            np.testing.assert_array_equal(d, base_d, err_msg=strategy)
            np.testing.assert_array_equal(p, base_p, err_msg=strategy)


_SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    from repro.compat import enable_x64
    from repro.core import DeltaConfig, DeltaSteppingSolver
    from repro.graphs import watts_strogatz

    g = watts_strogatz(300, 6, 0.05, seed=1)
    with enable_x64():
        results = {}
        for strategy in ("edge", "sharded_edge", "sharded_ell"):
            for shards in ((None,) if strategy == "edge" else (1, 8)):
                cfg = DeltaConfig(delta=10, strategy=strategy,
                                  pred_mode="packed", n_shards=shards)
                r = DeltaSteppingSolver(g, cfg).solve(0)
                results[(strategy, shards)] = (np.asarray(r.dist),
                                               np.asarray(r.pred))
    base_d, base_p = results[("edge", None)]
    for key, (d, p) in results.items():
        assert np.array_equal(d, base_d), ("dist", key)
        assert np.array_equal(p, base_p), ("pred", key)
    print("DET-OK")
""")


def test_packed_bitwise_1_vs_8_device_mesh_subprocess():
    """The §9 determinism claim on a real 8-device host mesh: sharded
    backends at 1 and 8 shards are bitwise-equal — distances and packed
    predecessors — to the single-device engine."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    out = subprocess.run([sys.executable, "-c", _SUBPROC], env=env,
                         capture_output=True, text=True, timeout=600)
    assert "DET-OK" in out.stdout, out.stdout + out.stderr


def _tie_graph():
    """Two equal-cost paths 0→3 settling in different buckets:
    0 →(1) 2 →(9) 3 (parent 2 settles in bucket 0) and
    0 →(9) 1 →(1) 3 (parent 1 settles in bucket 4). dist[3] = 10 both
    ways."""
    return COOGraph(src=np.array([0, 2, 0, 1], np.int32),
                    dst=np.array([2, 3, 1, 3], np.int32),
                    w=np.array([1, 9, 9, 1], np.int32), n_nodes=4)


def test_packed_ties_are_canonical_on_positive_weights():
    """DESIGN.md §11: on the canonical-ties class (all weights >= 1) the
    packed C4 filter compares whole (cost, pred) words, so the later tie
    candidate from vertex 1 *replaces* the first-settled parent 2 — the
    converged tree is the schedule-independent smallest-id tight-parent
    tree, identical to what argmin mode recovers from distances. (This
    trajectory independence is the warm-start bitwise contract's
    foundation.) Distances agree bitwise; the tree is valid and walks
    back to the source reproducing dist exactly."""
    g = _tie_graph()
    assert graph_is_canonical(g)
    with enable_x64():
        packed = DeltaSteppingSolver(
            g, DeltaConfig(delta=2, pred_mode="packed")).solve(0)
        d_packed = np.asarray(packed.dist, np.int64)
        p_packed = np.asarray(packed.pred)
    argmin = DeltaSteppingSolver(
        g, DeltaConfig(delta=2, pred_mode="argmin")).solve(0)
    d_argmin = np.asarray(argmin.dist, np.int64)
    p_argmin = np.asarray(argmin.pred)

    dref, _ = dijkstra(g, 0)
    np.testing.assert_array_equal(d_packed, dref)
    np.testing.assert_array_equal(d_argmin, dref)
    assert p_packed[3] == 1                  # smallest-id tight parent
    np.testing.assert_array_equal(p_packed, p_argmin)
    for pred in (p_packed, p_argmin):
        assert validate_pred_tree(g, 0, dref, pred)
        assert walk_pred_tree(g, 0, dref, pred)


def test_packed_ties_stay_temporal_on_zero_weights():
    """Zero-weight twin of the tie graph: 0 →(0) 2 →(10) 3 and
    0 →(10) 1 →(0) 3, dist[3] = 10 both ways. The graph leaves the
    canonical class, so the packed filter keeps the historical strict
    distance comparison: parent 2 settles first (bucket 0) and the later
    equal-cost candidate from vertex 1 is blocked — pred[3] == 2, not
    the smaller id 1. The first-settled rule is what keeps packed trees
    acyclic inside zero-weight tie groups (argmin is documented as
    unsupported there)."""
    g = COOGraph(src=np.array([0, 2, 0, 1], np.int32),
                 dst=np.array([2, 3, 1, 3], np.int32),
                 w=np.array([0, 10, 10, 0], np.int32), n_nodes=4)
    assert not graph_is_canonical(g)
    with enable_x64():
        res = DeltaSteppingSolver(
            g, DeltaConfig(delta=2, pred_mode="packed")).solve(0)
        dist = np.asarray(res.dist, np.int64)
        pred = np.asarray(res.pred)
    dref, _ = dijkstra(g, 0)
    np.testing.assert_array_equal(dist, dref)
    assert pred[3] == 2                      # first settled tight parent
    assert validate_pred_tree(g, 0, dref, pred)
    assert walk_pred_tree(g, 0, dref, pred)


def test_argmin_is_deterministic_across_backends():
    """argmin trees are a pure function of converged distances, so they
    cannot differ across backends or mesh shapes even on tie graphs."""
    g = _tie_graph()
    preds = {}
    for strategy in ("edge", "ell", "sharded_edge", "sharded_ell"):
        res = DeltaSteppingSolver(
            g, DeltaConfig(delta=2, strategy=strategy,
                           pred_mode="argmin")).solve(0)
        preds[strategy] = np.asarray(res.pred)
    base = preds["edge"]
    for strategy, p in preds.items():
        np.testing.assert_array_equal(p, base, err_msg=strategy)
