"""Frontier-policy family tests (repro.core.policies, DESIGN.md §15).

The policy axis — Δ-stepping / ρ-stepping / radius-stepping — shares
every relaxation backend, the telemetry counters and the warm-repair
path. This file pins the policy-specific contracts the differential
cross product (tests/test_differential.py) does not state explicitly:

* the driver tuple ``ALL_POLICIES`` equals the engine's ``POLICIES``
  registry, and the hypothesis-free fallback sweep still exercises every
  policy;
* warm re-solve bitwise equals cold per policy (the repair path is
  policy-agnostic: it only manufactures pending state);
* the provable telemetry bounds: any ρ's round count is bounded by
  ρ=1's (each round consumes at least the pending-minimum distance
  class), and radius-stepping's outer rounds are bounded by the Δ=1
  bucket count (same argument — Δ=1 outer = distinct finite distances);
* deterministic edge cases per policy (self-loop, zero weight,
  disconnected vertex);
* overflow demotion through the façade's single fallback point;
* config/plan validation, radius preprocessing and its RadiiStore.
"""
import numpy as np
import pytest

import _property_driver
from _property_driver import ALL_POLICIES, null_ctx
from test_differential import adversarial_coo
from repro.api import Engine, PointToPoint, SingleSource, UpdateBatch
from repro.compat import enable_x64
from repro.core import DeltaConfig, dijkstra
from repro.core.policies import (
    POLICIES,
    RadiiStore,
    compute_radii,
    default_rho,
    make_policy,
)
from repro.dynamic import apply_weight_update
from repro.graphs import watts_strogatz
from repro.graphs.structures import COOGraph, INF32

_INF = int(INF32)


def _cfg(policy, *, strategy="edge", pred_mode="argmin", delta=10, **kw):
    return DeltaConfig(delta=delta, strategy=strategy, pred_mode=pred_mode,
                       policy=policy, **kw)


def _solve(g, source, cfg):
    return Engine(g, cfg).plan().solve(SingleSource(source))


# ---------------------------------------------------------------------------
# registry + driver-fallback coverage
# ---------------------------------------------------------------------------

def test_driver_tuple_pins_policy_registry():
    """A policy added to the engine must join the differential cross
    product: the test driver's tuple and the core registry are the same
    set (and 'delta' stays first — the suites slice it off as the
    already-covered classic loop)."""
    assert set(ALL_POLICIES) == set(POLICIES)
    assert ALL_POLICIES[0] == "delta" == POLICIES[0]


def test_seed_sweep_fallback_covers_every_policy(monkeypatch):
    """Without hypothesis the driver degrades to a deterministic seed
    sweep — the policy loop lives *inside* the test body, so the
    fallback must still exercise every policy, not silently skip the
    axis."""
    monkeypatch.setattr(_property_driver, "HAVE_HYPOTHESIS", False)
    seen = []

    @_property_driver.drive(
        max_examples=5, fallback_examples=3,
        strategy=lambda st: st.integers(min_value=0, max_value=10),
        fallback_draw=lambda rng: int(rng.integers(0, 10)))
    def probe(seed):
        for policy in ALL_POLICIES:
            seen.append((seed, policy))

    probe()
    assert len(seen) == 3 * len(ALL_POLICIES)
    assert {p for _, p in seen} == set(ALL_POLICIES)


# ---------------------------------------------------------------------------
# warm == cold per policy
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", ALL_POLICIES)
@pytest.mark.parametrize("pred_mode", ["argmin", "packed"])
def test_warm_resolve_bitwise_equals_cold(policy, pred_mode):
    """One perturbation batch per policy: the warm re-solve is bitwise
    equal (dist AND pred, packed words included) to a cold solve of the
    updated graph — the repair path only manufactures pending state,
    and pending is what every policy selects from."""
    g = watts_strogatz(200, 6, 0.05, seed=3)
    rng = np.random.default_rng(7)
    ctx = enable_x64() if pred_mode == "packed" else null_ctx()
    with ctx:
        cfg = _cfg(policy, pred_mode=pred_mode, rho=16)
        plan = Engine(g, cfg).plan()
        plan.solve(SingleSource(0))
        w = np.asarray(plan.graph.w)
        ids = rng.choice(g.n_edges, size=10, replace=False)
        neww = np.clip(w[ids] + rng.integers(-5, 6, size=10), 1, None)
        warm = plan.solve(UpdateBatch(ids, neww))
        assert bool(warm.telemetry.warm)
        g2 = apply_weight_update(g, ids, neww)
        cold = Engine(g2, cfg).plan().solve(SingleSource(0))
        np.testing.assert_array_equal(np.asarray(warm.dist),
                                      np.asarray(cold.dist))
        np.testing.assert_array_equal(np.asarray(warm.pred),
                                      np.asarray(cold.pred))


# ---------------------------------------------------------------------------
# telemetry: the provable round-count bounds
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [2, 3, 12, 13])
def test_rho_round_count_bounded_by_rho1(seed):
    """ρ-round-count sanity on the adversarial corpus: every round
    consumes at least the whole pending-minimum distance class, so
    rounds(ρ=1) is at least the number of distinct finite distance
    classes and at most |V| — and larger batches never take more rounds
    than ρ=1 (each ρ=k round steps a superset of the ρ=1 round's
    frontier from the same pending state)."""
    g, source, _ = adversarial_coo(seed)

    def rounds(rho):
        cfg = _cfg("rho", pred_mode="none", rho=rho)
        return int(_solve(g, source, cfg).telemetry.buckets)

    base = rounds(1)
    dref, _ = dijkstra(g, source)
    finite = dref[dref < _INF]
    # a round's relaxations can land new members into the class it just
    # stepped, so base can exceed the class count — never undershoot it
    assert len(np.unique(finite)) <= base <= g.n_nodes
    for rho in (4, 32, g.n_nodes):
        assert rounds(rho) <= base, rho


@pytest.mark.parametrize("seed", [2, 3, 12, 13])
def test_radius_outer_rounds_bounded_by_delta1_buckets(seed):
    """Radius-stepping's outer rounds are bounded by the Δ=1 bucket
    count on the adversarial corpus: θ = min pending (tent + r) >= the
    pending minimum, so each radius round settles at least the distance
    class a Δ=1 bucket would."""
    g, source, _ = adversarial_coo(seed)
    delta1 = _solve(g, source, _cfg("delta", pred_mode="none", delta=1))
    radius = _solve(g, source, _cfg("radius", pred_mode="none"))
    assert (int(radius.telemetry.buckets)
            <= int(delta1.telemetry.buckets))
    np.testing.assert_array_equal(np.asarray(radius.dist),
                                  np.asarray(delta1.dist))


# ---------------------------------------------------------------------------
# deterministic edge cases per policy
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", ALL_POLICIES)
def test_self_loop_zero_weight_and_disconnected(policy):
    """Hand-built pathology: a self-loop on the source, a zero-weight
    edge in the shortest path, a duplicate edge pair, and a vertex no
    edge reaches. Every policy returns the oracle distances and the
    sentinels (INF32 dist, -1 pred) for the unreachable tail."""
    src = np.array([0, 0, 0, 1, 1, 2], np.int32)
    dst = np.array([0, 1, 1, 2, 2, 3], np.int32)
    w = np.array([5, 3, 7, 0, 4, 2], np.int32)   # dup 0->1, zero 1->2
    g = COOGraph(src, dst, w, 5)                 # vertex 4 disconnected
    cfg = _cfg(policy, delta=3, rho=2)
    res = _solve(g, 0, cfg)
    dist = np.asarray(res.dist, np.int64)
    dref, _ = dijkstra(g, 0)
    np.testing.assert_array_equal(dist, dref)
    assert dist[4] == _INF
    assert int(np.asarray(res.pred)[4]) == -1
    assert dist.tolist() == [0, 3, 3, 5, _INF]


# ---------------------------------------------------------------------------
# overflow demotion through the façade's fallback point
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", ALL_POLICIES[1:])
def test_overflow_demotes_to_full_width_per_policy(policy):
    """A frontier cap the policy's rounds overflow must demote through
    ``Plan.solve(fallback=True)`` to the full-width twin — same policy,
    exact answers, fallback telemetry set."""
    g = watts_strogatz(200, 8, 0.05, seed=25)
    cfg = _cfg(policy, strategy="ell", frontier_cap=4, rho=64)
    plan = Engine(g, cfg).plan(fallback=True)
    res = plan.solve(SingleSource(0))
    assert plan._demoted is not None
    assert bool(res.telemetry.fallback)
    assert plan._demoted.config.policy == policy
    dref, _ = dijkstra(g, 0)
    np.testing.assert_array_equal(np.asarray(res.dist, np.int64), dref)
    # follow-up queries ride the demoted twin and stay exact
    p2p = plan.solve(PointToPoint(0, 7))
    assert p2p.distance == int(dref[7])


# ---------------------------------------------------------------------------
# validation
# ---------------------------------------------------------------------------

def test_config_validation():
    with pytest.raises(ValueError, match="policy"):
        DeltaConfig(policy="bogus")
    with pytest.raises(ValueError):
        DeltaConfig(policy="rho", rho=0)
    with pytest.raises(ValueError):
        DeltaConfig(policy="radius", radius_k=0)


def test_grid_stencil_rejects_non_delta_policies():
    """The grid kernel recomputes bucket membership in-kernel from
    tent // Δ — it has no frontier-mask input a policy could drive."""
    from repro.graphs import grid_map
    g, free = grid_map(8, 8, seed=0)
    cfg = DeltaConfig(delta=13, strategy="pallas", interpret=True,
                      pred_mode="none", policy="rho", rho=8)
    with pytest.raises(ValueError, match="policy"):
        Engine(g, cfg, free_mask=free).plan()


def test_landmark_p2p_rejects_non_delta_policies():
    g = watts_strogatz(100, 4, 0.05, seed=1)
    plan = Engine(g, _cfg("rho", rho=8)).plan()
    with pytest.raises(ValueError, match="delta"):
        plan.solve(PointToPoint(0, 5, mode="alt"))


def test_explain_reports_policy():
    g = watts_strogatz(100, 4, 0.05, seed=1)
    assert Engine(g, _cfg("radius")).plan().explain()["policy"] == "radius"


# ---------------------------------------------------------------------------
# radius preprocessing + store
# ---------------------------------------------------------------------------

def test_compute_radii_kth_smallest_out_weight():
    src = np.array([0, 0, 0, 1, 2], np.int32)
    dst = np.array([1, 2, 3, 0, 0], np.int32)
    w = np.array([9, 4, 6, 5, 8], np.int32)
    g = COOGraph(src, dst, w, 4)                 # vertex 3: no out-edges
    r = compute_radii(g, k=2)
    assert r.tolist() == [6, 5, 8, 0]            # deg<k -> max out weight
    assert compute_radii(g, k=1).tolist() == [4, 5, 8, 0]
    assert compute_radii(g, k=10).tolist() == [9, 5, 8, 0]
    with pytest.raises(ValueError):
        compute_radii(g, k=0)


def test_radii_store_round_trip_and_corrupt_miss(tmp_path):
    g = watts_strogatz(60, 4, 0.05, seed=2)
    store = RadiiStore(str(tmp_path / "radii"))
    assert store.get(g, 4) is None               # cold miss
    r = compute_radii(g, 4)
    store.put(g, 4, r)
    np.testing.assert_array_equal(store.get(g, 4), r)
    # a fresh store object reads the persisted file back
    fresh = RadiiStore(str(tmp_path / "radii"))
    np.testing.assert_array_equal(fresh.get(g, 4), r)
    assert fresh.get(g, 5) is None               # different k: miss
    # different weights: different content hash, miss
    g2 = apply_weight_update(g, [0], [int(np.asarray(g.w)[0]) + 1])
    assert fresh.get(g2, 4) is None
    # corrupt every stored file: miss, never an error
    for f in (tmp_path / "radii").iterdir():
        f.write_bytes(b"garbage")
    assert RadiiStore(str(tmp_path / "radii")).get(g, 4) is None
    # in-memory store round-trips without a path
    mem = RadiiStore(None)
    mem.put(g, 4, r)
    np.testing.assert_array_equal(mem.get(g, 4), r)


def test_make_policy_defaults():
    g = watts_strogatz(400, 4, 0.05, seed=2)
    pol = make_policy(g, DeltaConfig(policy="rho"))
    assert pol.rho == default_rho(400) == 50
    pol = make_policy(g, DeltaConfig(policy="rho", rho=7))
    assert pol.rho == 7
    rad = make_policy(g, DeltaConfig(policy="radius", radius_k=2))
    np.testing.assert_array_equal(np.asarray(rad.r), compute_radii(g, 2))
