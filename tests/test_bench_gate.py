"""CI bench-gate plumbing: the regression checker's verdict logic
(including the injected-2x-slowdown drill the gate is certified with)
and the bench driver's exit-code contract — ``benchmarks/run.py`` must
exit non-zero when any bench module fails, or the gate can't trust a
green run."""
import json
import os
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if ROOT not in sys.path:
    sys.path.insert(0, ROOT)

from benchmarks import run as bench_run                      # noqa: E402
from benchmarks.check_regression import compare_records, main  # noqa: E402


def _record(times, status="ok", smoke=True):
    return {
        "bench": "demo",
        "status": status,
        "smoke": smoke,
        "rows": [{"name": n, "us_per_call": us, "derived": ""}
                 for n, us in times.items()],
    }


BASE = {"a/fast": 10_000, "a/slow": 100_000}


def test_identical_records_pass():
    failures, notes = compare_records(_record(BASE), _record(BASE))
    assert failures == [] and notes == []


def test_two_x_slowdown_fails():
    cur = {k: 2 * v for k, v in BASE.items()}
    failures, _ = compare_records(_record(BASE), _record(cur))
    assert len(failures) == 2
    assert all("1.5x tolerance" in f for f in failures)


def test_injected_slowdown_drill():
    """The certification drill from the module docstring: identical
    records with --inject-slowdown 2.0 must go red."""
    failures, _ = compare_records(_record(BASE), _record(BASE),
                                  inject_slowdown=2.0)
    assert failures


def test_ungated_rows_never_compared():
    base = _record({"a/tune_cost": 1_000_000})
    base["rows"][0]["gate"] = False
    cur = _record({"a/tune_cost": 10_000_000})  # 10x, but informational
    cur["rows"][0]["gate"] = False
    failures, _ = compare_records(base, cur)
    assert failures == []
    # dropping an informational row is also fine
    failures, _ = compare_records(base, _record({}))
    assert failures == []


def test_current_run_cannot_exempt_a_gated_row():
    """The baseline flag is authoritative: a PR flipping a gated row to
    gate=False (to hide a slowdown) must still be compared."""
    cur = _record({"a/fast": 40_000, "a/slow": 100_000})
    for r in cur["rows"]:
        r["gate"] = False
    failures, _ = compare_records(_record(BASE), cur)
    assert any("a/fast" in f for f in failures)


def test_noise_floor_exempts_tiny_rows():
    base = {"a/tiny": 100}
    cur = {"a/tiny": 300}                       # 3x but only +200us
    failures, notes = compare_records(_record(base), _record(cur),
                                      min_us=2_000)
    assert failures == []
    assert notes and "noise floor" in notes[0]


def test_missing_row_and_failed_status_fail():
    cur = _record({"a/fast": 10_000}, status="failed")
    failures, _ = compare_records(_record(BASE), cur)
    assert any("disappeared" in f for f in failures)
    assert any("status" in f for f in failures)
    failures, _ = compare_records(_record(BASE), None)
    assert failures


def test_smoke_mismatch_fails_new_rows_noted():
    cur = _record(dict(BASE, **{"a/new": 5_000}))
    failures, notes = compare_records(_record(BASE), cur)
    assert failures == []
    assert any("new row" in n for n in notes)
    failures, _ = compare_records(_record(BASE, smoke=False), cur)
    assert any("smoke-mode mismatch" in f for f in failures)


def test_check_regression_cli(tmp_path):
    base_dir, cur_dir = tmp_path / "base", tmp_path / "cur"
    base_dir.mkdir(), cur_dir.mkdir()
    (base_dir / "BENCH_demo.json").write_text(json.dumps(_record(BASE)))
    (cur_dir / "BENCH_demo.json").write_text(json.dumps(_record(BASE)))
    common = ["--bench-dir", str(cur_dir), "--baseline-dir", str(base_dir)]
    assert main(common) == 0
    assert main(common + ["--inject-slowdown", "2.0"]) == 1
    assert main(["--bench-dir", str(cur_dir),
                 "--baseline-dir", str(tmp_path / "nope")]) == 2


def test_update_refuses_non_smoke_records(tmp_path):
    """Baselining a full-size run would make every BENCH_SMOKE=1 gate
    run fail on smoke-mode mismatch; --allow-full is the override."""
    cur_dir, base_dir = tmp_path / "cur", tmp_path / "base"
    cur_dir.mkdir()
    (cur_dir / "BENCH_demo.json").write_text(
        json.dumps(_record(BASE, smoke=False)))
    args = ["--bench-dir", str(cur_dir), "--baseline-dir", str(base_dir)]
    assert main(args + ["--update"]) == 2
    assert not (base_dir / "BENCH_demo.json").exists()
    assert main(args + ["--update", "--allow-full"]) == 0
    assert (base_dir / "BENCH_demo.json").exists()


def test_update_warns_about_and_prunes_orphans(tmp_path, capsys):
    base_dir, cur_dir = tmp_path / "base", tmp_path / "cur"
    base_dir.mkdir(), cur_dir.mkdir()
    (base_dir / "BENCH_renamed_away.json").write_text(json.dumps(_record(BASE)))
    (cur_dir / "BENCH_demo.json").write_text(json.dumps(_record(BASE)))
    args = ["--bench-dir", str(cur_dir), "--baseline-dir", str(base_dir)]
    assert main(args + ["--update"]) == 0
    assert "orphan" in capsys.readouterr().err
    assert (base_dir / "BENCH_renamed_away.json").exists()  # warned only
    assert main(args + ["--update", "--prune"]) == 0
    assert not (base_dir / "BENCH_renamed_away.json").exists()
    assert (base_dir / "BENCH_demo.json").exists()
    assert main(args) == 0                                  # gate green now


def test_check_regression_update_refuses_failed(tmp_path):
    cur_dir, base_dir = tmp_path / "cur", tmp_path / "base"
    cur_dir.mkdir()
    # alphabetically-earlier OK record must NOT be half-copied when a
    # later record is failed: validate-all-then-copy
    (cur_dir / "BENCH_aaa.json").write_text(json.dumps(_record(BASE)))
    (cur_dir / "BENCH_demo.json").write_text(
        json.dumps(_record(BASE, status="failed")))
    rc = main(["--bench-dir", str(cur_dir),
               "--baseline-dir", str(base_dir), "--update"])
    assert rc == 2
    assert not (base_dir / "BENCH_aaa.json").exists()


# ---------------------------------------------------------------------------
# benchmarks/run.py exit-code contract
# ---------------------------------------------------------------------------

def test_run_exits_nonzero_on_bench_failure(tmp_path, monkeypatch):
    import benchmarks.bench_smallworld as bsw

    def boom():
        raise RuntimeError("injected bench failure")

    monkeypatch.setattr(bsw, "main", boom)
    monkeypatch.setenv("BENCH_OUT_DIR", str(tmp_path))
    assert bench_run.main(["--only", "smallworld"]) == 1
    record = json.loads((tmp_path / "BENCH_smallworld.json").read_text())
    assert record["status"] == "failed"


def test_run_exits_zero_on_success(tmp_path, monkeypatch):
    import benchmarks.bench_smallworld as bsw
    monkeypatch.setattr(bsw, "main", lambda: None)
    monkeypatch.setenv("BENCH_OUT_DIR", str(tmp_path))
    assert bench_run.main(["--only", "smallworld"]) == 0
    record = json.loads((tmp_path / "BENCH_smallworld.json").read_text())
    assert record["status"] == "ok"


def test_run_rejects_unknown_module(capsys):
    assert bench_run.main(["--only", "nonexistent"]) == 2
    assert "unknown bench" in capsys.readouterr().err
