"""Query/Plan façade tests (repro.api, DESIGN.md §10).

Three contracts:

* **Parity** — ``Plan.solve(SingleSource)`` / ``Plan.solve(MultiSource)``
  are bitwise identical (dist AND pred — packed (cost, pred) words
  included) to the seed-era solver composition, re-derived here directly
  from the module-level jitted drivers the pre-façade
  ``DeltaSteppingSolver`` owned, across all five backends. The CI
  ``sharded`` job runs this file under an 8-device host mesh, so the
  sharded backends are covered with real cross-device collectives.
* **PointToPoint** — differential vs the heap-Dijkstra oracle on the
  adversarial COO corpus (zero weights, self-loops, duplicate edges,
  disconnected tails; shared driver tests/_property_driver.py), plus
  the early-exit guarantee: strictly fewer buckets than the full solve
  on a line graph.
* **BoundedRadius / ManyToMany / fallback** — exactness within the
  radius, sentinel filtering beyond it, tiled matrix assembly, and the
  façade's single overflow-fallback point.
"""
from functools import partial

import jax.numpy as jnp
import numpy as np

from _property_driver import (
    ALL_POLICIES, ALL_STRATEGIES, drive, null_ctx as _null)
from test_differential import adversarial_coo
from repro.api import (
    BoundedRadius,
    Engine,
    ManyToMany,
    MultiSource,
    PointToPoint,
    SingleSource,
)
from repro.compat import enable_x64
from repro.core import DeltaConfig, DeltaSteppingSolver, dijkstra
from repro.core.backends import make_backend
from repro.core.delta_stepping import (
    _finish_pred,
    _finish_pred_many,
    _run_many_seq,
    _run_many_vmapped,
    _run_one,
)
from repro.graphs.structures import COOGraph, INF32

drive_seed = partial(
    drive,
    strategy=lambda st: st.integers(min_value=0, max_value=2**31 - 1),
    fallback_draw=lambda rng: int(rng.integers(0, 2**31)))

BACKENDS = ALL_STRATEGIES


def _seed_solve(g, source, cfg):
    """The pre-façade ``DeltaSteppingSolver.solve`` body, inlined from
    the module-level drivers it was built on — the parity reference."""
    backend = make_backend(g, cfg)
    packed = cfg.pred_mode == "packed"
    src = jnp.asarray(source, jnp.int32)
    tent, outer, inner, over = _run_one(backend, src, n=g.n_nodes,
                                        packed=packed)
    dist, pred = _finish_pred(tent, g, src, cfg)
    return dist, pred, outer, inner


def _seed_solve_many(g, sources, cfg):
    """The pre-façade ``solve_many`` body, inlined likewise."""
    backend = make_backend(g, cfg)
    packed = cfg.pred_mode == "packed"
    srcs = jnp.asarray(sources, jnp.int32)
    many = _run_many_vmapped if backend.supports_vmap else _run_many_seq
    tent, outer, inner, over = many(backend, srcs, n=g.n_nodes,
                                    packed=packed)
    dist, pred = _finish_pred_many(tent, g, srcs, cfg)
    return dist, pred, outer, inner


def _line_graph(n, w=10):
    """0 -> 1 -> ... -> n-1, unit chain of weight-w edges."""
    src = np.arange(n - 1, dtype=np.int32)
    dst = src + 1
    ws = np.full(n - 1, w, np.int32)
    return COOGraph(src=src, dst=dst, w=ws, n_nodes=n)


def _edge_weights(g):
    """min parallel-edge weight per (u, v) — path validity oracle."""
    ew = {}
    for s, d, w in zip(np.asarray(g.src), np.asarray(g.dst),
                       np.asarray(g.w)):
        key = (int(s), int(d))
        ew[key] = min(ew.get(key, 1 << 62), int(w))
    return ew


# ---------------------------------------------------------------------------
# parity: the façade vs the seed solver composition, all five backends
# ---------------------------------------------------------------------------

def test_single_source_parity_all_backends():
    """Plan.solve(SingleSource) is bitwise identical — dist, pred, and
    iteration counters — to the seed driver composition on every
    backend, for argmin and packed pred words."""
    g, source, _ = adversarial_coo(12345)
    for pred_mode in ("argmin", "packed"):
        ctx = enable_x64() if pred_mode == "packed" else _null()
        with ctx:
            for strategy in BACKENDS:
                cfg = DeltaConfig(delta=7, strategy=strategy,
                                  pred_mode=pred_mode, interpret=True)
                dist0, pred0, outer0, inner0 = _seed_solve(g, source, cfg)
                plan = Engine(g, cfg).plan()
                res = plan.solve(SingleSource(source))
                tag = (strategy, pred_mode)
                np.testing.assert_array_equal(
                    np.asarray(res.dist), np.asarray(dist0), err_msg=str(tag))
                np.testing.assert_array_equal(
                    np.asarray(res.pred), np.asarray(pred0), err_msg=str(tag))
                assert int(res.telemetry.buckets) == int(outer0), tag
                assert int(res.telemetry.inner_iters) == int(inner0), tag
                # and the deprecated shim returns the same SSSPResult
                legacy = DeltaSteppingSolver(g, cfg).solve(source)
                np.testing.assert_array_equal(
                    np.asarray(legacy.dist), np.asarray(dist0),
                    err_msg=str(tag))
                np.testing.assert_array_equal(
                    np.asarray(legacy.pred), np.asarray(pred0),
                    err_msg=str(tag))


def test_multi_source_parity_all_backends():
    """Plan.solve(MultiSource) is bitwise identical to the seed
    solve_many composition on every backend (packed words included)."""
    g, source, _ = adversarial_coo(54321)
    srcs = np.asarray([source, 0, g.n_nodes - 1], np.int32)
    for pred_mode in ("argmin", "packed"):
        ctx = enable_x64() if pred_mode == "packed" else _null()
        with ctx:
            for strategy in BACKENDS:
                cfg = DeltaConfig(delta=7, strategy=strategy,
                                  pred_mode=pred_mode, interpret=True)
                dist0, pred0, outer0, inner0 = _seed_solve_many(g, srcs, cfg)
                res = Engine(g, cfg).plan().solve(MultiSource(srcs))
                tag = (strategy, pred_mode)
                np.testing.assert_array_equal(
                    np.asarray(res.dist), np.asarray(dist0), err_msg=str(tag))
                np.testing.assert_array_equal(
                    np.asarray(res.pred), np.asarray(pred0), err_msg=str(tag))
                np.testing.assert_array_equal(
                    np.asarray(res.telemetry.buckets), np.asarray(outer0),
                    err_msg=str(tag))
                legacy = DeltaSteppingSolver(g, cfg).solve_many(srcs)
                np.testing.assert_array_equal(
                    np.asarray(legacy.dist), np.asarray(dist0),
                    err_msg=str(tag))
                np.testing.assert_array_equal(
                    np.asarray(legacy.pred), np.asarray(pred0),
                    err_msg=str(tag))


# ---------------------------------------------------------------------------
# PointToPoint: differential vs the oracle on the adversarial corpus
# ---------------------------------------------------------------------------

@drive_seed(max_examples=20, fallback_examples=8)
def test_point_to_point_matches_oracle(seed):
    """Early-exit p2p distance equals heap Dijkstra on adversarial COO
    graphs for every backend; returned paths are real graph paths whose
    weights sum to the distance (checked where the pred mode guarantees
    tree validity — packed always, argmin on zero-weight-free cases)."""
    g, source, w_lo = adversarial_coo(seed)
    dref, _ = dijkstra(g, source)
    rng = np.random.default_rng(seed)
    # one reachable-ish target, one guaranteed-disconnected tail target
    targets = (int(rng.integers(0, g.n_nodes)), g.n_nodes - 1)
    ew = _edge_weights(g)
    for pred_mode in ("argmin", "packed"):
        ctx = enable_x64() if pred_mode == "packed" else _null()
        with ctx:
            for strategy in BACKENDS:
                cfg = DeltaConfig(delta=7, strategy=strategy,
                                  pred_mode=pred_mode, interpret=True)
                plan = Engine(g, cfg).plan()
                for target in targets:
                    res = plan.solve(PointToPoint(source, target))
                    tag = (seed, strategy, pred_mode, target)
                    assert res.distance == int(dref[target]), tag
                    if res.distance >= int(INF32):
                        assert res.path is None, tag
                        continue
                    if pred_mode == "packed" or w_lo >= 1:
                        assert res.path is not None, tag
                        assert res.path[0] == source, tag
                        assert res.path[-1] == target, tag
                        acc = 0
                        for u, v in zip(res.path, res.path[1:]):
                            assert (u, v) in ew, tag
                            acc += ew[(u, v)]
                        assert acc == res.distance, tag


def test_point_to_point_early_exit_line_graph():
    """On a line graph, a near target must settle after inspecting
    strictly fewer buckets (and fewer inner iterations) than the full
    solve — the measurable content of the Kainer–Träff early exit."""
    g = _line_graph(128, w=10)
    cfg = DeltaConfig(delta=10, strategy="edge", pred_mode="argmin")
    plan = Engine(g, cfg).plan()
    full = plan.solve(SingleSource(0))
    near = plan.solve(PointToPoint(0, 5))
    assert near.distance == 50
    assert near.path == [0, 1, 2, 3, 4, 5]
    assert int(near.telemetry.buckets) < int(full.telemetry.buckets)
    assert int(near.telemetry.inner_iters) < int(full.telemetry.inner_iters)
    # early exit must never be wrong for the farthest vertex either
    far = plan.solve(PointToPoint(0, 127))
    assert far.distance == 1270


@drive_seed(max_examples=8, fallback_examples=4)
def test_point_to_point_policy_family_matches_oracle(seed):
    """p2p early exit under the non-delta frontier policies (DESIGN.md
    §15): the stop rule ``tent[target] <= min pending tent`` is sound
    for every policy (each round sweeps the full edge set of what it
    relaxes), so the answered distance must equal heap Dijkstra on the
    adversarial corpus — reachable targets and the disconnected tail."""
    g, source, _ = adversarial_coo(seed)
    dref, _ = dijkstra(g, source)
    rng = np.random.default_rng(seed)
    targets = (int(rng.integers(0, g.n_nodes)), g.n_nodes - 1)
    for policy in ALL_POLICIES[1:]:
        for strategy in ("edge", "ell", "sharded_fused"):
            cfg = DeltaConfig(delta=7, strategy=strategy,
                              pred_mode="argmin", interpret=True,
                              policy=policy, rho=4)
            plan = Engine(g, cfg).plan()
            for target in targets:
                res = plan.solve(PointToPoint(source, target))
                tag = (seed, policy, strategy, target)
                assert res.distance == int(dref[target]), tag


def test_point_to_point_policy_early_exit_line_graph():
    """The early exit has measurable content under every policy: a near
    target settles in strictly fewer rounds than the full solve."""
    g = _line_graph(128, w=10)
    for policy, extra in (("rho", dict(rho=4)), ("radius", {})):
        cfg = DeltaConfig(delta=10, strategy="edge", pred_mode="argmin",
                          policy=policy, **extra)
        plan = Engine(g, cfg).plan()
        full = plan.solve(SingleSource(0))
        near = plan.solve(PointToPoint(0, 5))
        assert near.distance == 50, policy
        assert near.path == [0, 1, 2, 3, 4, 5], policy
        assert (int(near.telemetry.buckets)
                < int(full.telemetry.buckets)), policy
        far = plan.solve(PointToPoint(0, 127))
        assert far.distance == 1270, policy


def test_point_to_point_source_is_target():
    g = _line_graph(16)
    plan = Engine(g, DeltaConfig(delta=10)).plan()
    res = plan.solve(PointToPoint(3, 3))
    assert res.distance == 0
    assert res.path == [3]


# ---------------------------------------------------------------------------
# BoundedRadius
# ---------------------------------------------------------------------------

def test_bounded_radius_exact_within_filtered_beyond():
    """dist <= r entries equal the oracle; everything beyond carries the
    INF32 / -1 sentinels; the solve stops before the full bucket
    schedule on a line graph."""
    from repro.graphs import watts_strogatz
    g = watts_strogatz(400, 6, 0.05, seed=3)
    dref, _ = dijkstra(g, 0)
    fin = dref < int(INF32)
    r = int(np.median(dref[fin]))
    for strategy in ("edge", "ell", "sharded_edge"):
        plan = Engine(g, DeltaConfig(delta=10, strategy=strategy)).plan()
        res = plan.solve(BoundedRadius(0, r))
        dist = np.asarray(res.dist, np.int64)
        pred = np.asarray(res.pred)
        expected = np.where(dref <= r, dref, int(INF32))
        np.testing.assert_array_equal(dist, expected, err_msg=strategy)
        assert (pred[dref > r] == -1).all(), strategy
    # early exit on the line graph: radius 50 of 1270 → few buckets
    line = _line_graph(128, w=10)
    plan = Engine(line, DeltaConfig(delta=10)).plan()
    full = plan.solve(SingleSource(0))
    ball = plan.solve(BoundedRadius(0, 50))
    assert int(ball.telemetry.buckets) < int(full.telemetry.buckets)
    assert int((np.asarray(ball.dist) < int(INF32)).sum()) == 6


def test_bounded_radius_zero():
    g = _line_graph(8, w=5)
    plan = Engine(g, DeltaConfig(delta=5)).plan()
    res = plan.solve(BoundedRadius(2, 0))
    dist = np.asarray(res.dist)
    assert dist[2] == 0
    assert (np.delete(dist, 2) == int(INF32)).all()


# ---------------------------------------------------------------------------
# ManyToMany
# ---------------------------------------------------------------------------

def test_many_to_many_matrix_matches_oracle():
    from repro.graphs import watts_strogatz
    g = watts_strogatz(200, 6, 0.05, seed=7)
    sources = [0, 3, 9, 14, 77]
    targets = [1, 2, 50, 199]
    for tile in (2, 8):                      # uneven + oversize tiles
        res = Engine(g, DeltaConfig(delta=10)).plan().solve(
            ManyToMany(sources, targets, tile=tile))
        assert res.matrix.shape == (len(sources), len(targets))
        for i, s in enumerate(sources):
            dref, _ = dijkstra(g, s)
            np.testing.assert_array_equal(res.matrix[i], dref[targets],
                                          err_msg=f"tile={tile} row {i}")


def test_many_to_many_includes_disconnected():
    g, source, _ = adversarial_coo(99)       # has a disconnected tail
    res = Engine(g, DeltaConfig(delta=7)).plan().solve(
        ManyToMany([source], [g.n_nodes - 1], tile=1))
    dref, _ = dijkstra(g, source)
    assert res.matrix[0, 0] == int(dref[g.n_nodes - 1])


# ---------------------------------------------------------------------------
# the one overflow-fallback point
# ---------------------------------------------------------------------------

def test_fallback_reanswers_and_demotes():
    """A capped plan with fallback=True re-answers an overflowing query
    full-width, marks the telemetry, and demotes permanently; with
    fallback=False the flag is only reported (legacy behavior)."""
    from repro.graphs import watts_strogatz
    g = watts_strogatz(300, 6, 0.05, seed=0)
    dref, _ = dijkstra(g, 0)
    cfg = DeltaConfig(delta=100, strategy="ell", frontier_cap=2,
                      pred_mode="none")
    plan = Engine(g, cfg).plan(fallback=True)
    res = plan.solve(SingleSource(0))
    assert res.telemetry.fallback
    np.testing.assert_array_equal(np.asarray(res.dist, np.int64), dref)
    assert plan.explain()["fallback_taken"]
    # demoted: later queries answer full-width directly
    res2 = plan.solve(MultiSource([0, 1]))
    assert res2.telemetry.fallback
    np.testing.assert_array_equal(np.asarray(res2.dist[0], np.int64), dref)
    # parity default: flag reported, answer left to the caller
    raw = Engine(g, cfg).plan().solve(SingleSource(0))
    assert bool(np.asarray(raw.telemetry.overflow))
    assert not raw.telemetry.fallback


def test_query_validation_rejects_bad_ids():
    """Out-of-range vertex ids must raise, not silently return all-INF
    (the jitted scatter drops OOB indices) or early-exit on a clamped
    gather; tile=0 must hit its own ValueError, not the default."""
    import pytest
    g = _line_graph(16)
    plan = Engine(g, DeltaConfig(delta=10)).plan()
    with pytest.raises(ValueError, match="out of range"):
        plan.solve(SingleSource(16))
    with pytest.raises(ValueError, match="out of range"):
        plan.solve(SingleSource(-1))
    with pytest.raises(ValueError, match="out of range"):
        plan.solve(MultiSource([0, 16]))
    with pytest.raises(ValueError, match="out of range"):
        plan.solve(PointToPoint(0, 16))
    with pytest.raises(ValueError, match="out of range"):
        plan.solve(BoundedRadius(16, 5))
    with pytest.raises(ValueError, match="out of range"):
        plan.solve(ManyToMany([0], [16]))
    with pytest.raises(ValueError, match="tile must be >= 1"):
        plan.solve(ManyToMany([0], [1], tile=0))
    with pytest.raises(ValueError, match="radius"):
        plan.solve(BoundedRadius(0, -1))


def test_solver_shim_ignores_cache_for_concrete_config(tmp_path):
    """Legacy semantics preserved exactly: a concrete config a caller
    pinned is never overwritten by a cached tuning record — tune_cache
    is consulted for config="auto" only (the old _resolve_auto
    contract)."""
    import json
    from repro.tune import TuningRecord, fingerprint, graph_stats
    from repro.graphs import watts_strogatz
    g = watts_strogatz(200, 6, 0.05, seed=0)
    fp = fingerprint(graph_stats(g))
    path = tmp_path / "cache.json"
    rec = TuningRecord(fingerprint=fp, delta=99, strategy="ell",
                       frontier_cap=None, source="measured")
    path.write_text(json.dumps({"version": 1,
                                "records": {fp: rec.to_json()}}))
    pinned = DeltaConfig(delta=5, strategy="edge")
    solver = DeltaSteppingSolver(g, pinned, tune_cache=str(path))
    assert solver.config == pinned              # cache not consulted
    auto = DeltaSteppingSolver(g, "auto", tune_cache=str(path))
    assert auto.config.delta == 99              # cache hit for "auto"


def test_plan_attaches_tuning_record(tmp_path):
    """A Plan resolved through the tuning subsystem carries the record
    it came from — tuning evidence attaches to the serving unit."""
    from repro.graphs import watts_strogatz
    g = watts_strogatz(300, 6, 0.05, seed=0)
    plan = Engine(g, "auto",
                  tune_cache=str(tmp_path / "t.json")).plan()
    assert plan.record is not None
    assert plan.record.source == "heuristic"
    assert plan.config.delta == plan.record.delta
    assert plan.explain()["tuning_source"] == "heuristic"
    # no tuning inputs → no record
    assert Engine(g, DeltaConfig(delta=5)).plan().record is None
