"""Per-kernel validation: Pallas (interpret=True on CPU) vs pure-jnp
oracle, swept over shapes/dtypes/parameters, plus end-to-end use of the
grid kernel inside the game-map solver against Dijkstra."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import DeltaConfig, delta_stepping, dijkstra
from repro.core.grid import GridDeltaConfig, GridDeltaSolver
from repro.graphs import grid_map
from repro.graphs.structures import INF32, coo_to_csr, csr_to_ell
from repro.graphs.generators import random_graph
from repro.kernels.bucket_scan import bucket_scan, bucket_scan_ref
from repro.kernels.ell_relax import ell_relax, ell_relax_ref
from repro.kernels.grid_relax import grid_relax, grid_relax_ref


def _rand_tent(rng, shape, frac_inf=0.3, hi=400):
    t = rng.integers(0, hi, size=shape).astype(np.int32)
    mask = rng.random(shape) < frac_inf
    return np.where(mask, INF32, t).astype(np.int32)


# ---------------------------------------------------------------- grid_relax
@pytest.mark.parametrize("shape", [(8, 16), (16, 128), (65, 130), (3, 257),
                                   (128, 128)])
@pytest.mark.parametrize("light", [True, False])
@pytest.mark.parametrize("delta", [13, 10, 25])
def test_grid_relax_matches_ref(shape, light, delta):
    rng = np.random.default_rng(hash((shape, light, delta)) % 2**32)
    tent = jnp.asarray(_rand_tent(rng, shape))
    free = jnp.asarray(rng.random(shape) > 0.2)
    tent = jnp.where(free, tent, INF32)
    for i in [0, 3, 11]:
        ref = grid_relax_ref(tent, free, i, delta=delta, cost_straight=10,
                             cost_diag=14, light=light)
        for block_rows in [8, 64]:
            out = grid_relax(tent, free, i, delta=delta, cost_straight=10,
                             cost_diag=14, light=light, block_rows=block_rows,
                             backend="pallas", interpret=True)
            np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_grid_relax_settles_single_source():
    free = jnp.ones((4, 8), bool)
    tent = jnp.full((4, 8), INF32, jnp.int32).at[0, 0].set(0)
    out = grid_relax(tent, free, 0, delta=13, cost_straight=10, cost_diag=14,
                     light=True, backend="pallas", interpret=True)
    assert int(out[0, 1]) == 10 and int(out[1, 0]) == 10
    assert int(out[1, 1]) == INF32  # diagonal is heavy under Δ=13


# ----------------------------------------------------------------- ell_relax
@pytest.mark.parametrize("n,deg,cap", [(16, 4, 8), (64, 7, 64), (33, 1, 16),
                                       (128, 16, 40)])
@pytest.mark.parametrize("backend", ["pallas", "pallas_row"])
def test_ell_relax_matches_ref(n, deg, cap, backend):
    rng = np.random.default_rng(n * 1000 + deg)
    g = random_graph(n, n * deg, seed=int(rng.integers(2**31)))
    ell = csr_to_ell(coo_to_csr(g))
    dist = jnp.asarray(_rand_tent(rng, (n,)))
    fidx = np.full(cap, n, np.int32)
    k = rng.integers(1, cap + 1)
    fidx[:k] = rng.choice(n, size=k, replace=False)
    fidx = jnp.asarray(fidx)
    ref = ell_relax_ref(fidx, dist, ell.w)
    out = ell_relax(fidx, dist, ell.w, backend=backend, interpret=True)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


# --------------------------------------------------------------- bucket_scan
@pytest.mark.parametrize("n", [5, 128, 1000, 4096])
@pytest.mark.parametrize("delta", [1, 10, 64])
def test_bucket_scan_matches_ref(n, delta):
    rng = np.random.default_rng(n + delta)
    tent = jnp.asarray(_rand_tent(rng, (n,)))
    explored = jnp.asarray(_rand_tent(rng, (n,)))
    for i in [0, 2, 9]:
        f_ref, any_ref, nxt_ref = bucket_scan_ref(tent, explored, i,
                                                  delta=delta)
        f, any_, nxt = bucket_scan(tent, explored, i, delta=delta,
                                   backend="pallas", interpret=True)
        np.testing.assert_array_equal(np.asarray(f), np.asarray(f_ref))
        assert bool(any_) == bool(any_ref)
        assert int(nxt) == int(nxt_ref)


# ------------------------------------------------- end-to-end grid solver
@pytest.mark.parametrize("backend", ["ref", "pallas"])
def test_grid_solver_matches_dijkstra(backend):
    h, w = 20, 33
    g, free = grid_map(h, w, 0.15, seed=21)
    src_flat = int(np.flatnonzero(free.ravel())[0])
    dref, _ = dijkstra(g, src_flat)
    cfg = GridDeltaConfig(backend=backend, interpret=(backend == "pallas"),
                          block_rows=8)
    solver = GridDeltaSolver(free, cfg)
    res = solver.solve((src_flat // w, src_flat % w))
    np.testing.assert_array_equal(
        np.asarray(res.dist).ravel().astype(np.int64), dref)
    # cross-check with the generic edge engine too
    d2 = delta_stepping(g, src_flat, DeltaConfig(delta=13)).dist
    np.testing.assert_array_equal(np.asarray(res.dist).ravel(),
                                  np.asarray(d2))
