"""Unreachable-vertex contract, pinned across the whole API surface:
every backend (single-device, pallas, mesh-sharded), every pred mode,
the batched ``solve_many`` path and the serving path must return the
same sentinels for disconnected vertices — ``INF32`` distance and
``-1`` predecessor — bitwise, not approximately (the serving layer
widens distances to int64 but keeps the INT32_MAX sentinel value).
"""
import numpy as np
import pytest

from _property_driver import null_ctx as _null
from repro.compat import enable_x64
from repro.core import DeltaConfig, DeltaSteppingSolver, dijkstra
from repro.graphs import grid_map, random_graph
from repro.graphs.structures import COOGraph, INF32


def _island_graph():
    """Edges confined to vertices 0..9; 10..19 are a disconnected tail
    (some with *outgoing* edges into the core — reachable-from but not
    reachable, the asymmetric case a naive check misses)."""
    g = random_graph(10, 40, seed=7)
    src = np.concatenate([np.asarray(g.src), np.array([12, 15], np.int32)])
    dst = np.concatenate([np.asarray(g.dst), np.array([0, 3], np.int32)])
    w = np.concatenate([np.asarray(g.w), np.array([1, 1], np.int32)])
    return COOGraph(src=src.astype(np.int32), dst=dst.astype(np.int32),
                    w=w.astype(np.int32), n_nodes=20)


GRAPH = _island_graph()
DREF, _ = dijkstra(GRAPH, 0)
UNREACHABLE = DREF >= int(INF32)


@pytest.mark.parametrize("strategy", ["edge", "ell", "pallas",
                                      "sharded_edge", "sharded_ell"])
@pytest.mark.parametrize("pred_mode", ["none", "argmin", "packed"])
def test_unreachable_sentinels_every_backend(strategy, pred_mode):
    assert UNREACHABLE.sum() >= 8           # the tail really is cut off
    ctx = enable_x64() if pred_mode == "packed" else _null()
    with ctx:
        cfg = DeltaConfig(delta=5, strategy=strategy, pred_mode=pred_mode,
                          interpret=True)
        res = DeltaSteppingSolver(GRAPH, cfg).solve(0)
        dist = np.asarray(res.dist, np.int64)
        pred = np.asarray(res.pred)
    np.testing.assert_array_equal(dist, DREF)
    assert (dist[UNREACHABLE] == int(INF32)).all()
    assert (pred[UNREACHABLE] == -1).all()


@pytest.mark.parametrize("strategy", ["edge", "sharded_edge"])
@pytest.mark.parametrize("pred_mode", ["none", "argmin", "packed"])
def test_unreachable_sentinels_through_solve_many(strategy, pred_mode):
    """Batched lanes keep the sentinels — including a lane whose source
    is itself inside the disconnected tail (so the *core* is
    unreachable from it)."""
    srcs = np.asarray([0, 12, 19], np.int32)
    ctx = enable_x64() if pred_mode == "packed" else _null()
    with ctx:
        cfg = DeltaConfig(delta=5, strategy=strategy, pred_mode=pred_mode)
        res = DeltaSteppingSolver(GRAPH, cfg).solve_many(srcs)
        dist = np.asarray(res.dist, np.int64)
        pred = np.asarray(res.pred)
    for i, s in enumerate(srcs):
        ref, _ = dijkstra(GRAPH, int(s))
        unreachable = ref >= int(INF32)
        np.testing.assert_array_equal(dist[i], ref, err_msg=f"lane {i}")
        assert (dist[i][unreachable] == int(INF32)).all(), f"lane {i}"
        assert (pred[i][unreachable] == -1).all(), f"lane {i}"
        assert pred[i][s] == -1, f"lane {i}"
    # vertex 19 is fully isolated: everything but itself is unreachable
    assert (dist[2][np.arange(20) != 19] == int(INF32)).all()


@pytest.mark.parametrize("strategy", ["edge", "sharded_edge"])
def test_unreachable_sentinels_through_serve_path(strategy):
    """SSSPServer queries: a full-vector query reports INF32 for the
    tail; a point-to-point query to an unreachable target reports INF32
    distance and ``path=None`` (not a bogus partial path)."""
    from repro.serve import SSSPQuery, SSSPServer
    target = int(np.flatnonzero(UNREACHABLE)[0])
    srv = SSSPServer(GRAPH,
                     DeltaConfig(delta=5, strategy=strategy,
                                 pred_mode="argmin"),
                     batch_size=2)
    srv.submit(SSSPQuery(qid=0, source=0))
    srv.submit(SSSPQuery(qid=1, source=0, target=target))
    done = sorted(srv.run_to_completion(), key=lambda q: q.qid)
    assert len(done) == 2
    full, p2p = done
    assert (full.dist[UNREACHABLE] == int(INF32)).all()
    np.testing.assert_array_equal(full.dist, DREF)
    assert p2p.dist == int(INF32)
    assert p2p.path is None


def test_unreachable_walls_on_gamemap():
    """Game-map walls (cells with no incident edges) get the same
    sentinels through the grid-stencil pallas backend as through the
    generic backends."""
    g, free = grid_map(12, 15, 0.3, seed=3)
    flat = np.asarray(free).ravel()
    src = int(np.flatnonzero(flat)[0])
    dref, _ = dijkstra(g, src)
    walls = ~flat
    for strategy, mask in (("edge", None), ("sharded_ell", None),
                           ("pallas", free)):
        cfg = DeltaConfig(delta=13, strategy=strategy, pred_mode="argmin",
                          interpret=True)
        res = DeltaSteppingSolver(g, cfg, free_mask=mask).solve(src)
        dist = np.asarray(res.dist, np.int64)
        pred = np.asarray(res.pred)
        np.testing.assert_array_equal(dist, dref, err_msg=strategy)
        assert (dist[walls] == int(INF32)).all(), strategy
        assert (pred[walls] == -1).all(), strategy
