"""Δ-stepping engine vs the Dijkstra oracle across graph families,
strategies, pred modes and Δ values — the correctness core of the repro."""
import numpy as np
import pytest

from repro.compat import enable_x64
from repro.core import (
    DeltaConfig,
    DeltaSteppingSolver,
    delta_stepping,
    dijkstra,
    validate_pred_tree,
)
from repro.graphs import (
    grid_map,
    random_graph,
    rmat,
    square_lattice,
    watts_strogatz,
)
from repro.graphs.structures import INF32


def _graphs():
    g, _ = grid_map(25, 31, 0.15, seed=3)
    return {
        "smallworld": watts_strogatz(300, 6, 0.05, seed=0),
        "smallworld_dense": watts_strogatz(120, 10, 0.3, seed=1),
        "rmat": rmat(256, 2500, seed=2),
        "gamemap": g,
        "lattice": square_lattice(17, weighted=True, seed=4),
        "disconnected": random_graph(150, 180, seed=5),
    }


GRAPHS = _graphs()


@pytest.mark.parametrize("name", sorted(GRAPHS))
@pytest.mark.parametrize("strategy", ["edge", "ell"])
@pytest.mark.parametrize("delta", [1, 5, 13, 100])
def test_matches_dijkstra(name, strategy, delta):
    g = GRAPHS[name]
    dref, _ = dijkstra(g, 0)
    res = delta_stepping(g, 0, DeltaConfig(delta=delta, strategy=strategy))
    np.testing.assert_array_equal(np.asarray(res.dist, np.int64), dref)
    assert not bool(res.overflow)


@pytest.mark.parametrize("name", ["smallworld", "rmat", "gamemap"])
@pytest.mark.parametrize("pred_mode", ["argmin", "packed"])
def test_pred_tree_valid(name, pred_mode):
    g = GRAPHS[name]
    ctx = enable_x64() if pred_mode == "packed" else _null()
    with ctx:
        res = delta_stepping(g, 0, DeltaConfig(delta=10, pred_mode=pred_mode))
        dist = np.asarray(res.dist, np.int64)
        pred = np.asarray(res.pred)
    dref, _ = dijkstra(g, 0)
    np.testing.assert_array_equal(dist, dref)
    assert validate_pred_tree(g, 0, dist, pred)
    assert pred[0] == -1


class _null:
    def __enter__(self):
        return self

    def __exit__(self, *a):
        return False


def test_multiple_sources_one_solver():
    g = GRAPHS["smallworld"]
    solver = DeltaSteppingSolver(g, DeltaConfig(delta=10))
    for s in [0, 7, 299]:
        dref, _ = dijkstra(g, s)
        np.testing.assert_array_equal(
            np.asarray(solver.solve(s).dist, np.int64), dref)


def test_unreachable_nodes_stay_inf():
    g = GRAPHS["disconnected"]
    res = delta_stepping(g, 0, DeltaConfig(delta=10))
    dref, _ = dijkstra(g, 0)
    d = np.asarray(res.dist, np.int64)
    np.testing.assert_array_equal(d, dref)
    unreached = d >= int(INF32)
    assert unreached.any(), "test graph should be disconnected"
    assert (np.asarray(res.pred)[unreached] == -1).all()


def test_delta_invariance():
    """The paper sweeps Δ for performance (Fig. 1); the result must not
    depend on it."""
    g = GRAPHS["rmat"]
    base = np.asarray(delta_stepping(g, 3, DeltaConfig(delta=1)).dist)
    for delta in [2, 3, 7, 19, 50]:
        d = np.asarray(delta_stepping(g, 3, DeltaConfig(delta=delta)).dist)
        np.testing.assert_array_equal(d, base)


def test_iteration_counts_shrink_with_delta():
    """Larger Δ ⇒ fewer buckets (outer iterations), the knob the paper
    trades against redundant work."""
    g = GRAPHS["smallworld"]
    o_small = int(delta_stepping(g, 0, DeltaConfig(delta=1)).outer_iters)
    o_large = int(delta_stepping(g, 0, DeltaConfig(delta=50)).outer_iters)
    assert o_large < o_small


def test_ell_frontier_capacity_overflow_flag():
    g = GRAPHS["smallworld_dense"]
    res = delta_stepping(
        g, 0, DeltaConfig(delta=100, strategy="ell", frontier_cap=2))
    assert bool(res.overflow)


def test_ell_overflow_flag_vs_correctness():
    """Regression for the ell frontier-capacity contract: whenever the
    overflow flag does NOT trip, the capped run must be exact; and a cap
    smaller than the true max frontier must trip the flag (a silent
    truncation would return wrong distances with overflow=False)."""
    g = GRAPHS["smallworld_dense"]
    dref, _ = dijkstra(g, 0)
    # delta=100 makes one giant bucket: the frontier spans most of the
    # 120-vertex graph, so small caps must overflow.
    saw_overflow = saw_exact = False
    for cap in [2, 8, 30, g.n_nodes]:
        res = delta_stepping(
            g, 0, DeltaConfig(delta=100, strategy="ell", frontier_cap=cap))
        if bool(res.overflow):
            saw_overflow = True
        else:
            saw_exact = True
            np.testing.assert_array_equal(
                np.asarray(res.dist, np.int64), dref)
    assert saw_overflow, "tiny caps should trip the overflow flag"
    assert saw_exact, "cap=|V| can never overflow"
    # a truncated run must not silently agree AND must flag itself
    res2 = delta_stepping(
        g, 0, DeltaConfig(delta=100, strategy="ell", frontier_cap=2))
    assert bool(res2.overflow)
    assert not np.array_equal(np.asarray(res2.dist, np.int64), dref), (
        "cap=2 cannot cover the frontier; distances should be incomplete")


def test_source_self_distance_zero():
    for name, g in GRAPHS.items():
        res = delta_stepping(g, 0, DeltaConfig(delta=10))
        assert int(res.dist[0]) == 0, name
