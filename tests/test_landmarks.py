"""The ALT goal-directed query subsystem (repro.landmarks, DESIGN.md
§14): landmark selection + distance tables, admissible potentials
(reduced costs >= 0), bidirectional Δ-stepping with the meeting-rule
stop, and the Plan/Server/tuner wiring around them.

The acceptance pin is bitwise distance equality: every landmark mode
(``alt``, ``bidirectional``, ``alt_bidirectional``) must answer exactly
what the early-exit unidirectional solve and the heap-Dijkstra oracle
answer — goal direction may only change *how fast* the answer arrives,
never the answer. Invalidation is the other contract: after a weight
batch that decreases any edge below its table-build value, stale tables
must never serve (``recompute`` drops + lazily rebuilds; ``refuse``
rejects the batch before any weight is applied)."""
import numpy as np
import pytest

from _property_driver import ALL_STRATEGIES
from repro.api import (
    Engine,
    LandmarkRefused,
    PointToPoint,
    UpdateRefused,
    stitch_bidirectional_path,
)
from repro.core import DeltaConfig, dijkstra, walk_pred_tree
from repro.core.delta_stepping import P2P_MODES
from repro.graphs import random_graph, square_lattice, watts_strogatz
from repro.graphs.structures import INF32
from repro.landmarks import (
    LANDMARK_MODES,
    LandmarkSpec,
    LandmarkState,
    LandmarkStore,
    build_tables,
    graph_whash,
    potentials,
    reduce_forward,
    reduce_union,
    select_landmarks,
)
from repro.graphs.structures import coo_to_csr, csr_to_ell, union_with_reverse

INF = int(INF32)


def _edge_weights(g):
    w = {}
    for u, v, c in zip(np.asarray(g.src), np.asarray(g.dst),
                       np.asarray(g.w)):
        key = (int(u), int(v))
        w[key] = min(w[key], int(c)) if key in w else int(c)
    return w


def _check_path(g, path, source, target, distance):
    """Endpoint + edge-existence + exact-cost validation of one path."""
    assert path[0] == source and path[-1] == target
    assert len(set(path)) == len(path)          # simple (cycle guard)
    ew = _edge_weights(g)
    acc = 0
    for a, b in zip(path, path[1:]):
        assert (a, b) in ew, (a, b)
        acc += ew[(a, b)]
    assert acc == distance


def _path_as_pred_tree(g, path, distance):
    """Re-express a stitched path as a one-chain pred tree and run the
    *existing* walk_pred_tree oracle over it — the stitched output must
    satisfy the same global invariant as a full solve's tree."""
    n = g.n_nodes
    pred = np.full(n, -1, np.int32)
    dist = np.full(n, INF, np.int64)
    ew = _edge_weights(g)
    acc = 0
    dist[path[0]] = 0
    for a, b in zip(path, path[1:]):
        acc += ew[(a, b)]
        pred[b] = a
        dist[b] = acc
    assert acc == distance
    return walk_pred_tree(g, path[0], dist, pred)


GRAPHS = {
    "lattice": lambda: square_lattice(14, weighted=True, seed=3),
    "smallworld": lambda: watts_strogatz(180, 6, 0.05, seed=7),
    "random": lambda: random_graph(150, 900, seed=2),
}


# ---------------------------------------------------------------------------
# potentials: admissibility as reduced-cost nonnegativity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("family", sorted(GRAPHS))
def test_reduced_costs_nonnegative(family):
    """π from the landmark tables must be *consistent*: every finite
    reduced cost w + π(v) − π(u) >= 0, on the forward graph and on both
    halves of the forward∪reverse union — this is the entire correctness
    argument for running Δ-stepping on the reweighted graph
    (DESIGN.md §14)."""
    import jax.numpy as jnp

    g = GRAPHS[family]()
    tables = build_tables(g, k=4, delta=10)
    fwd = csr_to_ell(coo_to_csr(g))
    uni = csr_to_ell(coo_to_csr(union_with_reverse(g)))
    for target in (0, g.n_nodes // 2, g.n_nodes - 1):
        pi = potentials(tables, target)
        assert pi[target] == 0                  # the goal has zero slack
        pi32 = jnp.asarray(pi.astype(np.int32))
        wf = np.asarray(reduce_forward(fwd.w, fwd.nbr, pi32, g.n_nodes))
        assert (wf[np.asarray(fwd.w) < INF] >= 0).all()
        wu = np.asarray(reduce_union(uni.w, uni.nbr, pi32, g.n_nodes))
        assert (wu[np.asarray(uni.w) < INF] >= 0).all()


# ---------------------------------------------------------------------------
# the acceptance pin: every mode, bitwise vs early-exit and Dijkstra
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("family", sorted(GRAPHS))
def test_all_modes_bitwise_equal_distances(family):
    g = GRAPHS[family]()
    plan = Engine(g, DeltaConfig(delta=10, pred_mode="argmin")).plan()
    targets = [1, g.n_nodes // 3, g.n_nodes - 1]
    dref, _ = dijkstra(g, 0)
    for t in targets:
        base = plan.solve(PointToPoint(0, t))   # early-exit reference
        assert base.distance == int(dref[t])
        for mode in LANDMARK_MODES:
            r = plan.solve(PointToPoint(0, t, mode=mode))
            assert r.distance == base.distance, (family, mode, t)
            if r.distance < INF:
                _check_path(g, r.path, 0, t, r.distance)
                assert _path_as_pred_tree(g, r.path, r.distance), \
                    (family, mode, t)
            else:
                assert r.path is None


@pytest.mark.parametrize("strategy", ALL_STRATEGIES)
def test_modes_match_oracle_across_backends(strategy):
    """The landmark query path rides along every plan strategy — the
    mode axis must stay bitwise-correct regardless of which relaxation
    backend the plan itself resolved to."""
    g = random_graph(80, 400, seed=4)
    cfg = DeltaConfig(delta=8, strategy=strategy, pred_mode="argmin",
                      interpret=True)
    plan = Engine(g, cfg).plan()
    dref, _ = dijkstra(g, 0)
    for mode in P2P_MODES:
        r = plan.solve(PointToPoint(0, g.n_nodes - 1, mode=mode))
        assert r.distance == int(dref[g.n_nodes - 1]), (strategy, mode)


def test_unreachable_target_every_mode():
    g = random_graph(60, 90, seed=5)            # sparse: isolated tail
    dref, _ = dijkstra(g, 0)
    far = int(np.argmax(dref))                  # an INF vertex
    assert dref[far] >= INF
    plan = Engine(g, DeltaConfig(delta=8, pred_mode="argmin")).plan()
    for mode in P2P_MODES:
        r = plan.solve(PointToPoint(0, far, mode=mode))
        assert r.distance == INF and r.path is None, mode


def test_source_equals_target_every_mode():
    g = square_lattice(8, weighted=True, seed=1)
    plan = Engine(g, DeltaConfig(delta=10, pred_mode="argmin")).plan()
    for mode in P2P_MODES:
        r = plan.solve(PointToPoint(5, 5, mode=mode))
        assert r.distance == 0 and r.path == [5], mode


def test_landmark_modes_require_canonical_weights():
    """Zero-weight edges break the path-recovery walk; the landmark
    modes must refuse them up front rather than mis-answer."""
    g = random_graph(40, 120, seed=0)
    w = np.asarray(g.w).copy()
    w[0] = 0
    bad = type(g)(src=g.src, dst=g.dst, w=w.astype(np.int32),
                  n_nodes=g.n_nodes)
    plan = Engine(bad, DeltaConfig(delta=8, pred_mode="argmin")).plan()
    with pytest.raises(ValueError, match="canonical"):
        plan.solve(PointToPoint(0, 10, mode="alt"))


def test_bad_mode_rejected():
    g = square_lattice(6, weighted=True)
    plan = Engine(g, DeltaConfig(delta=10)).plan()
    with pytest.raises(ValueError, match="p2p"):
        plan.solve(PointToPoint(0, 5, mode="astar"))
    with pytest.raises(ValueError):
        DeltaConfig(delta=10, p2p_mode="astar")


# ---------------------------------------------------------------------------
# stitch_bidirectional_path: the meeting-point fix (satellite)
# ---------------------------------------------------------------------------

def test_stitch_direct_regression():
    """pred_f is rooted at source, pred_b at target over the *reversed*
    edges; stitching joins them at the meeting vertex without repeating
    it."""
    pf = np.array([-1, 0, 1, -1], np.int32)     # 0 -> 1 -> 2
    pb = np.array([-1, -1, 3, -1], np.int32)    # 3 -> 2 (backward tree)
    assert stitch_bidirectional_path(pf, pb, 0, 3, 2, 4) == [0, 1, 2, 3]
    # meet == target: the backward half is the trivial [target]
    assert stitch_bidirectional_path(pf, pb, 0, 3, 3, 4) is None  # pf[3]=-1
    pf2 = np.array([-1, 0, 1, 2], np.int32)
    assert stitch_bidirectional_path(pf2, pb, 0, 3, 3, 4) == [0, 1, 2, 3]
    # meet == source: the forward half is the trivial [source]
    pb2 = np.array([1, 2, 3, -1], np.int32)     # chain back to 3
    assert stitch_bidirectional_path(pf, pb2, 0, 3, 0, 4) == [0, 1, 2, 3]


def test_stitch_cycle_guard_and_broken_chains():
    pf = np.array([-1, 2, 1, -1], np.int32)     # 1 <-> 2 cycle
    pb = np.array([-1, -1, 3, -1], np.int32)
    assert stitch_bidirectional_path(pf, pb, 0, 3, 2, 4) is None
    # backward cycle
    pf_ok = np.array([-1, 0, 1, -1], np.int32)
    pb_cyc = np.array([-1, -1, 2, -1], np.int32)  # 2 -> itself
    assert stitch_bidirectional_path(pf_ok, pb_cyc, 0, 3, 2, 4) is None
    # backward chain rooted at the wrong vertex
    pb_wrong = np.array([-1, -1, 1, -1], np.int32)
    assert stitch_bidirectional_path(pf_ok, pb_wrong, 0, 3, 2, 4) is None


def test_stitched_paths_pass_walk_oracle_many_pairs():
    g = random_graph(120, 700, seed=9)
    plan = Engine(g, DeltaConfig(delta=8, pred_mode="argmin")).plan()
    dref, _ = dijkstra(g, 3)
    rng = np.random.default_rng(0)
    for t in rng.integers(0, g.n_nodes, size=6):
        t = int(t)
        for mode in ("bidirectional", "alt_bidirectional"):
            r = plan.solve(PointToPoint(3, t, mode=mode))
            assert r.distance == int(min(dref[t], INF)), (mode, t)
            if r.distance < INF and t != 3:
                _check_path(g, r.path, 3, t, r.distance)
                assert _path_as_pred_tree(g, r.path, r.distance), (mode, t)


# ---------------------------------------------------------------------------
# invalidation under Plan.update (satellite): stale tables never serve
# ---------------------------------------------------------------------------

def _inc_dec_batches(g):
    """One increase-only batch and one decreasing batch on the heaviest
    edge (random_graph weights span [1, 20], so a decrease exists)."""
    w = np.asarray(g.w)
    eid = int(np.argmax(w))
    return (np.array([eid]), np.array([int(w[eid]) + 5]),
            np.array([eid]), np.array([1]))


@pytest.mark.parametrize("strategy", ALL_STRATEGIES)
def test_update_invalidation_recompute(strategy):
    g = random_graph(100, 500, seed=2)
    cfg = DeltaConfig(delta=8, strategy=strategy, pred_mode="argmin",
                      interpret=True)
    plan = Engine(g, cfg).plan().prepare_landmarks(k=3)
    t = g.n_nodes - 1
    assert plan.solve(PointToPoint(0, t, mode="alt")).distance == \
        int(dijkstra(g, 0)[0][t])
    tables0 = plan.landmark_tables
    inc_ids, inc_w, dec_ids, dec_w = _inc_dec_batches(g)

    plan.update(inc_ids, inc_w)                 # increase-only: kept
    assert plan.landmark_tables is tables0, strategy
    d1 = int(dijkstra(plan.graph, 0)[0][t])
    assert plan.solve(PointToPoint(0, t, mode="alt")).distance == d1

    plan.update(dec_ids, dec_w)                 # decrease: stale -> drop
    assert plan.landmark_tables is None, strategy
    d2 = int(dijkstra(plan.graph, 0)[0][t])
    for mode in LANDMARK_MODES:                 # lazy rebuild, fresh answer
        assert plan.solve(PointToPoint(0, t, mode=mode)).distance == d2, \
            (strategy, mode)
    assert plan.landmark_tables is not None


def test_update_invalidation_refuse():
    g = random_graph(100, 500, seed=2)
    plan = Engine(g, DeltaConfig(delta=8, pred_mode="argmin")).plan()
    plan.prepare_landmarks(k=3, on_update="refuse")
    w_before = np.asarray(plan.graph.w).copy()
    inc_ids, inc_w, dec_ids, dec_w = _inc_dec_batches(g)

    plan.update(inc_ids, inc_w)                 # increases pass through
    with pytest.raises(LandmarkRefused) as ei:
        plan.update(dec_ids, dec_w)
    assert ei.value.reason == "landmarks_stale"
    assert isinstance(ei.value, UpdateRefused)  # sheds on the typed path
    # refusal happened BEFORE any weight applied
    w_after = np.asarray(plan.graph.w)
    assert int(w_after[dec_ids[0]]) == int(w_before[dec_ids[0]]) + 5
    t = g.n_nodes - 1
    assert plan.solve(PointToPoint(0, t, mode="alt")).distance == \
        int(dijkstra(plan.graph, 0)[0][t])


# ---------------------------------------------------------------------------
# selection + store
# ---------------------------------------------------------------------------

def test_selection_deterministic_and_valid():
    g = watts_strogatz(150, 6, 0.05, seed=1)
    for strat in ("farthest", "random"):
        a, a_out, a_in = select_landmarks(g, 5, strategy=strat, seed=3)
        b, b_out, b_in = select_landmarks(g, 5, strategy=strat, seed=3)
        np.testing.assert_array_equal(a, b)
        np.testing.assert_array_equal(a_out, b_out)
        np.testing.assert_array_equal(a_in, b_in)
        assert len(np.unique(a)) == 5
        assert (a >= 0).all() and (a < g.n_nodes).all()
        assert list(a) == sorted(a)             # canonical order
    assert not np.array_equal(
        select_landmarks(g, 5, strategy="farthest", seed=3)[0],
        select_landmarks(g, 5, strategy="random", seed=3)[0])


def test_tables_rows_match_full_solves():
    """d_out rows are distances FROM each landmark, d_in rows distances
    TO it — both bitwise against the oracle on graph and reverse."""
    g = random_graph(80, 400, seed=6)
    tb = build_tables(g, k=3, delta=8)
    rev = g.reversed() if hasattr(g, "reversed") else None
    for j, lm in enumerate(tb.landmarks):
        np.testing.assert_array_equal(tb.d_out[j], dijkstra(g, int(lm))[0])
        if rev is not None:
            np.testing.assert_array_equal(
                tb.d_in[j], dijkstra(rev, int(lm))[0])


def test_store_roundtrip_and_corruption(tmp_path):
    g = random_graph(60, 300, seed=8)
    spec = LandmarkSpec(k=3, store=str(tmp_path))
    st1 = LandmarkState(spec, 8)
    tb = st1.ensure_tables(g)
    files = list(tmp_path.glob("landmarks_*.npz"))
    assert len(files) == 1
    # a second state over the same store hits persistently
    st2 = LandmarkState(spec, 8)
    hit = st2.ensure_tables(g)
    np.testing.assert_array_equal(hit.landmarks, tb.landmarks)
    np.testing.assert_array_equal(hit.d_out, tb.d_out)
    np.testing.assert_array_equal(hit.d_in, tb.d_in)
    assert hit.whash == graph_whash(g)
    # corruption is a miss, never a crash or a wrong answer
    files[0].write_bytes(b"not an npz")
    store = LandmarkStore(str(tmp_path))
    assert store.get(tb.fingerprint, tb.whash, 3, "farthest", 0) is None
    st3 = LandmarkState(spec, 8)
    rebuilt = st3.ensure_tables(g)              # rebuilds through the miss
    np.testing.assert_array_equal(rebuilt.d_out, tb.d_out)


def test_store_is_weight_keyed():
    """Same fingerprint bucket, different weights -> different tables:
    the whash term must keep them apart (tables move *answers*)."""
    g = random_graph(60, 300, seed=8)
    w2 = np.asarray(g.w).copy()
    w2[0] += 1
    g2 = type(g)(src=g.src, dst=g.dst, w=w2.astype(np.int32),
                 n_nodes=g.n_nodes)
    assert graph_whash(g) != graph_whash(g2)


# ---------------------------------------------------------------------------
# tuner + server wiring
# ---------------------------------------------------------------------------

def test_tune_p2p_picks_injected_winner(tmp_path):
    from repro.tune import TuningCache, tune_p2p

    g = square_lattice(10, weighted=True, seed=2)
    costs = {"early_exit": 3.0, "alt": 2.0, "bidirectional": 1.5,
             "alt_bidirectional": 0.5}
    rec = tune_p2p(g, measure_fn=lambda mode: costs[mode],
                   cache=str(tmp_path / "tune.json"))
    assert rec.p2p_mode == "alt_bidirectional"
    cfg = rec.to_config(DeltaConfig(delta=10))
    assert cfg.p2p_mode == "alt_bidirectional"
    # round-trips through the persistent cache
    cached = TuningCache(str(tmp_path / "tune.json"))
    hit = cached.get(rec.fingerprint)
    assert hit is not None and hit.p2p_mode == "alt_bidirectional"


def test_server_explicit_mode_runs_solo_and_matches(monkeypatch=None):
    from repro.serve import Server

    g = watts_strogatz(150, 6, 0.05, seed=4)
    dref, _ = dijkstra(g, 0)
    cfg = DeltaConfig(delta=10, pred_mode="argmin")
    with Server({"g": g}, config=cfg, lane_width=3, landmarks=2) as srv:
        t1 = srv.submit(PointToPoint(0, 40, mode="alt_bidirectional"),
                        graph="g")
        t2 = srv.submit(PointToPoint(0, 77), graph="g")
        r1, r2 = t1.result(timeout=300), t2.result(timeout=300)
    assert r1.distance == int(dref[40])
    assert r2.distance == int(dref[77])
    stats = srv.stats()
    assert stats["batches"]["solo"] >= 1        # explicit mode: solo batch


def test_server_rejects_bogus_mode():
    from repro.serve import RequestRejected, Server

    g = square_lattice(8, weighted=True)
    with Server({"g": g}, config=DeltaConfig(delta=10),
                lane_width=2) as srv:
        t = srv.submit(PointToPoint(0, 5, mode="astar"), graph="g")
        exc = t.exception(30)
    assert isinstance(exc, RequestRejected) and exc.reason == "invalid"
