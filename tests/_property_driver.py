"""Shared property-test driver: hypothesis-driven when hypothesis is
installed (the CI tier-1 install includes it via requirements-dev.txt,
so property suites never skip there), deterministic seed sweep
otherwise — the suites degrade to fewer examples, never to zero.

Used by tests/test_differential.py and tests/test_property_sssp.py; the
two files share this one implementation so a fix to the fallback
seeding or the ``@given`` wrapping cannot silently diverge between
them.
"""
import numpy as np

# Every RelaxBackend strategy the engine exposes, in registration order —
# the single source of truth for "the full differential matrix". The
# differential suite consumes this tuple so a newly added backend cannot
# silently stay out of the oracle cross product.
ALL_STRATEGIES = ("edge", "ell", "pallas", "fused",
                  "sharded_edge", "sharded_ell", "sharded_fused")

# Every frontier-selection policy (DESIGN.md §15), same contract: the
# differential suites consume this tuple, so a newly added policy joins
# the oracle cross product automatically. Pinned equal to
# repro.core.policies.POLICIES by tests/test_policies.py.
ALL_POLICIES = ("delta", "rho", "radius")

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    st = None
    HAVE_HYPOTHESIS = False


def drive(max_examples: int, fallback_examples: int, strategy,
          fallback_draw):
    """Property-driver decorator.

    ``strategy``      — callable ``st -> hypothesis strategy`` (built
                        lazily so importing this module never needs
                        hypothesis);
    ``fallback_draw`` — callable ``rng -> one drawn value`` emulating
                        the strategy with a seeded numpy Generator.
    """
    if HAVE_HYPOTHESIS:
        def deco(fn):
            return settings(max_examples=max_examples, deadline=None)(
                given(strategy(st))(fn))
        return deco

    def deco(fn):
        def run_sweep():
            rng = np.random.default_rng(0)
            for _ in range(fallback_examples):
                fn(fallback_draw(rng))
        run_sweep.__name__ = fn.__name__
        run_sweep.__doc__ = fn.__doc__
        return run_sweep
    return deco


class null_ctx:
    """No-op context manager (stand-in for enable_x64 in non-packed
    parametrizations)."""

    def __enter__(self):
        return self

    def __exit__(self, *a):
        return False
