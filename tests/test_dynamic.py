"""Dynamic-graph update subsystem (repro.dynamic, DESIGN.md §11).

The load-bearing contract: a warm re-solve after a k-edge perturbation
is **bitwise identical** — dist AND pred, packed (cost, pred) words
included — to a cold solve of the updated graph, across all five
relaxation backends. The CI ``sharded`` job runs this file under an
8-device host mesh, so the sharded backends are covered with real
cross-device collectives.

Also covered: the repair-cone bookkeeping (telemetry counts, bucket
skipping), the documented cold fallbacks (increases without a
predecessor tree, packed mode outside the canonical-ties class, an
overflowed resident state), update-batch composition and validation,
the ``UpdateBatch`` query packaging, serve-layer update batches between
microbatches, and the tuning-record reuse across in-range cost churn.
"""
import numpy as np
import pytest

from _property_driver import ALL_POLICIES, ALL_STRATEGIES, null_ctx
from repro.api import Engine, MultiSource, SingleSource, UpdateBatch
from repro.compat import enable_x64
from repro.core import DeltaConfig, dijkstra, walk_pred_tree
from repro.dynamic import apply_weight_update, plan_repair, resident_words
from repro.dynamic.repair import Resident
from repro.graphs import square_lattice, watts_strogatz
from repro.graphs.structures import COOGraph, INF32

BACKENDS = ALL_STRATEGIES

_INF = int(INF32)


def _cfg(strategy, pred_mode, delta=10):
    return DeltaConfig(delta=delta, strategy=strategy, pred_mode=pred_mode,
                       interpret=True)


def _perturb(rng, w, k, lo=1, hi=60):
    """k random edge ids + mixed-sign in-range replacement weights."""
    ids = rng.choice(w.shape[0], size=min(k, w.shape[0]), replace=False)
    neww = np.clip(w[ids] + rng.integers(-8, 9, size=ids.shape[0]), lo, hi)
    return ids, neww


def _x64_if(packed):
    return enable_x64() if packed else null_ctx()


@pytest.mark.parametrize("pred_mode", ["none", "argmin", "packed"])
@pytest.mark.parametrize("strategy", BACKENDS)
def test_warm_resolve_bitwise_equals_cold(strategy, pred_mode):
    """The acceptance contract, per backend x pred mode: three stacked
    perturbation batches, each warm re-solve bitwise equal to a cold
    solve of the updated graph and exact vs the Dijkstra oracle."""
    g = watts_strogatz(240, 6, 0.05, seed=3)
    rng = np.random.default_rng(17)
    packed = pred_mode == "packed"
    with _x64_if(packed):
        plan = Engine(g, _cfg(strategy, pred_mode)).plan()
        plan.solve(SingleSource(0))
        cur = g
        for _ in range(3):
            ids, neww = _perturb(rng, np.asarray(plan.graph.w), k=12)
            warm = plan.solve(UpdateBatch(ids, neww))
            cur = apply_weight_update(cur, ids, neww)
            cold = Engine(cur, _cfg(strategy, pred_mode)).plan().solve(
                SingleSource(0))
            np.testing.assert_array_equal(
                np.asarray(warm.dist), np.asarray(cold.dist))
            np.testing.assert_array_equal(
                np.asarray(warm.pred), np.asarray(cold.pred))
            dref, _ = dijkstra(cur, 0)
            np.testing.assert_array_equal(
                np.asarray(warm.dist, np.int64), dref)
            if pred_mode != "none":
                assert walk_pred_tree(cur, 0, dref, np.asarray(warm.pred))
            # mixed batches contain increases: 'none' mode must have
            # fallen back cold, the tree-tracking modes repair warm
            assert bool(warm.telemetry.warm) == (pred_mode != "none")


@pytest.mark.parametrize("pred_mode", ["none", "argmin", "packed"])
@pytest.mark.parametrize("policy", ALL_POLICIES[1:])
@pytest.mark.parametrize("strategy", ["edge", "ell", "sharded_fused"])
def test_warm_resolve_bitwise_equals_cold_per_policy(strategy, policy,
                                                     pred_mode):
    """The warm contract holds per frontier policy (DESIGN.md §15): the
    repair path only manufactures pending state (``tent < explored``) on
    the repair cone, and pending is what every policy selects from — so
    warm == cold is policy-agnostic. Two stacked batches, each warm
    re-solve bitwise equal to a cold solve of the updated graph.
    radius-stepping additionally recomputes its weight-dependent r(v)
    radii at update time; a stale table would still be *exact* (any
    r >= 0 is), so the bitwise pin is against the cold plan's table."""
    g = watts_strogatz(240, 6, 0.05, seed=3)
    rng = np.random.default_rng(17)
    packed = pred_mode == "packed"

    def cfg():
        return DeltaConfig(delta=10, strategy=strategy, pred_mode=pred_mode,
                           interpret=True, policy=policy, rho=24)

    with _x64_if(packed):
        plan = Engine(g, cfg()).plan()
        plan.solve(SingleSource(0))
        cur = g
        for _ in range(2):
            ids, neww = _perturb(rng, np.asarray(plan.graph.w), k=12)
            warm = plan.solve(UpdateBatch(ids, neww))
            cur = apply_weight_update(cur, ids, neww)
            cold = Engine(cur, cfg()).plan().solve(SingleSource(0))
            np.testing.assert_array_equal(
                np.asarray(warm.dist), np.asarray(cold.dist))
            np.testing.assert_array_equal(
                np.asarray(warm.pred), np.asarray(cold.pred))
            dref, _ = dijkstra(cur, 0)
            np.testing.assert_array_equal(
                np.asarray(warm.dist, np.int64), dref)
            if pred_mode != "none":
                assert walk_pred_tree(cur, 0, dref, np.asarray(warm.pred))
            assert bool(warm.telemetry.warm) == (pred_mode != "none")


def test_decrease_only_stays_warm_without_pred_tree():
    """pred_mode='none' tracks no tree, but decrease-only batches need
    none — the repair must stay warm and bitwise-equal."""
    g = watts_strogatz(300, 6, 0.05, seed=5)
    plan = Engine(g, _cfg("edge", "none")).plan()
    plan.solve(SingleSource(0))
    w = np.asarray(plan.graph.w)
    ids = np.nonzero(w > 5)[0][:20]
    neww = w[ids] - 4
    warm = plan.solve(UpdateBatch(ids, neww))
    assert bool(warm.telemetry.warm)
    g2 = apply_weight_update(g, ids, neww)
    cold = Engine(g2, _cfg("edge", "none")).plan().solve(SingleSource(0))
    np.testing.assert_array_equal(np.asarray(warm.dist),
                                  np.asarray(cold.dist))


def test_warm_skips_untouched_buckets():
    """A far-end perturbation on a long-diameter lattice must not re-walk
    the whole bucket sequence: the unsettled-only next-bucket scan jumps
    straight to the repair cone's buckets."""
    side = 24
    g = square_lattice(side, weighted=True)
    plan = Engine(g, _cfg("edge", "argmin")).plan()
    full = plan.solve(SingleSource(0))
    b_cold = int(full.telemetry.buckets)
    # perturb one far-corner edge (the highest-dst edges sit far from
    # vertex 0 on a lattice built row-major)
    far_edge = int(np.argmax(np.asarray(g.dst)))
    old_w = int(np.asarray(g.w)[far_edge])
    warm = plan.solve(UpdateBatch([far_edge], [old_w + 7]))
    assert bool(warm.telemetry.warm)
    assert int(warm.telemetry.buckets) < b_cold / 2, (
        f"warm visited {int(warm.telemetry.buckets)} of {b_cold} buckets"
    )
    g2 = apply_weight_update(g, [far_edge], [old_w + 7])
    cold = Engine(g2, _cfg("edge", "argmin")).plan().solve(SingleSource(0))
    np.testing.assert_array_equal(np.asarray(warm.dist),
                                  np.asarray(cold.dist))
    np.testing.assert_array_equal(np.asarray(warm.pred),
                                  np.asarray(cold.pred))


def test_cascade_outgrowing_repair_twin_stays_exact():
    """A tiny seed whose improvement cascades across most of the graph
    overflows the frontier-capped repair twin; resolve must re-run the
    same warm state full-width and still match the cold solve bitwise
    (the cap moves time, never answers)."""
    side = 20
    g = square_lattice(side, weighted=True)
    # inflate every weight so one near-source shortcut rewrites almost
    # every distance downstream; Δ=1000 packs the whole cascade into a
    # couple of buckets, so the per-sweep frontier far exceeds the
    # repair twin's cap (one seed -> cap 64) and the overflow re-run
    # path must fire
    w = np.asarray(g.w) * 10
    g = COOGraph(g.src, g.dst, np.asarray(w, np.int32), g.n_nodes)
    plan = Engine(g, _cfg("edge", "argmin", delta=1000)).plan()
    plan.solve(SingleSource(0))
    runs = []
    orig = plan._run_warm
    plan._run_warm = lambda be, t, e: runs.append(be is plan.backend) or orig(
        be, t, e)
    warm = plan.solve(UpdateBatch([0], [1]))   # edge 0: 0 -> neighbor
    assert bool(warm.telemetry.warm)
    assert runs == [False, True], runs         # twin overflowed, rerun full
    g2 = apply_weight_update(g, [0], [1])
    cold = Engine(g2, _cfg("edge", "argmin", delta=1000)).plan().solve(
        SingleSource(0))
    np.testing.assert_array_equal(np.asarray(warm.dist),
                                  np.asarray(cold.dist))
    np.testing.assert_array_equal(np.asarray(warm.pred),
                                  np.asarray(cold.pred))


def test_distance_neutral_tie_change_updates_argmin_pred():
    """A decrease landing exactly on dist[v] creates a new smaller-id
    tight parent without moving any distance. The warm no-op fast path
    must still hand back the argmin tree of the *updated* graph, not the
    stale resident one (regression: review finding on the repaired==0
    short-circuit)."""
    src = np.array([0, 0, 2, 1], np.int32)
    dst = np.array([1, 2, 3, 3], np.int32)
    w = np.array([1, 1, 5, 6], np.int32)
    g = COOGraph(src, dst, w, 4)
    plan = Engine(g, _cfg("edge", "argmin", delta=3)).plan()
    base = plan.solve(SingleSource(0))
    assert int(base.pred[3]) == 2            # only tight parent
    warm = plan.solve(UpdateBatch([3], [5]))  # 1->3 now tight too, dist same
    assert bool(warm.telemetry.warm) and int(warm.telemetry.repaired) == 0
    g2 = apply_weight_update(g, [3], [5])
    cold = Engine(g2, _cfg("edge", "argmin", delta=3)).plan().solve(
        SingleSource(0))
    np.testing.assert_array_equal(np.asarray(warm.dist),
                                  np.asarray(cold.dist))
    np.testing.assert_array_equal(np.asarray(warm.pred),
                                  np.asarray(cold.pred))
    assert int(warm.pred[3]) == 1            # smallest-id tight parent
    # and the refreshed residency carries the corrected tree forward
    follow = plan.resolve(warm=True)
    np.testing.assert_array_equal(np.asarray(follow.pred),
                                  np.asarray(cold.pred))


def test_residency_survives_overflow_demotion():
    """An overflow on a fallback plan demotes to a full-width twin; the
    resident state must ride along so update/resolve keep working
    (regression: review finding on the demotion path)."""
    g = watts_strogatz(200, 8, 0.05, seed=25)
    cfg = DeltaConfig(delta=10, pred_mode="argmin", strategy="ell",
                      frontier_cap=4)
    plan = Engine(g, cfg).plan(fallback=True)
    plan.solve(SingleSource(0))              # overflows cap=4 -> demotes
    assert plan._demoted is not None
    w = np.asarray(plan.graph.w)
    warm = plan.solve(UpdateBatch([0], [int(w[0]) + 3]))
    g2 = apply_weight_update(g, [0], [int(w[0]) + 3])
    cold = Engine(g2, DeltaConfig(delta=10, pred_mode="argmin")).plan().solve(
        SingleSource(0))
    np.testing.assert_array_equal(np.asarray(warm.dist),
                                  np.asarray(cold.dist))
    np.testing.assert_array_equal(np.asarray(warm.pred),
                                  np.asarray(cold.pred))


def test_increase_without_pred_tree_falls_back_cold():
    g = watts_strogatz(200, 6, 0.05, seed=7)
    plan = Engine(g, _cfg("edge", "none")).plan()
    plan.solve(SingleSource(0))
    w = np.asarray(plan.graph.w)
    warm = plan.solve(UpdateBatch([3], [int(w[3]) + 10]))
    assert not bool(warm.telemetry.warm)     # cold fallback, documented
    g2 = apply_weight_update(g, [3], [int(w[3]) + 10])
    dref, _ = dijkstra(g2, 0)
    np.testing.assert_array_equal(np.asarray(warm.dist, np.int64), dref)


def test_zero_weight_graph_packed_falls_back_cold():
    """Outside the canonical-ties class the packed fixed point is
    schedule-dependent, so the warm contract refuses and the resolve
    runs cold — answers still exact and bitwise-stable."""
    src = np.array([0, 0, 1, 2], np.int32)
    dst = np.array([1, 2, 3, 3], np.int32)
    w = np.array([0, 5, 7, 2], np.int32)     # zero weight: not canonical
    g = COOGraph(src, dst, w, 4)
    with enable_x64():
        plan = Engine(g, _cfg("edge", "packed", delta=3)).plan()
        plan.solve(SingleSource(0))
        warm = plan.solve(UpdateBatch([1], [4]))
        assert not bool(warm.telemetry.warm)
        g2 = apply_weight_update(g, [1], [4])
        cold = Engine(g2, _cfg("edge", "packed", delta=3)).plan().solve(
            SingleSource(0))
        np.testing.assert_array_equal(np.asarray(warm.dist),
                                      np.asarray(cold.dist))
        np.testing.assert_array_equal(np.asarray(warm.pred),
                                      np.asarray(cold.pred))


def test_overflowed_resident_state_refused():
    g = watts_strogatz(200, 6, 0.05, seed=9)
    resident = Resident(source=0,
                        dist=np.zeros(g.n_nodes, np.int64),
                        pred=np.full(g.n_nodes, -1, np.int32),
                        w=np.asarray(g.w, np.int32),
                        overflow=True)
    rep, reason = plan_repair(g, resident, pred_mode="none")
    assert rep is None and "overflow" in reason


def test_noop_update_short_circuits():
    """Re-submitting identical weights is distance-neutral: the resident
    answer stands, zero buckets processed, telemetry says so."""
    g = watts_strogatz(200, 6, 0.05, seed=11)
    plan = Engine(g, _cfg("edge", "argmin")).plan()
    base = plan.solve(SingleSource(0))
    w = np.asarray(plan.graph.w)
    res = plan.solve(UpdateBatch([0, 5, 9], w[[0, 5, 9]]))
    assert bool(res.telemetry.warm)
    assert int(res.telemetry.repaired) == 0
    assert int(res.telemetry.buckets) == 0
    np.testing.assert_array_equal(np.asarray(res.dist),
                                  np.asarray(base.dist))
    np.testing.assert_array_equal(np.asarray(res.pred),
                                  np.asarray(base.pred))


def test_update_batches_compose_before_resolve():
    """Several update() calls between resolves diff against one resident
    snapshot — the warm result equals the cold solve of the *final*
    graph."""
    g = watts_strogatz(260, 6, 0.05, seed=13)
    plan = Engine(g, _cfg("edge", "argmin")).plan()
    plan.solve(SingleSource(0))
    rng = np.random.default_rng(23)
    cur = g
    for _ in range(3):                       # three batches, no resolve
        ids, neww = _perturb(rng, np.asarray(plan.graph.w), k=8)
        plan.update(ids, neww)
        cur = apply_weight_update(cur, ids, neww)
    warm = plan.resolve(warm=True)
    assert bool(warm.telemetry.warm)
    cold = Engine(cur, _cfg("edge", "argmin")).plan().solve(SingleSource(0))
    np.testing.assert_array_equal(np.asarray(warm.dist),
                                  np.asarray(cold.dist))
    np.testing.assert_array_equal(np.asarray(warm.pred),
                                  np.asarray(cold.pred))


def test_update_validation():
    g = watts_strogatz(50, 4, 0.05, seed=1)
    plan = Engine(g, _cfg("edge", "argmin")).plan()
    with pytest.raises(ValueError):
        plan.update([g.n_edges], [5])        # edge id out of range
    with pytest.raises(ValueError):
        plan.update([0], [-1])               # negative weight
    with pytest.raises(ValueError):
        plan.update([0], [_INF])             # INF sentinel as a weight
    with pytest.raises(ValueError):
        plan.update([0, 1], [5])             # shape mismatch


def test_resolve_without_resident_raises():
    g = watts_strogatz(50, 4, 0.05, seed=1)
    plan = Engine(g, _cfg("edge", "argmin")).plan()
    with pytest.raises(ValueError, match="resident"):
        plan.resolve()


def test_cold_resolve_refreshes_residency():
    """resolve(warm=False) is a full re-solve that still refreshes the
    resident snapshot, so a later warm resolve repairs from it."""
    g = watts_strogatz(200, 6, 0.05, seed=15)
    plan = Engine(g, _cfg("edge", "argmin")).plan()
    plan.solve(SingleSource(0))
    w = np.asarray(plan.graph.w)
    plan.update([2], [int(w[2]) + 5])
    cold = plan.resolve(warm=False)
    assert not bool(cold.telemetry.warm)
    warm = plan.resolve(warm=True)           # no changes since: no-op
    assert int(warm.telemetry.repaired) == 0
    np.testing.assert_array_equal(np.asarray(warm.dist),
                                  np.asarray(cold.dist))


def test_resident_words_roundtrip():
    """(dist, pred) -> packed words reconstruction is bit-exact against
    pack.py's layout, including the source and unreachable sentinels."""
    from repro.core import pack as packing
    dist = np.array([0, 7, _INF], np.int64)
    pred = np.array([-1, 0, -1], np.int32)
    words = resident_words(dist, pred, source=0, packed=True)
    assert words[0] == 0                     # pack(0, source=0)
    assert words[1] == (7 << 32) | 0
    assert words[2] == packing.INF_PACKED
    plain = resident_words(dist, pred, source=0, packed=False)
    assert plain.dtype == np.int32 and plain[2] == _INF


def test_grid_plan_rejects_weight_updates():
    from repro.graphs import grid_map

    g, free = grid_map(8, 8, seed=0)
    plan = Engine(
        g, DeltaConfig(delta=13, strategy="pallas", interpret=True,
                       pred_mode="none"),
        free_mask=free).plan()
    with pytest.raises(ValueError, match="grid"):
        plan.update([0], [5])


def test_fingerprint_stable_across_inrange_updates(tmp_path):
    """The tune satellite: in-range cost churn keeps the cache
    fingerprint — a plan's tuning record survives updates, and a fresh
    engine over the updated graph still hits the cache."""
    from repro.tune import TuningCache, fingerprint, graph_stats

    g = watts_strogatz(300, 6, 0.05, seed=19)
    cache = str(tmp_path / "tuning.json")
    # measured search once, persisted to the cache file
    plan = Engine(g, "auto", tune=True, tune_cache=cache).plan(sources=(0,))
    assert plan.record is not None and plan.record.source == "measured"
    fp0 = plan.record.fingerprint
    plan.solve(SingleSource(0))
    # perturb within the existing weight range: fingerprint unchanged
    w = np.asarray(plan.graph.w)
    lo, hi = int(w.min()), int(w.max())
    rng = np.random.default_rng(29)
    ids = rng.choice(g.n_edges, size=20, replace=False)
    plan.update(ids, rng.integers(lo, hi + 1, size=20))
    assert fingerprint(graph_stats(plan.graph)) == fp0
    # a fresh engine over the updated graph reuses the cached record:
    # it never measures (no tune=True), so a 'measured' provenance can
    # only have come from the cache file
    plan2 = Engine(plan.graph, "auto", tune_cache=cache).plan()
    assert plan2.record.source == "measured"
    assert plan2.record.fingerprint == fp0
    assert plan2.record.delta == plan.record.delta
    assert TuningCache(cache).get(fp0) is not None


def test_server_applies_updates_between_microbatches():
    """SSSPServer holds its plan resident: queued weight updates apply at
    the next step() boundary, so a microbatch is answered against one
    consistent snapshot — before-update answers match the old graph,
    after-update answers the new one."""
    g = watts_strogatz(200, 6, 0.05, seed=21)
    from repro.serve import SSSPQuery, SSSPServer

    with pytest.deprecated_call():
        srv = SSSPServer(g, DeltaConfig(delta=10, pred_mode="argmin"),
                         batch_size=2)
    srv.submit(SSSPQuery(qid=0, source=0))
    (q0,) = srv.step()
    dref, _ = dijkstra(g, 0)
    np.testing.assert_array_equal(q0.dist, dref)

    w = np.asarray(srv.plan.graph.w)
    ids = np.arange(10)
    neww = np.clip(w[ids] + 9, 1, None)
    srv.update(ids, neww)
    assert srv.plan.graph is g or True       # not yet applied
    srv.submit(SSSPQuery(qid=1, source=0))
    (q1,) = srv.step()                       # update lands first
    g2 = apply_weight_update(g, ids, neww)
    dref2, _ = dijkstra(g2, 0)
    np.testing.assert_array_equal(q1.dist, dref2)
    assert srv.graph is srv.plan.graph       # server view refreshed
