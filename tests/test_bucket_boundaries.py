"""Bucket-boundary semantics pins: distances exactly on a Δ multiple.

The tuner's Δ search makes these the hottest edge cases — a candidate Δ
that divides many path lengths puts whole frontiers exactly on bucket
boundaries. The contract being pinned (paper C1 + Alg. 1 light/heavy
split):

* a vertex with dist == k·Δ belongs to bucket k (floor division), not
  bucket k-1;
* an edge with w == Δ is *light* (w <= Δ), w == Δ+1 is heavy;
* a settled vertex (explored == dist) is excluded from the frontier
  (strict ``dist < explored``);
* the Pallas ``bucket_scan`` kernel agrees with the jnp ``scan_bucket``
  twin on boundary inputs.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import DeltaConfig, delta_stepping, dijkstra, scan_bucket
from repro.core.backends import edge_candidates
from repro.graphs import square_lattice
from repro.graphs.structures import COOGraph, INF32
from repro.kernels.bucket_scan import bucket_scan

_IMAX = 2**31 - 1


def _path_graph(k, w):
    """0 -> 1 -> ... -> k, every weight w: dist[i] = i*w, all boundary."""
    src = jnp.arange(k, dtype=jnp.int32)
    dst = src + 1
    ww = jnp.full((k,), w, jnp.int32)
    return COOGraph(src, dst, ww, k + 1)


def test_scan_bucket_exact_multiples():
    delta = 10
    dist = jnp.array([0, 10, 20, 25, INF32], jnp.int32)
    explored = jnp.full((5,), INF32, jnp.int32)
    f, any_, nxt = scan_bucket(dist, explored, jnp.int32(0), delta=delta)
    np.testing.assert_array_equal(
        np.asarray(f), [True, False, False, False, False])
    assert bool(any_) and int(nxt) == 1         # dist 10 is bucket 1, not 0
    f, any_, nxt = scan_bucket(dist, explored, jnp.int32(1), delta=delta)
    np.testing.assert_array_equal(
        np.asarray(f), [False, True, False, False, False])
    assert int(nxt) == 2
    f, any_, nxt = scan_bucket(dist, explored, jnp.int32(2), delta=delta)
    np.testing.assert_array_equal(
        np.asarray(f), [False, False, True, True, False])
    assert int(nxt) == _IMAX                     # INF32 never opens a bucket


def test_scan_bucket_settled_vertex_excluded():
    """explored == dist means settled: strict < keeps it off the
    frontier (otherwise the light phase would never terminate)."""
    delta = 10
    dist = jnp.array([10, 10], jnp.int32)
    explored = jnp.array([10, INF32], jnp.int32)
    f, any_, _ = scan_bucket(dist, explored, jnp.int32(1), delta=delta)
    np.testing.assert_array_equal(np.asarray(f), [False, True])
    assert bool(any_)


@pytest.mark.parametrize("bucket_i", [0, 1, 3])
def test_pallas_bucket_scan_agrees_on_boundaries(bucket_i):
    delta = 10
    dist = jnp.array([0, 9, 10, 11, 20, 30, 30, INF32, 40], jnp.int32)
    explored = jnp.array(
        [0, INF32, 10, INF32, INF32, 30, INF32, INF32, INF32], jnp.int32)
    ref = scan_bucket(dist, explored, jnp.int32(bucket_i), delta=delta)
    ker = bucket_scan(dist, explored, jnp.int32(bucket_i), delta=delta,
                      backend="pallas", interpret=True)
    np.testing.assert_array_equal(np.asarray(ref[0]), np.asarray(ker[0]))
    assert bool(ref[1]) == bool(ker[1])
    assert int(ref[2]) == int(ker[2])


def test_edge_candidates_weight_equal_delta_is_light():
    delta = 10
    d_src = jnp.array([0, 0, 0, INF32], jnp.int32)
    f_src = jnp.array([True, True, True, True])
    w = jnp.array([10, 11, 9, 5], jnp.int32)
    cand, ok_light = edge_candidates(d_src, f_src, w, delta=delta, light=True)
    np.testing.assert_array_equal(
        np.asarray(ok_light), [True, False, True, False])   # w==Δ light
    _, ok_heavy = edge_candidates(d_src, f_src, w, delta=delta, light=False)
    np.testing.assert_array_equal(
        np.asarray(ok_heavy), [False, True, False, False])  # w==Δ+1 heavy
    np.testing.assert_array_equal(np.asarray(cand)[:3], [10, 11, 9])


@pytest.mark.parametrize("strategy", ["edge", "ell"])
@pytest.mark.parametrize("w,delta", [(10, 10), (10, 5), (13, 13), (1, 1)])
def test_path_distances_on_exact_boundaries(strategy, w, delta):
    """Every distance is a multiple of Δ (w divisible by Δ): one vertex
    per bucket edge, each settled exactly once."""
    k = 12
    g = _path_graph(k, w)
    res = delta_stepping(
        g, 0, DeltaConfig(delta=delta, strategy=strategy, pred_mode="none"))
    np.testing.assert_array_equal(
        np.asarray(res.dist), np.arange(k + 1) * w)
    assert not bool(res.overflow)
    # w == Δ: bucket b holds exactly vertex b; the engine must still
    # process k+1 distinct buckets, not merge boundary vertices
    if w == delta:
        assert int(res.outer_iters) == k + 1


@pytest.mark.parametrize("delta", [1, 2, 4])
def test_unit_lattice_boundary_sweep(delta):
    """Unit-weight lattice: every distance is an exact multiple of 1 and
    of any Δ dividing the hop count — the all-boundary stress the tuner's
    Δ grid hits; all Δ values must agree with Dijkstra exactly."""
    g = square_lattice(9, weighted=False)
    dref, _ = dijkstra(g, 0)
    for strategy in ("edge", "ell"):
        res = delta_stepping(
            g, 0,
            DeltaConfig(delta=delta, strategy=strategy, pred_mode="none"))
        np.testing.assert_array_equal(np.asarray(res.dist, np.int64), dref)
