"""Mesh-sharded backends (DESIGN.md §9): partition correctness, backend
routing, config validation in-process (however many devices this run
has), plus the acceptance pin on a real 8-device host mesh in a
subprocess (the forced device count must be set before JAX initializes):
``sharded_edge`` and ``sharded_ell`` bitwise-equal to the single-device
``packed`` engine on all three paper graph families."""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import (
    DeltaConfig,
    DeltaSteppingSolver,
    ShardedEdgeBackend,
    ShardedEllBackend,
    dijkstra,
    make_backend,
    resolve_n_shards,
)
from repro.graphs import partition_edges, partition_ell, watts_strogatz
from repro.graphs.structures import INF32, coo_to_csr, light_heavy_split


def _edge_multiset(src, dst, w, n):
    src, dst, w = (np.asarray(a).ravel() for a in (src, dst, w))
    real = (src < n) & (w < INF32)
    return sorted(zip(src[real].tolist(), dst[real].tolist(),
                      w[real].tolist()))


@pytest.mark.parametrize("n_shards", [1, 3, 4])
def test_partition_edges_conserves_edge_multiset(n_shards):
    g = watts_strogatz(50, 4, 0.2, seed=2)
    part = partition_edges(g, n_shards)
    assert part.n_shards == n_shards
    assert part.shard_nodes * n_shards >= g.n_nodes
    got = _edge_multiset(part.src, part.dst, part.w, g.n_nodes)
    want = _edge_multiset(g.src, g.dst, g.w, g.n_nodes)
    assert got == want
    # row ownership: every real edge sits in its source's shard
    src = np.asarray(part.src)
    for i in range(n_shards):
        real = src[i] < g.n_nodes
        assert (src[i][real] // part.shard_nodes == i).all()


@pytest.mark.parametrize("n_shards", [1, 3, 4])
def test_partition_ell_conserves_light_heavy_split(n_shards):
    g = watts_strogatz(50, 4, 0.2, seed=2)
    delta = 10
    part = partition_ell(g, n_shards, delta)
    csr = coo_to_csr(g)
    light, heavy = light_heavy_split(csr, delta)

    def block_edges(nbr, w):
        nbr, w = np.asarray(nbr), np.asarray(w)
        out = []
        for i in range(part.n_shards):
            for r in range(part.shard_nodes):       # skip sentinel row
                v = i * part.shard_nodes + r
                real = (nbr[i, r] < g.n_nodes) & (w[i, r] < INF32)
                out += [(v, int(d), int(ww))
                        for d, ww in zip(nbr[i, r][real], w[i, r][real])]
        return sorted(out)

    def csr_edges(c):
        rp, col, w = (np.asarray(a) for a in (c.row_ptr, c.col, c.w))
        return sorted(
            (v, int(col[e]), int(w[e]))
            for v in range(c.n_nodes)
            for e in range(rp[v], rp[v + 1]))

    assert block_edges(part.light_nbr, part.light_w) == csr_edges(light)
    assert block_edges(part.heavy_nbr, part.heavy_w) == csr_edges(heavy)
    assert (np.asarray(part.light_w) <= delta).sum() \
        == np.asarray(light.w).shape[0]


def test_backend_routing_and_shard_validation():
    g = watts_strogatz(60, 4, 0.1, seed=0)
    assert isinstance(
        make_backend(g, DeltaConfig(strategy="sharded_edge")),
        ShardedEdgeBackend)
    assert isinstance(
        make_backend(g, DeltaConfig(strategy="sharded_ell")),
        ShardedEllBackend)
    assert resolve_n_shards(None) >= 1
    with pytest.raises(ValueError):
        resolve_n_shards(10_000)            # more shards than devices
    with pytest.raises(ValueError):
        DeltaConfig(strategy="sharded_edge", n_shards=0)


def test_sharded_matches_oracle_in_process():
    """Whatever the device count of this process (1 in a plain run, 8
    under the CI sharded job), both sharded backends are exact."""
    g = watts_strogatz(200, 6, 0.1, seed=11)
    dref, _ = dijkstra(g, 0)
    for strategy in ("sharded_edge", "sharded_ell"):
        res = DeltaSteppingSolver(
            g, DeltaConfig(delta=10, strategy=strategy)).solve(0)
        np.testing.assert_array_equal(
            np.asarray(res.dist, np.int64), dref, err_msg=strategy)
        assert not bool(res.overflow)


def test_sharded_ell_per_shard_cap_overflow_flag():
    """A tiny per-shard cap trips the overflow flag (and only the
    flag — the engine's cap-validation layers handle the rest)."""
    g = watts_strogatz(200, 6, 0.1, seed=11)
    res = DeltaSteppingSolver(
        g, DeltaConfig(delta=1_000, strategy="sharded_ell",
                       frontier_cap=2)).solve(0)
    assert bool(res.overflow)


_ACCEPTANCE = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    from repro.compat import enable_x64
    from repro.core import DeltaConfig, DeltaSteppingSolver
    from repro.graphs import grid_map, rmat, watts_strogatz

    gm, free = grid_map(25, 31, 0.15, seed=3)
    families = {
        "smallworld": (watts_strogatz(300, 6, 0.05, seed=0), 0, 10),
        "rmat": (rmat(256, 2500, seed=2), 0, 10),
        "gamemap": (gm, int(np.flatnonzero(np.asarray(free).ravel())[0]),
                    13),
    }
    with enable_x64():
        for name, (g, src, delta) in families.items():
            base = DeltaSteppingSolver(
                g, DeltaConfig(delta=delta, pred_mode="packed")).solve(src)
            for strategy in ("sharded_edge", "sharded_ell"):
                cfg = DeltaConfig(delta=delta, strategy=strategy,
                                  pred_mode="packed", n_shards=8)
                r = DeltaSteppingSolver(g, cfg).solve(src)
                for field in ("dist", "pred"):
                    a = np.asarray(getattr(r, field))
                    b = np.asarray(getattr(base, field))
                    assert np.array_equal(a, b), (name, strategy, field)
                assert int(r.outer_iters) == int(base.outer_iters)
    print("SHARDED-ACCEPT-OK")
""")


def test_sharded_acceptance_8_device_mesh_subprocess():
    """ISSUE 3 acceptance: on an 8-device host mesh, both sharded
    backends reproduce the single-device packed engine bitwise on the
    paper's three graph families."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    out = subprocess.run([sys.executable, "-c", _ACCEPTANCE], env=env,
                         capture_output=True, text=True, timeout=600)
    assert "SHARDED-ACCEPT-OK" in out.stdout, out.stdout + out.stderr
