"""Differential test harness: the engine vs the ``core/ref.py`` heap-
Dijkstra oracle on adversarial random COO graphs — zero-weight edges,
self-loops, duplicate (parallel) edges and disconnected vertices — across
**every** backend × pred_mode × Δ combination, the mesh-sharded backends
included (n_shards = every local device: 1 in a plain run, 8 under the
CI ``sharded`` job's forced host platform) — and, at a fixed Δ, the same
cross product for every non-delta frontier policy (ρ-stepping /
radius-stepping, DESIGN.md §15).

Hypothesis drives the case generation when it is installed, with a
deterministic seed-sweep fallback otherwise (shared driver:
tests/_property_driver.py). All randomness flows through one integer
seed, and every case shares one (n_nodes, n_edges) shape so the module
compiles each backend × pred × Δ program exactly once (the drivers are
module-jitted, core.delta_stepping).

Predecessor checks: ``packed`` trees are validated always (zero weights
are safe, pack.py); ``argmin`` trees only on zero-weight-free cases —
post-hoc recovery assumes weights >= 1 (a zero-weight tie can close a
predecessor cycle; test_determinism.py documents argmin's divergences).
"""
from functools import partial

import jax
import numpy as np
import pytest

from _property_driver import (
    ALL_POLICIES, ALL_STRATEGIES, drive, null_ctx as _null)
from repro.compat import enable_x64
from repro.core import (
    DeltaConfig,
    DeltaSteppingSolver,
    dijkstra,
    walk_pred_tree,
)
from repro.graphs.structures import COOGraph, INF32

# one integer seed is the whole case (adversarial_coo expands it)
drive_seed = partial(
    drive,
    strategy=lambda st: st.integers(min_value=0, max_value=2**31 - 1),
    fallback_draw=lambda rng: int(rng.integers(0, 2**31)))


# This module compiles the single largest program population in the
# suite (backend × pred × Δ × policy); on top of the hundreds of
# executables cached by the modules that run before it, XLA's CPU
# compiler can segfault mid-compile (single-core boxes, deterministic).
# An empty cache is the state this module is validated under standalone,
# and costs nothing here: every program below is keyed on this module's
# own fixed shape and compiles fresh either way.
@pytest.fixture(scope="module", autouse=True)
def _fresh_compile_caches():
    jax.clear_caches()


# One fixed shape for every case: the shape is the jit cache key, the
# arrays are arguments — so each backend × pred × Δ program compiles once.
N, M = 32, 96

BACKENDS = ALL_STRATEGIES
PRED_MODES = ("none", "argmin", "packed")
DELTAS = (1, 7, 31)


def adversarial_coo(seed: int):
    """One adversarial instance from one seed: edges confined to the
    first ``k`` vertices (the rest are guaranteed-disconnected), forced
    self-loop, forced duplicate edges with differing weights, and
    zero-weight edges on odd seeds."""
    rng = np.random.default_rng(seed)
    k = int(rng.integers(2, N + 1))
    src = rng.integers(0, k, size=M).astype(np.int64)
    dst = rng.integers(0, k, size=M).astype(np.int64)
    w_lo = int(seed % 2)                       # zero weights on odd seeds
    w = rng.integers(w_lo, 21, size=M).astype(np.int64)
    src[0] = dst[0] = int(rng.integers(0, k))  # self-loop
    src[1], dst[1] = src[2], dst[2]            # duplicate edge pair,
    w[1] = int(rng.integers(w_lo, 21))         # independent weights
    g = COOGraph(src=src.astype(np.int32), dst=dst.astype(np.int32),
                 w=w.astype(np.int32), n_nodes=N)
    source = int(rng.integers(0, k))
    return g, source, w_lo


def _solve(g, source, strategy, pred_mode, delta, policy="delta"):
    # rho pinned small so the ρ-batches actually split the frontier on
    # N=32 graphs (the interesting regime; rho >= N degenerates to one
    # Dijkstra-like giant step only when every vertex is pending)
    cfg = DeltaConfig(delta=delta, strategy=strategy, pred_mode=pred_mode,
                      interpret=True, policy=policy, rho=4)
    res = DeltaSteppingSolver(g, cfg).solve(source)
    assert not bool(res.overflow), (strategy, pred_mode, delta, policy)
    return res


@drive_seed(max_examples=25, fallback_examples=10)
def test_all_backend_pred_delta_combos_match_oracle(seed):
    """Exact distance equality against heap Dijkstra for the full
    backend × pred_mode × Δ cross product on one adversarial graph, and
    sentinel handling for its disconnected tail: INF32 distance, -1
    pred. Pred trees are walked end-to-end where the mode guarantees
    validity."""
    g, source, w_lo = adversarial_coo(seed)
    dref, _ = dijkstra(g, source)
    unreachable = dref >= int(INF32)
    for delta in DELTAS:
        for strategy in BACKENDS:
            for pred_mode in PRED_MODES:
                ctx = enable_x64() if pred_mode == "packed" else _null()
                with ctx:
                    res = _solve(g, source, strategy, pred_mode, delta)
                    dist = np.asarray(res.dist, np.int64)
                    pred = np.asarray(res.pred)
                tag = (seed, strategy, pred_mode, delta)
                np.testing.assert_array_equal(dist, dref, err_msg=str(tag))
                if pred_mode == "none":
                    continue
                assert (pred[unreachable] == -1).all(), tag
                assert pred[source] == -1, tag
                if pred_mode == "packed" or w_lo >= 1:
                    assert walk_pred_tree(g, source, dist, pred), tag


@drive_seed(max_examples=40, fallback_examples=16)
def test_backends_agree_bitwise_on_adversarial_graphs(seed):
    """All backends run the same bucket schedule, so distances *and*
    iteration counters must agree bitwise across the whole backend axis
    (single-device and mesh-sharded) — not just match the oracle."""
    g, source, _ = adversarial_coo(seed)
    delta = DELTAS[seed % len(DELTAS)]
    base = _solve(g, source, "edge", "argmin", delta)
    for strategy in BACKENDS[1:]:
        res = _solve(g, source, strategy, "argmin", delta)
        np.testing.assert_array_equal(
            np.asarray(res.dist), np.asarray(base.dist), err_msg=strategy)
        np.testing.assert_array_equal(
            np.asarray(res.pred), np.asarray(base.pred), err_msg=strategy)
        assert int(res.outer_iters) == int(base.outer_iters), strategy


@drive_seed(max_examples=10, fallback_examples=5)
def test_policy_family_full_cross_product_matches_oracle(seed):
    """The frontier-policy family (ρ-stepping, radius-stepping,
    DESIGN.md §15) pinned to the heap-Dijkstra oracle across the full
    backend × pred_mode cross product on the same adversarial corpus —
    dist AND pred (packed (cost, pred) words included) must be exact.
    Δ is fixed: the non-delta policies do not bucket, Δ only moves
    their light/heavy phase split."""
    g, source, w_lo = adversarial_coo(seed)
    dref, _ = dijkstra(g, source)
    unreachable = dref >= int(INF32)
    for policy in ALL_POLICIES[1:]:             # delta covered above
        for strategy in BACKENDS:
            for pred_mode in PRED_MODES:
                ctx = enable_x64() if pred_mode == "packed" else _null()
                with ctx:
                    res = _solve(g, source, strategy, pred_mode, 7,
                                 policy=policy)
                    dist = np.asarray(res.dist, np.int64)
                    pred = np.asarray(res.pred)
                tag = (seed, policy, strategy, pred_mode)
                np.testing.assert_array_equal(dist, dref, err_msg=str(tag))
                if pred_mode == "none":
                    continue
                assert (pred[unreachable] == -1).all(), tag
                assert pred[source] == -1, tag
                if pred_mode == "packed" or w_lo >= 1:
                    assert walk_pred_tree(g, source, dist, pred), tag


@drive_seed(max_examples=15, fallback_examples=6)
def test_rho_sweep_matches_oracle(seed):
    """ρ-stepping is exact for *every* batch size: ρ=1 (Dijkstra-like,
    one distance class per round), small batches, and ρ >= |V| (one
    giant pending sweep per round)."""
    g, source, _ = adversarial_coo(seed)
    dref, _ = dijkstra(g, source)
    for rho in (1, 3, N, 4 * N):
        cfg = DeltaConfig(delta=7, strategy="edge", pred_mode="none",
                          policy="rho", rho=rho)
        res = DeltaSteppingSolver(g, cfg).solve(source)
        np.testing.assert_array_equal(
            np.asarray(res.dist, np.int64), dref, err_msg=f"rho={rho}")


@drive_seed(max_examples=20, fallback_examples=8)
def test_solve_many_lanes_match_single_solves(seed):
    """Batched multi-source lanes are bitwise identical to per-source
    solves on adversarial graphs (includes disconnected sources)."""
    g, source, _ = adversarial_coo(seed)
    srcs = np.asarray([source, 0, N - 1], np.int32)  # N-1 often isolated
    for strategy in ("edge", "sharded_edge"):
        solver = DeltaSteppingSolver(
            g, DeltaConfig(delta=7, strategy=strategy, pred_mode="argmin"))
        many = solver.solve_many(srcs)
        for i, s in enumerate(srcs):
            one = solver.solve(int(s))
            np.testing.assert_array_equal(
                np.asarray(many.dist[i]), np.asarray(one.dist),
                err_msg=f"{strategy} lane {i}")
            np.testing.assert_array_equal(
                np.asarray(many.pred[i]), np.asarray(one.pred),
                err_msg=f"{strategy} lane {i}")


def test_empty_graph_every_backend():
    """M=0 edge case (separate shape): only the source is reachable."""
    z = np.zeros((0,), np.int32)
    g = COOGraph(src=z, dst=z, w=z, n_nodes=5)
    for strategy in ("edge", "ell", "fused", "sharded_edge", "sharded_ell",
                     "sharded_fused"):
        res = _solve(g, 2, strategy, "argmin", 7)
        dist = np.asarray(res.dist, np.int64)
        assert dist[2] == 0
        assert (dist[[0, 1, 3, 4]] == int(INF32)).all()
        assert (np.asarray(res.pred) == -1).all()

