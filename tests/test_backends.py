"""Unified relaxation-backend engine: the edge / ell / pallas backends
all run through the single loop driver (core.delta_stepping._run_backend)
and must agree with the Dijkstra oracle on the paper's graph classes.
The pallas backend (interpret mode on CPU) is the path that exercises
kernels/ell_relax + kernels/bucket_scan, and kernels/grid_relax on the
game-map class."""
import numpy as np
import pytest

from repro.core import (
    DeltaConfig,
    DeltaSteppingSolver,
    EdgeBackend,
    EllBackend,
    GridPallasBackend,
    PallasEllBackend,
    dijkstra,
    make_backend,
)
from repro.graphs import grid_map, rmat, watts_strogatz


def _graphs():
    g, free = grid_map(25, 31, 0.15, seed=3)
    return {
        "smallworld": (watts_strogatz(300, 6, 0.05, seed=0), None),
        "rmat": (rmat(256, 2500, seed=2), None),
        "gamemap": (g, free),
    }


GRAPHS = _graphs()


def _free_src(free):
    return int(np.flatnonzero(np.asarray(free).ravel())[0])


@pytest.mark.parametrize("name", sorted(GRAPHS))
@pytest.mark.parametrize("strategy", ["edge", "ell", "pallas"])
def test_backend_matches_dijkstra(name, strategy):
    g, free = GRAPHS[name]
    delta = 13 if name == "gamemap" else 10
    src = 0 if name != "gamemap" else _free_src(free)
    cfg = DeltaConfig(delta=delta, strategy=strategy, interpret=True)
    solver = DeltaSteppingSolver(
        g, cfg, free_mask=free if strategy == "pallas" else None)
    res = solver.solve(src)
    dref, _ = dijkstra(g, src)
    np.testing.assert_array_equal(np.asarray(res.dist, np.int64), dref)
    assert not bool(res.overflow)


def test_backend_routing():
    g, free = GRAPHS["gamemap"]
    assert isinstance(
        make_backend(g, DeltaConfig(strategy="edge")), EdgeBackend)
    assert isinstance(
        make_backend(g, DeltaConfig(strategy="ell")), EllBackend)
    assert isinstance(
        make_backend(g, DeltaConfig(strategy="pallas")), PallasEllBackend)
    assert isinstance(
        make_backend(g, DeltaConfig(strategy="pallas"), free_mask=free),
        GridPallasBackend)
    with pytest.raises(ValueError):
        make_backend(g, DeltaConfig(strategy="pallas", pred_mode="packed"),
                     free_mask=free)


@pytest.mark.parametrize("name", sorted(GRAPHS))
def test_backends_agree_bitwise(name):
    """All backends run the same bucket schedule, so distances and
    iteration counters must agree exactly, not just in the limit."""
    g, free = GRAPHS[name]
    delta = 13 if name == "gamemap" else 10
    src = 0 if name != "gamemap" else _free_src(free)
    results = {}
    for strategy in ("edge", "ell", "pallas"):
        cfg = DeltaConfig(delta=delta, strategy=strategy, interpret=True)
        solver = DeltaSteppingSolver(
            g, cfg, free_mask=free if strategy == "pallas" else None)
        results[strategy] = solver.solve(src)
    base = results["edge"]
    for strategy, res in results.items():
        np.testing.assert_array_equal(np.asarray(res.dist),
                                      np.asarray(base.dist), strategy)
        assert int(res.outer_iters) == int(base.outer_iters), strategy


def test_pallas_ell_backend_with_frontier_cap():
    g, _ = GRAPHS["smallworld"]
    dref, _ = dijkstra(g, 0)
    res = DeltaSteppingSolver(
        g, DeltaConfig(delta=10, strategy="pallas", interpret=True,
                       frontier_cap=g.n_nodes)).solve(0)
    np.testing.assert_array_equal(np.asarray(res.dist, np.int64), dref)
    # a tiny cap must trip the overflow flag through the pallas path too
    res2 = DeltaSteppingSolver(
        g, DeltaConfig(delta=100, strategy="pallas", interpret=True,
                       frontier_cap=2)).solve(0)
    assert bool(res2.overflow)