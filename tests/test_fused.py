"""Fused frontier kernel (DESIGN.md §12): the single-pallas_call
scan + on-chip compaction + ELL row gather, its jnp oracle twin, and the
``fused`` / ``sharded_fused`` strategies built on it.

Layers under test, bottom up:

* kernel vs oracle — ``frontier_relax(backend="pallas", interpret=True)``
  bitwise-equals ``backend="ref"`` on random instances, including the
  exact-fill tile (population == cap) and overflow (population > cap);
* engine — ``fused`` bitwise-equals ``edge`` (dist, pred, both
  iteration counters: the atomic-iteration loop must not change the
  schedule), with the crafted exact-fill frontier raising no overflow
  and the all-heavy (zero-width light block) graph routing through the
  D == 0 oracle path;
* façade — a scratch-capacity overflow routes through the one fallback
  point, ``Plan.solve(fallback=True)``: demotion, never a wrong answer;
* warm repair — a fused plan's weight-update resolve stays bitwise
  cold-identical (the capped repair twin is itself fused);
* mesh — ``sharded_fused`` on a real 8-device host mesh reproduces the
  single-device ``fused`` engine bitwise (subprocess: the forced device
  count must be set before JAX initializes).
"""
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.api import Engine, SingleSource
from repro.compat import enable_x64
from repro.core import DeltaConfig, DeltaSteppingSolver, dijkstra
from repro.graphs import watts_strogatz
from repro.graphs.structures import COOGraph, INF32
from repro.kernels.frontier_relax import frontier_relax, frontier_relax_ref


@pytest.fixture(scope="module", autouse=True)
def _fresh_executable_caches():
    # In a full tier-1 run ~200 tests' worth of live compiled executables
    # precede this module, and XLA:CPU's JIT segfaults compiling the
    # oracle's first (tiny) reduction under that accumulation — each half
    # of the preceding suite passes alone, only the full prefix crashes.
    # Dropping the accumulated executables before this module compiles
    # keeps the process under the limit; later modules just recompile.
    jax.clear_caches()
    yield


def _solve(g, src, strategy, **kw):
    kw.setdefault("delta", 10)
    return DeltaSteppingSolver(g, DeltaConfig(strategy=strategy, **kw)
                               ).solve(src)


def _assert_bitwise(a, b, tag):
    np.testing.assert_array_equal(
        np.asarray(a.dist), np.asarray(b.dist), err_msg=f"{tag}: dist")
    np.testing.assert_array_equal(
        np.asarray(a.pred), np.asarray(b.pred), err_msg=f"{tag}: pred")
    assert int(a.outer_iters) == int(b.outer_iters), tag
    assert int(a.inner_iters) == int(b.inner_iters), tag


# ---------------------------------------------------------------------------
# kernel vs oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(6))
def test_kernel_matches_oracle_random(seed):
    """Bitwise kernel/oracle parity on random (dist, explored, ELL)
    instances; caps drawn to cover under-fill, exact-fill and overflow
    of the compaction scratch."""
    rng = np.random.default_rng(seed)
    s = int(rng.integers(2, 400))
    d_w = int(rng.integers(1, 5))
    delta = int(rng.integers(1, 30))
    base = int(rng.integers(0, 3)) * s
    dist = np.where(rng.random(s) < 0.3, int(INF32),
                    rng.integers(0, 8 * delta, size=s)).astype(np.int32)
    explored = np.where(rng.random(s) < 0.5, int(INF32),
                        dist + rng.integers(0, 2, size=s)).astype(np.int32)
    nbr = rng.integers(0, s, size=(s + 1, d_w)).astype(np.int32)
    w = rng.integers(0, 20, size=(s + 1, d_w)).astype(np.int32)
    nbr[s] = s
    w[s] = int(INF32)
    bucket_i = int(rng.integers(0, 4))
    pop = int(((dist < int(INF32)) & (dist // delta == bucket_i)
               & (dist < explored)).sum())
    for cap in sorted({1, max(1, pop), pop + 3}):
        got = frontier_relax(dist, explored, bucket_i, nbr, w, delta=delta,
                             cap=cap, base=base, backend="pallas",
                             interpret=True)
        want = frontier_relax_ref(dist, explored, bucket_i, nbr, w,
                                  delta=delta, cap=cap, base=base)
        tag = (seed, cap, pop)
        for name, a, b in zip(("fidx", "rows_n", "rows_w"), got, want):
            np.testing.assert_array_equal(
                np.asarray(a), np.asarray(b), err_msg=f"{tag}: {name}")
        assert int(got[3]) == int(want[3]) == pop, tag      # count
        assert bool(got[4]) == bool(want[4]) == (pop > 0), tag
        assert int(got[5]) == int(want[5]), tag             # next bucket


def test_kernel_zero_width_block_routes_to_oracle():
    """D == 0 ELL blocks (a side of the light/heavy split with no
    edges) have no TPU layout — the wrapper must dispatch the oracle
    and still report the scan outputs."""
    dist = np.asarray([0, 5, int(INF32)], np.int32)
    explored = np.full(3, int(INF32), np.int32)
    nbr = np.zeros((4, 0), np.int32)
    w = np.zeros((4, 0), np.int32)
    fidx, rows_n, rows_w, count, any_, nxt = frontier_relax(
        dist, explored, 0, nbr, w, delta=10, cap=2, backend="pallas",
        interpret=True)
    assert rows_n.shape == (2, 0) and rows_w.shape == (2, 0)
    assert int(count) == 2 and bool(any_)
    np.testing.assert_array_equal(np.asarray(fidx), [0, 1])


# ---------------------------------------------------------------------------
# engine: fused vs edge
# ---------------------------------------------------------------------------

def _star(k: int) -> COOGraph:
    """Source 0 fanning out to 1..k with weight 2: under Δ=2 the source
    settles alone in bucket 0 and the k leaves form bucket 1 by
    themselves — that bucket's frontier *and* settled set are exactly
    the k leaves, so a scratch of cap == k fills every slot in both the
    kernel compaction and the heavy-pass compaction without spilling."""
    src = np.zeros(k, np.int32)
    dst = np.arange(1, k + 1, dtype=np.int32)
    w = np.full(k, 2, np.int32)
    return COOGraph(src=src, dst=dst, w=w, n_nodes=k + 1)


@pytest.mark.parametrize("kernel_kw", [dict(interpret=True), dict()],
                         ids=["kernel-interpret", "ref-twin"])
def test_fused_bitwise_equals_edge(kernel_kw):
    """Both fused execution paths (interpret-mode kernel; CPU oracle
    twin) reproduce ``edge`` bitwise — dist, packed pred words and the
    outer/inner counters (the atomic-iteration loop runs the same
    bucket schedule, DESIGN.md §12)."""
    g = watts_strogatz(300, 6, 0.05, seed=1)
    with enable_x64():
        base = _solve(g, 0, "edge", pred_mode="packed")
        res = _solve(g, 0, "fused", pred_mode="packed", **kernel_kw)
    _assert_bitwise(res, base, kernel_kw)
    assert not bool(res.overflow)


def test_exact_fill_frontier_no_overflow():
    """Regression: a frontier that exactly fills the compaction scratch
    (population == cap) is complete — right answer, no overflow flag.
    ``count`` must compare with ``>``, not ``>=``."""
    k = 13
    g = _star(k)
    base = _solve(g, 0, "edge", delta=2, pred_mode="argmin")
    for kw in (dict(interpret=True), dict()):
        res = _solve(g, 0, "fused", delta=2, frontier_cap=k,
                     pred_mode="argmin", **kw)
        _assert_bitwise(res, base, kw)
        assert not bool(res.overflow), kw


def test_overflow_flag_one_past_fill():
    """One frontier member past the scratch (population == cap + 1)
    must raise the overflow flag — the truncated wave still drains the
    bucket, but the engine must report the capacity breach so the
    façade can demote."""
    k = 13
    g = _star(k)
    res = _solve(g, 0, "fused", delta=2, frontier_cap=k - 1,
                 pred_mode="argmin", interpret=True)
    assert bool(res.overflow)


def test_all_heavy_graph_zero_width_light_block():
    """Every weight > Δ: the light ELL block is zero-width and every
    relaxation happens in heavy passes — the fused driver loop must
    still settle buckets bitwise like ``edge``."""
    g = watts_strogatz(120, 4, 0.1, seed=5)
    g = COOGraph(src=g.src, dst=g.dst,
                 w=np.asarray(g.w, np.int32) + 50, n_nodes=g.n_nodes)
    base = _solve(g, 0, "edge", delta=3, pred_mode="argmin")
    res = _solve(g, 0, "fused", delta=3, pred_mode="argmin", interpret=True)
    _assert_bitwise(res, base, "all-heavy")


# ---------------------------------------------------------------------------
# façade: overflow demotes, never a wrong answer
# ---------------------------------------------------------------------------

def test_scratch_overflow_demotes_through_fallback():
    """A fused plan whose scratch cap is far too small trips overflow;
    with ``fallback=True`` the façade re-answers on the full-width twin
    and demotes permanently — the caller never sees a wrong answer."""
    g = watts_strogatz(300, 6, 0.05, seed=0)
    dref, _ = dijkstra(g, 0)
    cfg = DeltaConfig(delta=100, strategy="fused", frontier_cap=3,
                      interpret=True)
    plan = Engine(g, cfg).plan(fallback=True)
    res = plan.solve(SingleSource(0))
    assert res.telemetry.fallback
    np.testing.assert_array_equal(np.asarray(res.dist, np.int64), dref)
    assert plan.explain()["fallback_taken"]
    # parity default: the raw capped run only reports the flag
    raw = Engine(g, cfg).plan().solve(SingleSource(0))
    assert bool(np.asarray(raw.telemetry.overflow))
    assert not raw.telemetry.fallback


# ---------------------------------------------------------------------------
# warm repair: fused twin keeps the cold identity
# ---------------------------------------------------------------------------

def test_fused_warm_resolve_bitwise_cold_identity():
    """Weight updates on a fused plan resolve warm through a capped
    *fused* repair twin — and stay bitwise identical to a cold solve on
    the updated graph (DESIGN.md §11 lemma, fused instantiation)."""
    g = watts_strogatz(200, 6, 0.05, seed=7)
    cfg = DeltaConfig(delta=10, strategy="fused", interpret=True)
    plan = Engine(g, cfg).plan()
    plan.solve(SingleSource(0))
    edge_ids = np.asarray([3, 41, 97], np.int64)
    new_w = np.asarray(g.w)[edge_ids] + 7
    warm = plan.update(edge_ids, new_w).resolve(warm=True)
    g2 = COOGraph(src=g.src, dst=g.dst,
                  w=np.asarray(g.w).copy(), n_nodes=g.n_nodes)
    w2 = np.asarray(g2.w)
    w2[edge_ids] = new_w
    cold = Engine(
        COOGraph(src=g2.src, dst=g2.dst, w=w2.astype(np.int32),
                 n_nodes=g2.n_nodes), cfg).plan().solve(SingleSource(0))
    np.testing.assert_array_equal(np.asarray(warm.dist),
                                  np.asarray(cold.dist))


# ---------------------------------------------------------------------------
# mesh acceptance: sharded_fused @ 8 == fused @ 1
# ---------------------------------------------------------------------------

_ACCEPTANCE = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    from repro.compat import enable_x64
    from repro.core import DeltaConfig, DeltaSteppingSolver
    from repro.graphs import rmat, watts_strogatz

    families = {
        "smallworld": (watts_strogatz(300, 6, 0.05, seed=0), 0, 10),
        "rmat": (rmat(256, 2500, seed=2), 0, 10),
    }
    with enable_x64():
        for name, (g, src, delta) in families.items():
            base = DeltaSteppingSolver(
                g, DeltaConfig(delta=delta, strategy="fused",
                               pred_mode="packed", interpret=True)
            ).solve(src)
            for kw in (dict(interpret=True), dict()):
                cfg = DeltaConfig(delta=delta, strategy="sharded_fused",
                                  pred_mode="packed", n_shards=8, **kw)
                r = DeltaSteppingSolver(g, cfg).solve(src)
                for field in ("dist", "pred"):
                    a = np.asarray(getattr(r, field))
                    b = np.asarray(getattr(base, field))
                    assert np.array_equal(a, b), (name, kw, field)
                assert int(r.outer_iters) == int(base.outer_iters)
                assert int(r.inner_iters) == int(base.inner_iters)
    print("FUSED-ACCEPT-OK")
""")


def test_sharded_fused_acceptance_8_device_mesh_subprocess():
    """ISSUE 6 acceptance: ``sharded_fused`` at shards=8 is bitwise
    identical (packed dist+pred words, both counters) to ``fused`` at
    shards=1 on the paper graph families, for both the interpret-mode
    kernel and the oracle-twin execution paths."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    out = subprocess.run([sys.executable, "-c", _ACCEPTANCE], env=env,
                         capture_output=True, text=True, timeout=600)
    assert "FUSED-ACCEPT-OK" in out.stdout, out.stdout + out.stderr
