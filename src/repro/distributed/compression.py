"""Gradient compression for data-parallel reduction at 1000+-node scale.

Two composable schemes with **error feedback** (the residual of the
compression is carried to the next step, which keeps SGD convergence —
Karimireddy et al. 2019):

* int8 quantization — per-tensor scale, 4× volume reduction on fp32
  all-reduce traffic; deterministic.
* top-k sparsification — keep the k largest-magnitude entries per
  tensor; with k = 1% this is the classic Deep Gradient Compression
  setting.

Both are pure functions usable inside jit; the trainer applies them
before the (implicit or explicit) cross-replica reduction and folds the
residual into the next step's gradients.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def quantize_int8(g):
    """g → (q int8, scale f32)."""
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def topk_sparsify(g, frac: float):
    """Keep the top-|frac| fraction of entries (by magnitude); returns the
    sparsified dense tensor and the residual (error feedback)."""
    flat = g.reshape(-1)
    k = max(1, int(flat.shape[0] * frac))
    thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
    mask = (jnp.abs(flat) >= thresh).astype(g.dtype)
    kept = (flat * mask).reshape(g.shape)
    return kept, g - kept


def compress_grads(grads, residuals, *, scheme: str = "int8",
                   topk_frac: float = 0.01) -> Tuple:
    """Apply error-feedback compression to a gradient pytree.

    Returns (compressed_grads, new_residuals). ``residuals`` may be None
    on the first step."""
    if residuals is None:
        residuals = jax.tree.map(jnp.zeros_like, grads)
    fed = jax.tree.map(lambda g, r: g + r, grads, residuals)
    if scheme == "int8":
        def comp(g):
            q, s = quantize_int8(g)
            dq = dequantize_int8(q, s)
            return dq, g - dq
    elif scheme == "topk":
        def comp(g):
            return topk_sparsify(g, topk_frac)
    elif scheme == "none":
        def comp(g):
            return g, jnp.zeros_like(g)
    else:
        raise ValueError(scheme)
    pairs = jax.tree.map(comp, fed)
    is_pair = lambda t: isinstance(t, tuple)
    comp_g = jax.tree.map(lambda t: t[0], pairs, is_leaf=is_pair)
    new_res = jax.tree.map(lambda t: t[1], pairs, is_leaf=is_pair)
    return comp_g, new_res
