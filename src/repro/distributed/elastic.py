"""Elastic scaling: re-mesh a live training state when the device pool
changes (node failure shrinks it, recovery grows it).

The state pytree is resharded by ``device_put`` onto the new mesh with
the same logical PartitionSpecs — legal whenever the new axis sizes
still divide (or pad) the sharded dimensions. On a real cluster this is
driven by the coordinator's failure detector; here ``plan_remesh``
computes the new mesh shape and ``remesh`` executes the transfer, and
the trainer wires it to its failure-injection hook so the path is
exercised in tests.
"""
from __future__ import annotations

from typing import Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec


def plan_remesh(n_devices: int, model_parallel: int) -> Tuple[int, int]:
    """Largest (data, model) grid that fits the surviving device count,
    preserving the model-parallel degree (weights must keep fitting)."""
    if n_devices < model_parallel:
        raise ValueError(
            f"cannot keep model_parallel={model_parallel} with only "
            f"{n_devices} devices")
    return (n_devices // model_parallel, model_parallel)


def make_mesh_from(devices, shape, names=("data", "model")) -> Mesh:
    import numpy as np
    n = shape[0] * shape[1]
    return Mesh(np.asarray(devices[:n]).reshape(shape), names)


def remesh(tree, spec_tree, new_mesh: Mesh):
    """device_put every leaf onto ``new_mesh`` with its PartitionSpec."""
    def put(x, spec):
        return jax.device_put(x, NamedSharding(new_mesh, spec))
    if isinstance(spec_tree, PartitionSpec):
        return jax.tree.map(lambda x: put(x, spec_tree), tree)
    return jax.tree.map(put, tree, spec_tree)
