from repro.distributed.compression import (
    compress_grads,
    dequantize_int8,
    quantize_int8,
    topk_sparsify,
)
from repro.distributed.elastic import make_mesh_from, plan_remesh, remesh

__all__ = ["compress_grads", "quantize_int8", "dequantize_int8",
           "topk_sparsify", "plan_remesh", "remesh", "make_mesh_from"]
