"""Render dryrun_reports.json into the EXPERIMENTS.md roofline tables.

    PYTHONPATH=src python -m repro.analysis.report_md dryrun_reports.json
"""
from __future__ import annotations

import json
import sys


def fmt_bytes(b):
    return f"{b / 2**30:.2f}"


def fmt_s(x):
    if x == 0:
        return "0"
    return f"{x:.2e}"


def table(reports, mesh):
    rows = [r for r in reports if r["mesh"] == mesh]
    out = [
        "| arch | shape | C (s) | M (s) | X (s) | bound | HBM GiB | fits "
        "| useful | roofline |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(r['compute_s'])} | "
            f"{fmt_s(r['memory_s'])} | {fmt_s(r['collective_s'])} | "
            f"{r['dominant'][:4]} | {fmt_bytes(r['hbm_bytes_per_dev'])} | "
            f"{'✓' if r['fits_hbm'] else '✗'} | "
            f"{r['useful_ratio']:.1%} | {r['peak_fraction']:.1%} |")
    return "\n".join(out)


def collectives_summary(reports):
    out = ["| arch | shape | mesh | AR | AG | RS | A2A | CP | wire GiB/dev |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in reports:
        by = r["collectives"]["by_op"]

        def cnt(op):
            return by.get(op, {}).get("count", 0)

        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{cnt('all-reduce')} | {cnt('all-gather')} | "
            f"{cnt('reduce-scatter')} | {cnt('all-to-all')} | "
            f"{cnt('collective-permute')} | "
            f"{fmt_bytes(r['collectives']['total']['wire_bytes'])} |")
    return "\n".join(out)


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else "dryrun_reports.json"
    with open(path) as f:
        reports = json.load(f)
    print("### Single-pod 16×16 (256 chips)\n")
    print(table(reports, "16x16"))
    print("\n### Multi-pod 2×16×16 (512 chips)\n")
    print(table(reports, "2x16x16"))
    print("\n### Collective op counts (per compiled step, per device)\n")
    print(collectives_summary([r for r in reports if r["mesh"] == "2x16x16"]))


if __name__ == "__main__":
    main()
