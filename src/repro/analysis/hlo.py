"""Optimized-HLO text parsing: collective traffic extraction.

``compiled.as_text()`` of an SPMD-partitioned module is per-partition:
every shape is the per-device shard, so operand sizes here are
**bytes per device per step**. ``cost_analysis`` is likewise
per-partition; roofline.py documents the chips multiplication.

Wire-traffic model per collective (ring algorithms, (P−1)/P ≈ 1):
  all-reduce         2 × operand bytes   (reduce-scatter + all-gather)
  reduce-scatter     1 × operand bytes
  all-gather         1 × result bytes    (operand is the local shard)
  all-to-all         1 × operand bytes
  collective-permute 1 × operand bytes
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")

_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_OP_RE = re.compile(
    r"=\s*(?P<result>.*?)\s*"
    r"(?P<op>" + "|".join(_COLLECTIVES) + r")(?:-start|-done)?\(")

_WIRE_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
                "all-to-all": 1.0, "collective-permute": 1.0}


def _shape_bytes(text: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_stats(hlo_text: str) -> Dict:
    """Returns per-collective-type {count, operand_bytes, wire_bytes} and
    totals, all per-device per-step."""
    stats = defaultdict(lambda: {"count": 0, "operand_bytes": 0,
                                 "wire_bytes": 0})
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        op = m.group("op")
        if "-done(" in line:      # async pair: count the -start only
            continue
        args = line[m.end():].split("),")[0]
        operand_bytes = _shape_bytes(args)
        result_bytes = _shape_bytes(m.group("result"))
        if operand_bytes == 0:
            # optimized HLO often omits operand type annotations —
            # fall back to the result shape (exact for all-reduce /
            # all-to-all / permute; undercounts reduce-scatter by ~P)
            operand_bytes = result_bytes
        basis = result_bytes if op == "all-gather" else operand_bytes
        stats[op]["count"] += 1
        stats[op]["operand_bytes"] += operand_bytes
        stats[op]["wire_bytes"] += int(basis * _WIRE_FACTOR[op])
    total = {
        "count": sum(s["count"] for s in stats.values()),
        "operand_bytes": sum(s["operand_bytes"] for s in stats.values()),
        "wire_bytes": sum(s["wire_bytes"] for s in stats.values()),
    }
    return {"by_op": dict(stats), "total": total}


def count_op(hlo_text: str, opname: str) -> int:
    return len(re.findall(rf"\b{re.escape(opname)}\(", hlo_text))
