"""Roofline-term derivation from a compiled dry-run artifact.

Target hardware: TPU v5e — 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s/link ICI (constants below).

``compiled.cost_analysis()`` and ``compiled.as_text()`` of an SPMD
module are per-partition, so with

    flops_dev, bytes_dev, wire_dev  = per-device per-step quantities

the three terms (seconds, global step time lower bounds) are

    compute    = flops_dev / peak_flops        (= HLO_FLOPs/(chips·peak))
    memory     = bytes_dev / hbm_bw            (= HLO_bytes/(chips·bw))
    collective = wire_dev  / link_bw           (= coll_bytes/(chips·link))

(the chips cancel because the per-partition module already divides the
global work by the device count). MODEL_FLOPS uses 6·N·D (train) or
2·N·D (forward-only), with N = active params for MoE; the ratio
MODEL_FLOPS / (flops_dev × chips) exposes remat/padding/redundancy
waste.
"""
from __future__ import annotations

import dataclasses
import json

from repro.analysis.hlo import collective_stats

V5E = {
    "peak_flops": 197e12,    # bf16 FLOP/s per chip
    "hbm_bw": 819e9,         # bytes/s per chip
    "link_bw": 50e9,         # bytes/s per ICI link
}


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    n_devices: int
    flops_per_dev: float
    bytes_per_dev: float
    wire_bytes_per_dev: float
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float
    useful_ratio: float          # MODEL_FLOPS / global HLO flops
    peak_fraction: float         # compute_s / max(term) — roofline fraction
    collectives: dict
    memory_analysis: dict
    note: str = ""

    def to_json(self):
        return dataclasses.asdict(self)

    def summary(self) -> str:
        return (f"{self.arch:>22s} {self.shape:>13s} {self.mesh:>5s} | "
                f"C {self.compute_s:9.3e}s M {self.memory_s:9.3e}s "
                f"X {self.collective_s:9.3e}s -> {self.dominant:10s} | "
                f"useful {self.useful_ratio:6.1%} roofline "
                f"{self.peak_fraction:6.1%}")


def _mem_analysis_dict(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception as e:                        # backend-dependent
        return {"error": str(e)}
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes",
              "alias_size_in_bytes"):
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    if not out:
        out["repr"] = str(ma)
    return out


def analyze(compiled, *, arch: str, shape: str, mesh_name: str,
            n_devices: int, model_flops: float, hw: dict = V5E,
            note: str = "") -> RooflineReport:
    from repro.compat import cost_analysis_dict
    cost = cost_analysis_dict(compiled)
    flops_dev = float(cost.get("flops", 0.0))
    bytes_dev = float(cost.get("bytes accessed", 0.0))
    hlo = compiled.as_text()
    coll = collective_stats(hlo)
    wire_dev = float(coll["total"]["wire_bytes"])

    compute_s = flops_dev / hw["peak_flops"]
    memory_s = bytes_dev / hw["hbm_bw"]
    collective_s = wire_dev / hw["link_bw"]
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    global_flops = flops_dev * n_devices
    useful = model_flops / global_flops if global_flops else 0.0
    bound = max(terms.values())
    peak_fraction = (compute_s / bound) if bound > 0 else 0.0

    return RooflineReport(
        arch=arch, shape=shape, mesh=mesh_name, n_devices=n_devices,
        flops_per_dev=flops_dev, bytes_per_dev=bytes_dev,
        wire_bytes_per_dev=wire_dev, compute_s=compute_s, memory_s=memory_s,
        collective_s=collective_s, dominant=dominant,
        model_flops=model_flops, useful_ratio=useful,
        peak_fraction=peak_fraction, collectives=coll,
        memory_analysis=_mem_analysis_dict(compiled), note=note)


def estimate_model_flops(family: str, cfg, shape) -> float:
    """Napkin MODEL_FLOPS per step (global), per family."""
    if family == "lm":
        n_active = cfg.n_active_params()
        if shape.kind == "train":
            tokens = shape.global_batch * shape.seq_len
            return 6.0 * n_active * tokens
        if shape.kind == "prefill":
            tokens = shape.global_batch * shape.seq_len
            return 2.0 * n_active * tokens
        # decode: one token per sequence + attention over the cache
        attn = (2.0 * 2.0 * cfg.n_layers * cfg.n_kv_heads * cfg.head_dim_
                * shape.seq_len * shape.global_batch)
        return 2.0 * n_active * shape.global_batch + attn
    if family == "gnn":
        n = shape.extra("n_nodes")
        e = shape.extra("n_edges")
        b = shape.extra("batch", 1)
        if shape.name == "minibatch_lg":
            bn = shape.extra("batch_nodes")
            f = shape.extra("fanout")
            n = bn * (1 + f[0] + f[0] * f[1])
            e = bn * f[0] * (1 + f[1])
        d = cfg.head_hidden()
        # per layer: node transform (N·d_in·d_out GEMM) + edge traffic
        node_flops = 2.0 * n * b * max(cfg.d_in, d) * d
        edge_flops = 4.0 * e * b * d
        return 3.0 * cfg.n_layers * (node_flops + edge_flops)  # fwd+bwd
    if family == "recsys":
        b = shape.global_batch
        mlp = 0
        dims = (cfg.n_dense,) + cfg.bot_mlp
        mlp += sum(2 * a * c for a, c in zip(dims[:-1], dims[1:]))
        f = 1 + cfg.n_sparse
        d_int = cfg.embed_dim + f * (f - 1) // 2
        dims = (d_int,) + cfg.top_mlp
        mlp += sum(2 * a * c for a, c in zip(dims[:-1], dims[1:]))
        inter = 2 * f * f * cfg.embed_dim
        factor = 3.0 if shape.kind == "train" else 1.0
        flops = factor * b * (mlp + inter)
        if shape.name == "retrieval_cand":
            flops += 2.0 * shape.extra("n_candidates") * cfg.embed_dim
        return flops
    if family == "sssp":
        # relaxation work: ~4 int-ops per edge per sweep; sweeps ~ ln(V)
        import math
        e = cfg.n_nodes * cfg.avg_degree
        sweeps = max(4, int(math.log(max(cfg.n_nodes, 2))))
        return 4.0 * e * sweeps * cfg.n_sources
    raise ValueError(family)


def save_reports(path: str, reports):
    with open(path, "w") as f:
        json.dump([r.to_json() for r in reports], f, indent=1)


def load_reports(path: str):
    with open(path) as f:
        return json.load(f)
