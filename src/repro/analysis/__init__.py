from repro.analysis.hlo import collective_stats, count_op
from repro.analysis.roofline import (
    V5E,
    RooflineReport,
    analyze,
    estimate_model_flops,
    load_reports,
    save_reports,
)

__all__ = ["collective_stats", "count_op", "analyze", "RooflineReport",
           "V5E", "estimate_model_flops", "save_reports", "load_reports"]
