"""Version shims over moved/renamed JAX APIs.

The framework targets current JAX names (``jax.shard_map``,
``jax.sharding.AxisType``, ``jax.enable_x64``); this container pins
jax 0.4.x where those live under ``jax.experimental`` or do not exist.
Every call site goes through this module so the rest of the codebase
reads as if written against one JAX version.
"""
from __future__ import annotations

import jax


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` (>=0.6) / ``jax.experimental.shard_map`` (0.4).
    ``check_vma`` maps onto the old ``check_rep``."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check_vma)


def make_mesh(shape, axes):
    """``jax.make_mesh`` with Auto axis types where the kwarg exists."""
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axes,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def enable_x64(enabled: bool = True):
    """Context manager enabling 64-bit types (``pred_mode='packed'``)."""
    if hasattr(jax, "enable_x64"):
        return jax.enable_x64(enabled)
    from jax.experimental import enable_x64 as _e64
    if enabled:
        return _e64()
    from jax.experimental import disable_x64
    return disable_x64()


def cost_analysis_dict(compiled) -> dict:
    """``Compiled.cost_analysis()`` returned a one-dict list in 0.4.x and
    a flat dict today; normalize to the dict."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost or {}
