from repro.serve.decode import BatchServer, Request, generate

__all__ = ["generate", "BatchServer", "Request"]
