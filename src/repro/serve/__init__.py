"""Serving layer: the async SSSP serving tier (``Server`` — continuous
batching, multi-graph tenancy, admission control; DESIGN.md §13), the
LM-decode continuous batcher (``BatchServer``), and the deprecated
synchronous ``SSSPServer`` shim kept for legacy call sites.

    from repro.serve import Server
    from repro.api import SingleSource, PointToPoint

    with Server({"road": g1, "social": g2}, tuning="auto") as srv:
        t1 = srv.submit(SingleSource(0), graph="road")
        t2 = srv.submit(PointToPoint(3, 99), graph="social", deadline=0.5)
        print(t1.result().dist, t2.result().distance)
    print(srv.stats())   # p50/p99 latency, occupancy, shed counts
"""

from repro.serve.decode import (
    BatchServer,
    Request,
    SSSPQuery,
    SSSPServer,
    generate,
)
from repro.serve.server import (
    RequestRejected,
    RequestTrace,
    Server,
    Ticket,
    UpdateApplied,
)

__all__ = [
    "BatchServer",
    "Request",
    "RequestRejected",
    "RequestTrace",
    "SSSPQuery",
    "SSSPServer",
    "Server",
    "Ticket",
    "UpdateApplied",
    "generate",
]
