from repro.serve.decode import (
    BatchServer,
    Request,
    SSSPQuery,
    SSSPServer,
    generate,
)

__all__ = ["generate", "BatchServer", "Request", "SSSPQuery", "SSSPServer"]
