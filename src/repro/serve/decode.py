"""Batched serving: two workloads behind the same submit/step/drain
idiom —

* LM decode: prefill + greedy decode over a preallocated KV cache, with
  a slot-based continuous-batching server for mixed request streams
  (``BatchServer``).
* SSSP queries: point-to-all / point-to-point shortest-path queries
  against a preprocessed graph, answered in fixed-size microbatches by
  the Query/Plan façade's batched multi-source program (``SSSPServer``
  → ``repro.api.Plan.solve(MultiSource(...))``, DESIGN.md §3/§10).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import LMConfig
from repro.models.transformer import (
    decode_step,
    init_cache,
    prefill,
)


def generate(params, cfg: LMConfig, prompts, n_new: int, max_len=None):
    """prompts int32[B, S] → generated int32[B, n_new] (greedy)."""
    b, s = prompts.shape
    max_len = max_len or (s + n_new)
    cache = init_cache(cfg, b, max_len)
    logits, cache = prefill(params, cfg, prompts, cache)
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)

    def body(carry, _):
        tok, cache = carry
        logits, cache = decode_step(params, cfg, cache, tok)
        nxt = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        return (nxt, cache), tok[:, 0]

    (_, _), toks = jax.lax.scan(body, (tok, cache), None, length=n_new)
    return toks.T                                    # (B, n_new)


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray
    max_new: int
    generated: list = dataclasses.field(default_factory=list)
    done: bool = False


class BatchServer:
    """Slot-based continuous batching: up to ``n_slots`` concurrent
    sequences share one batched KV cache; finished slots are refilled
    from the queue. Single jitted decode program for every step
    (prefills run per-request at admission)."""

    def __init__(self, params, cfg: LMConfig, n_slots: int, max_len: int,
                 eos_id: int = 1):
        self.params, self.cfg = params, cfg
        self.n_slots, self.max_len, self.eos = n_slots, max_len, eos_id
        self.cache = init_cache(cfg, n_slots, max_len)
        self.slots: List[Optional[Request]] = [None] * n_slots
        self.queue: List[Request] = []
        self._decode = jax.jit(lambda p, c, t: decode_step(p, cfg, c, t))

    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        for i in range(self.n_slots):
            if self.slots[i] is None and self.queue:
                req = self.queue.pop(0)
                # per-slot prefill into the shared cache
                sub = init_cache(self.cfg, 1, self.max_len)
                logits, sub = prefill(self.params, self.cfg,
                                      jnp.asarray(req.prompt[None]), sub)
                for kk in ("k", "v"):
                    self.cache[kk] = self.cache[kk].at[:, i:i + 1].set(
                        sub[kk])
                first = int(jnp.argmax(logits[0, -1]))
                req.generated.append(first)
                self.slots[i] = req
        # shared scalar length: slots track their own logical lengths; the
        # cache len is the max prompt+generated across active slots
        lens = [len(r.prompt) + len(r.generated)
                for r in self.slots if r is not None]
        if lens:
            self.cache["len"] = jnp.asarray(max(lens), jnp.int32)

    def step(self):
        self._admit()
        active = [i for i, r in enumerate(self.slots) if r is not None]
        if not active:
            return []
        toks = np.zeros((self.n_slots, 1), np.int32)
        for i in active:
            toks[i, 0] = self.slots[i].generated[-1]
        logits, self.cache = self._decode(self.params, self.cache,
                                          jnp.asarray(toks))
        nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
        finished = []
        for i in active:
            req = self.slots[i]
            req.generated.append(int(nxt[i]))
            if len(req.generated) >= req.max_new or int(nxt[i]) == self.eos:
                req.done = True
                finished.append(req)
                self.slots[i] = None
        return finished

    def run_to_completion(self, max_steps: int = 10_000):
        done = []
        for _ in range(max_steps):
            done += self.step()
            if not self.queue and all(s is None for s in self.slots):
                break
        return done


# ---------------------------------------------------------------------------
# batched SSSP serving
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SSSPQuery:
    """One shortest-path query. ``target=None`` asks for the full
    distance vector; a concrete target additionally extracts the path
    from the predecessor tree (requires pred_mode != 'none')."""

    qid: int
    source: int
    target: Optional[int] = None
    dist: Optional[np.ndarray] = None     # int64[n] (or scalar to target)
    path: Optional[List[int]] = None
    done: bool = False


class SSSPServer:
    """Microbatching SSSP server — a **deprecated** thin shim over the
    async serving tier (prefer ``repro.serve.Server``: submit queries,
    collect ``Ticket`` results; DESIGN.md §13). The shim hosts one
    tenant named ``"graph"`` on a private ``Server`` with
    ``lane_width=batch_size`` and preserves the legacy synchronous
    cadence: ``step()`` submits queued weight updates first (they apply
    between microbatches, through the same ``UpdateBatch`` submit path
    queries use), then up to ``batch_size`` queries, and drains the
    batch loop inline — same padded multi-source program, same bitwise
    answers as before the tier existed (tests/test_api_queries.py).

    Tuning still happens once, at graph-load time: ``config="auto"``
    resolves (Δ, backend, packing) through the tuning subsystem exactly
    as ``Engine(graph, tuning=...)`` does, and the resolved
    ``TuningRecord`` attaches to the plan (``server.plan.record``).
    """

    def __init__(self, graph, config=None, *, batch_size: int = 8,
                 free_mask=None, tune: bool = False,
                 tune_cache: Optional[str] = None):
        import warnings

        warnings.warn(
            "SSSPServer is deprecated: use repro.serve.Server (async "
            "serving tier, DESIGN.md §13) or repro.api.Engine(...).plan("
            "fallback=True) with MultiSource queries (DESIGN.md §10)",
            DeprecationWarning, stacklevel=2)
        from repro.api import Tuning
        from repro.core import DeltaConfig
        from repro.serve.server import Server

        # legacy knob translation: config="auto" meant resolve from
        # scratch; a concrete config survives as the tuning *base* so
        # its non-searched fields (pred_mode, n_shards, ...) carry into
        # the tuned result instead of being silently dropped
        if isinstance(config, str):
            if config != "auto":
                raise ValueError(f"config must be 'auto', got {config!r}")
            base = None
            tuning = Tuning(measure=bool(tune), cache=tune_cache)
        else:
            base = config or DeltaConfig()
            tuning = (Tuning(measure=bool(tune), cache=tune_cache)
                      if (tune or tune_cache is not None) else None)
        self._server = Server(config=base, tuning=tuning,
                              lane_width=batch_size)
        self._server.admit("graph", graph, free_mask=free_mask)
        # the legacy server tuned eagerly at graph load: srv.config is
        # the resolved DeltaConfig before the first query arrives
        self.config = self._server.plan("graph").config
        self.graph = graph
        self.free_mask = free_mask
        self.batch_size = batch_size
        self.queue: List[SSSPQuery] = []
        self._pending_updates: List[tuple] = []

    @property
    def plan(self):
        """The underlying ``repro.api.Plan`` (tuning record included)."""
        return self._server.plan("graph")

    def submit(self, query: SSSPQuery):
        if query.target is not None and self.config.pred_mode == "none":
            raise ValueError("point-to-point queries need a pred_mode")
        self.queue.append(query)

    def update(self, edge_ids, new_weights):
        """Queue a dynamic edge-cost update batch (repro.dynamic). The
        server holds one resident plan for the lifetime of the graph;
        updates are applied to it *between* query microbatches — at the
        start of the next ``step()`` — so every query inside a batch is
        answered against one consistent weight snapshot."""
        self._pending_updates.append((edge_ids, new_weights))

    def step(self) -> List[SSSPQuery]:
        """Serve one microbatch; returns the completed queries. Pending
        weight updates are applied first (between microbatches): the
        tier's batch former runs consecutive ``UpdateBatch`` submissions
        as an exclusive update batch before the query lanes."""
        from repro.api import PointToPoint, SingleSource, UpdateBatch
        upd_tickets = [
            self._server.submit(UpdateBatch(edge_ids, new_weights))
            for edge_ids, new_weights in self._pending_updates]
        self._pending_updates = []
        batch = self.queue[:self.batch_size]
        self.queue = self.queue[self.batch_size:]
        tickets = [
            self._server.submit(
                SingleSource(q.source) if q.target is None
                else PointToPoint(q.source, q.target))
            for q in batch]
        self._server.drain()
        if upd_tickets:
            self.graph = self.plan.graph
            for t in upd_tickets:
                t.result()                   # surface refused updates
        for q, t in zip(batch, tickets):
            res = t.result()
            if q.target is None:
                q.dist = np.asarray(res.dist, np.int64)
            else:
                q.dist = np.int64(res.distance)
                q.path = res.path
            q.done = True
        return batch

    def run_to_completion(self, max_steps: int = 10_000):
        done = []
        for _ in range(max_steps):
            if not self.queue:
                break
            done += self.step()
        return done
