"""Async serving tier: continuous batching, multi-graph tenancy and
admission control over the Query/Plan façade (DESIGN.md §13).

The paper's result — and the whole ρ-/Δ*-stepping line after it — is
that shared-memory SSSP wins by amortizing work into large uniform
batches. ``Server`` lifts that discipline to the serving layer: an
async request queue is drained *continuously* into microbatches that
run the already-compiled ``solve_many`` shapes, instead of the
deprecated ``SSSPServer``'s synchronous fixed-cadence stepping.

* **Continuous batching.** ``submit(query)`` returns a future-style
  ``Ticket`` immediately; the batch former takes the tenant owning the
  oldest pending request and packs up to ``lane_width`` consecutive
  lane-able queries (``SingleSource`` / ``PointToPoint`` /
  ``BoundedRadius`` — one multi-source lane each, short batches padded
  by repeating the last source so every batch runs one compiled shape).
  ``MultiSource`` / ``ManyToMany`` run as solo batches through the
  plan's own dispatch; per-tenant FIFO order is never reordered, so
  answers are bitwise what a serial ``plan.solve`` stream would give
  (tests/test_serving.py pins it).
* **Multi-graph tenancy.** Each admitted graph is a *tenant*; resident
  ``Plan``s live in an LRU bounded by ``max_resident``. Eviction drops
  the compiled plan but keeps the (possibly updated) graph; a
  re-admitted tenant re-resolves through the same ``tuning`` inputs —
  with a tuning cache, the fingerprint-keyed record makes the re-built
  plan bitwise identical to the evicted one.
* **Streamed updates.** ``UpdateBatch`` rides the same submit path as
  queries and is applied *between* microbatches on the owning plan, so
  every query batch sees one consistent weight snapshot. A plan that
  refuses an update (``api.UpdateRefused``, e.g. grid-stencil costs)
  sheds that one ticket; the batch loop keeps serving.
* **Admission control.** A full queue (``max_queue``) sheds at submit;
  an expired ``deadline`` sheds at batch-form time — both resolve the
  ticket with a typed ``RequestRejected`` carrying the reason. An
  accepted request is never dropped: every ticket resolves with a
  result or a typed rejection.

Per-request telemetry (``Ticket.trace``) records the
enqueue→batch→solve→extract timestamps and batch occupancy;
``Server.stats()`` aggregates them into p50/p99 latency, shed counts
and occupancy — the numbers ``benchmarks/bench_serving.py`` sweeps
into the repo's latency-SLO record.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Callable, Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from repro.api import (
    BoundedRadius,
    BoundedRadiusResult,
    Engine,
    ManyToMany,
    MultiSource,
    PointToPoint,
    PointToPointResult,
    SingleSource,
    SingleSourceResult,
    Telemetry,
    UpdateBatch,
    UpdateRefused,
    extract_path,
)
from repro.core.delta_stepping import P2P_MODES
from repro.graphs.structures import INF32

# query kinds that occupy exactly one multi-source lane each; anything
# else runs as a solo batch through the plan's own dispatch
_LANE_KINDS = (SingleSource, PointToPoint, BoundedRadius)
_QUERY_KINDS = _LANE_KINDS + (MultiSource, ManyToMany, UpdateBatch)


def _lane_able(query) -> bool:
    """Lane batches answer PointToPoint from a full single-source lane
    — correct, but it would silently ignore an explicitly requested
    goal-directed mode (repro.landmarks), so mode-carrying queries run
    solo through the plan's own dispatch instead."""
    if not isinstance(query, _LANE_KINDS):
        return False
    return not (isinstance(query, PointToPoint) and query.mode is not None)

# bounded ring of completed-request latencies backing stats()'s
# percentiles — enough for a load sweep, O(1) memory under sustained
# traffic
_LATENCY_WINDOW = 10_000


class RequestRejected(RuntimeError):
    """Typed admission-control rejection. ``reason`` is a stable tag:
    ``"queue_full"`` (depth cap at submit), ``"deadline"`` (expired at
    batch-form time), ``"update_refused"`` (the owning plan refused the
    update — see ``api.UpdateRefused``), ``"invalid"`` (malformed
    query), ``"closed"`` (server shut down without draining)."""

    def __init__(self, reason: str, detail: str = ""):
        msg = f"request rejected ({reason})"
        super().__init__(f"{msg}: {detail}" if detail else msg)
        self.reason = reason
        self.detail = detail


@dataclasses.dataclass
class RequestTrace:
    """Per-request serving telemetry: where one request's latency went.
    Timestamps are ``clock()`` values (``time.monotonic`` by default);
    ``t_batch``/``t_solve``/``t_done`` stay ``None`` for requests shed
    before reaching that stage. ``batch_occupancy`` is real lanes /
    ``lane_width`` for lane batches, 1.0 for solo and update batches."""

    tenant: str
    kind: str
    t_submit: float
    t_batch: Optional[float] = None
    t_solve: Optional[float] = None
    t_done: Optional[float] = None
    batch_occupancy: Optional[float] = None
    shed: Optional[str] = None

    @property
    def latency(self) -> Optional[float]:
        if self.t_done is None:
            return None
        return self.t_done - self.t_submit


@dataclasses.dataclass(frozen=True)
class UpdateApplied:
    """Acknowledgement for an ``UpdateBatch`` applied to a plan with no
    resident single-source answer to re-solve (the serving tier answers
    queries through batched lanes, which do not establish residency):
    the weights are swapped, the next batch sees them."""

    n_edges: int


class Ticket:
    """Future-style handle for one submitted request. ``result()``
    blocks until the batch loop resolves it, returning the query's
    typed result (or raising the typed rejection/error); ``trace``
    carries the per-request serving telemetry."""

    def __init__(self, trace: RequestTrace):
        self.trace = trace
        self._event = threading.Event()
        self._result = None
        self._exc: Optional[BaseException] = None

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: Optional[float] = None):
        if not self._event.wait(timeout):
            raise TimeoutError("request not served yet")
        if self._exc is not None:
            raise self._exc
        return self._result

    def exception(self, timeout: Optional[float] = None):
        if not self._event.wait(timeout):
            raise TimeoutError("request not served yet")
        return self._exc

    def _resolve(self, result) -> None:
        self._result = result
        self._event.set()

    def _reject(self, exc: BaseException) -> None:
        self._exc = exc
        self._event.set()


@dataclasses.dataclass
class _Pending:
    seq: int
    query: object
    ticket: Ticket
    deadline: Optional[float]  # absolute clock() value


@dataclasses.dataclass
class _Tenant:
    name: str
    graph: object
    config: object
    free_mask: object
    queue: deque = dataclasses.field(default_factory=deque)
    plan: object = None
    last_used: int = -1
    served: int = 0


@dataclasses.dataclass
class _Batch:
    tenant: _Tenant
    kind: str  # "lanes" | "solo" | "update"
    items: List[_Pending]


class Server:
    """The serving tier. ``graphs`` is a ``{name: COOGraph}`` mapping
    (or a single graph, admitted as ``"default"``); ``config`` is the
    per-tenant ``DeltaConfig`` base and ``tuning`` the ``Engine``
    resolution knob, both shared by every tenant unless ``admit``
    overrides them. Inline use: ``submit`` then ``drain()``/``pump()``.
    Async use: ``with Server(...) as srv:`` runs the batch loop on a
    background thread and ``close()`` drains it.
    """

    def __init__(
        self,
        graphs=None,
        *,
        config=None,
        tuning=None,
        lane_width: int = 8,
        max_resident: Optional[int] = None,
        max_queue: int = 256,
        clock: Callable[[], float] = time.monotonic,
        landmarks=None,
    ):
        if lane_width < 1:
            raise ValueError(f"lane_width must be >= 1, got {lane_width}")
        if max_resident is not None and max_resident < 1:
            raise ValueError(f"max_resident must be >= 1, got {max_resident}")
        self.lane_width = int(lane_width)
        self.max_resident = max_resident
        self.max_queue = int(max_queue)
        self._config = config
        self._tuning = tuning
        # landmark residency knob (repro.landmarks): Plan.prepare_landmarks
        # kwargs (or an int k) applied lazily to every tenant plan — the
        # table precompute itself runs on a tenant's first landmark-mode
        # query, so tenants that never ask for goal-directed point-to-
        # point never pay for it
        if isinstance(landmarks, int):
            landmarks = {"k": landmarks}
        self._landmarks = landmarks
        self._clock = clock
        self._lock = threading.Lock()
        self._work = threading.Condition(self._lock)
        self._tenants: Dict[str, _Tenant] = {}
        self._seq = 0
        self._tick = 0
        self._thread: Optional[threading.Thread] = None
        self._closing = False
        # counters behind stats()
        self._submitted = 0
        self._completed = 0
        self._shed: Dict[str, int] = {}
        self._batches = {"lanes": 0, "solo": 0, "update": 0}
        self._occupancy_sum = 0.0
        self._evictions = 0
        self._plans_built = 0
        self._latencies = deque(maxlen=_LATENCY_WINDOW)
        if graphs is not None:
            if not isinstance(graphs, dict):
                graphs = {"default": graphs}
            for name, g in graphs.items():
                self.admit(name, g)

    # -- tenancy -------------------------------------------------------------

    def admit(self, name: str, graph, *, config=None, free_mask=None) -> None:
        """Register a tenant graph. The plan is built lazily, on the
        tenant's first batch (and rebuilt after an LRU eviction)."""
        with self._lock:
            if name in self._tenants:
                raise ValueError(f"tenant {name!r} already admitted")
            self._tenants[name] = _Tenant(
                name=name,
                graph=graph,
                config=config if config is not None else self._config,
                free_mask=free_mask,
            )

    def plan(self, graph: Optional[str] = None):
        """The tenant's resident ``repro.api.Plan`` (built — and LRU-
        touched — on access)."""
        with self._lock:
            tenant = self._tenant_locked(graph)
            return self._plan_locked(tenant)

    def _tenant_locked(self, name: Optional[str]) -> _Tenant:
        if not self._tenants:
            raise ValueError("no tenant graphs admitted")
        if name is None:
            if len(self._tenants) > 1:
                raise ValueError(
                    f"server hosts {sorted(self._tenants)}: pass graph=<name>"
                )
            return next(iter(self._tenants.values()))
        try:
            return self._tenants[name]
        except KeyError:
            raise ValueError(
                f"unknown tenant {name!r} (admitted: {sorted(self._tenants)})"
            ) from None

    def _plan_locked(self, tenant: _Tenant):
        tenant.last_used = self._tick
        self._tick += 1
        if tenant.plan is None:
            tenant.plan = Engine(
                tenant.graph,
                tenant.config,
                free_mask=tenant.free_mask,
                tuning=self._tuning,
            ).plan(fallback=True)
            if self._landmarks is not None:
                tenant.plan.prepare_landmarks(**self._landmarks, build=False)
            self._plans_built += 1
            self._evict_locked(keep=tenant)
        return tenant.plan

    def _evict_locked(self, keep: _Tenant) -> None:
        if self.max_resident is None:
            return
        resident = [t for t in self._tenants.values() if t.plan is not None]
        while len(resident) > self.max_resident:
            victim = min(
                (t for t in resident if t is not keep),
                key=lambda t: t.last_used,
            )
            # updated weights outlive the plan: the tenant keeps the
            # plan's current graph, so a rebuild resumes from the same
            # weight state the evicted plan served
            victim.graph = victim.plan.graph
            victim.plan = None
            resident.remove(victim)
            self._evictions += 1

    # -- admission -----------------------------------------------------------

    def submit(self, query, *, graph: Optional[str] = None,
               deadline: Optional[float] = None) -> Ticket:
        """Enqueue one request; returns its ``Ticket`` immediately.
        ``graph`` names the tenant (optional when only one is admitted);
        ``deadline`` is a latency budget in seconds — a request still
        queued when it expires is shed at batch-form time. Admission
        failures (unknown tenant, full queue, malformed query) resolve
        the ticket with a typed ``RequestRejected`` instead of raising,
        so an open-loop generator never blocks on an overloaded server.
        """
        now = self._clock()
        with self._work:
            trace = RequestTrace(tenant=graph or "?",
                                 kind=type(query).__name__, t_submit=now)
            ticket = Ticket(trace)
            self._submitted += 1
            if self._closing:
                return self._shed_locked(ticket, "closed", "server closed")
            try:
                tenant = self._tenant_locked(graph)
            except ValueError as e:
                return self._shed_locked(ticket, "invalid", str(e))
            trace.tenant = tenant.name
            err = self._validate(tenant, query)
            if err is not None:
                return self._shed_locked(ticket, "invalid", err)
            depth = sum(len(t.queue) for t in self._tenants.values())
            if depth >= self.max_queue:
                return self._shed_locked(
                    ticket, "queue_full",
                    f"queue depth {depth} at cap {self.max_queue}")
            self._seq += 1
            tenant.queue.append(_Pending(
                seq=self._seq, query=query, ticket=ticket,
                deadline=None if deadline is None else now + deadline))
            self._work.notify()
            return ticket

    def _validate(self, tenant: _Tenant, query) -> Optional[str]:
        """Host-side admission validation of the per-lane query kinds,
        so one malformed request cannot poison its batch-mates (solo
        kinds are validated by ``plan.solve`` and fail alone)."""
        if not isinstance(query, _QUERY_KINDS):
            return f"unknown query kind {type(query).__name__!r}"
        n = tenant.graph.n_nodes
        if isinstance(query, _LANE_KINDS) and not 0 <= int(query.source) < n:
            return f"source {query.source} out of range for {n} vertices"
        if isinstance(query, PointToPoint) and not 0 <= int(query.target) < n:
            return f"target {query.target} out of range for {n} vertices"
        if (isinstance(query, PointToPoint) and query.mode is not None
                and query.mode not in P2P_MODES):
            return f"unknown p2p mode {query.mode!r} (known: {P2P_MODES})"
        if isinstance(query, BoundedRadius) and not (
            0 <= int(query.radius) < int(INF32)
        ):
            return f"radius must be in [0, INF32), got {query.radius}"
        return None

    def _shed_locked(self, ticket: Ticket, reason: str, detail: str) -> Ticket:
        ticket.trace.shed = reason
        ticket.trace.t_done = self._clock()
        self._shed[reason] = self._shed.get(reason, 0) + 1
        ticket._reject(RequestRejected(reason, detail))
        return ticket

    # -- the batch loop ------------------------------------------------------

    def _has_work_locked(self) -> bool:
        return any(t.queue for t in self._tenants.values())

    def _form_batch_locked(self) -> Optional[_Batch]:
        now = self._clock()
        for tenant in self._tenants.values():
            if any(p.deadline is not None and p.deadline < now
                   for p in tenant.queue):
                kept = deque()
                for p in tenant.queue:
                    if p.deadline is not None and p.deadline < now:
                        self._shed_locked(
                            p.ticket, "deadline",
                            "deadline expired before batch formation")
                    else:
                        kept.append(p)
                tenant.queue = kept
        live = [t for t in self._tenants.values() if t.queue]
        if not live:
            return None
        # continuous batching: serve the tenant owning the oldest
        # pending request, never reordering within a tenant
        tenant = min(live, key=lambda t: t.queue[0].seq)
        head = tenant.queue[0]
        if isinstance(head.query, UpdateBatch):
            kind, items = "update", []
            while tenant.queue and isinstance(tenant.queue[0].query,
                                              UpdateBatch):
                items.append(tenant.queue.popleft())
        elif _lane_able(head.query):
            kind, items = "lanes", []
            while (tenant.queue and len(items) < self.lane_width
                   and _lane_able(tenant.queue[0].query)):
                items.append(tenant.queue.popleft())
        else:
            kind, items = "solo", [tenant.queue.popleft()]
        t_batch = self._clock()
        occupancy = (len(items) / self.lane_width if kind == "lanes" else 1.0)
        for p in items:
            p.ticket.trace.t_batch = t_batch
            p.ticket.trace.batch_occupancy = occupancy
        self._batches[kind] += 1
        self._occupancy_sum += occupancy
        return _Batch(tenant=tenant, kind=kind, items=items)

    def pump(self) -> int:
        """Form and execute one microbatch inline (no worker thread);
        returns the number of requests resolved (0 = nothing queued)."""
        with self._lock:
            batch = self._form_batch_locked()
        if batch is None:
            return 0
        return self._execute(batch)

    def drain(self) -> None:
        """Serve inline until every queued request has resolved."""
        while self.pump():
            pass

    def _execute(self, batch: _Batch) -> int:
        try:
            with self._lock:
                plan = self._plan_locked(batch.tenant)
            if batch.kind == "update":
                self._run_updates(batch, plan)
            elif batch.kind == "solo":
                self._run_solo(batch, plan)
            else:
                self._run_lanes(batch, plan)
        except Exception as e:  # noqa: BLE001 — the loop must survive
            for p in batch.items:
                if not p.ticket.done():
                    p.ticket._reject(e)
        with self._lock:
            done = self._clock()
            for p in batch.items:
                p.ticket.trace.t_done = done
                if p.ticket.trace.shed is None and p.ticket.exception(0) is None:
                    self._completed += 1
                    batch.tenant.served += 1
                    self._latencies.append(done - p.ticket.trace.t_submit)
        return len(batch.items)

    def _run_updates(self, batch: _Batch, plan) -> None:
        """Streamed update application between microbatches: weights
        swap on the owning plan, one request at a time so a refused
        update sheds its own ticket and the rest of the stream (and the
        batch loop) keeps going."""
        tenant = batch.tenant
        for p in batch.items:
            q = p.query
            try:
                plan.update(q.edge_ids, q.new_weights)
            except UpdateRefused as e:
                with self._lock:
                    self._shed_locked(p.ticket, "update_refused", str(e))
                continue
            except ValueError as e:
                with self._lock:
                    self._shed_locked(p.ticket, "invalid", str(e))
                continue
            tenant.graph = plan.graph
            p.ticket.trace.t_solve = self._clock()
            if plan.explain()["resident_source"] is not None:
                p.ticket._resolve(plan.resolve(warm=q.warm))
            else:
                p.ticket._resolve(
                    UpdateApplied(n_edges=len(np.ravel(q.edge_ids))))

    def _run_solo(self, batch: _Batch, plan) -> None:
        (p,) = batch.items
        p.ticket.trace.t_solve = self._clock()
        p.ticket._resolve(plan.solve(p.query))

    def _run_lanes(self, batch: _Batch, plan) -> None:
        """One padded multi-source solve answers every lane: lane i is
        bitwise identical to ``SingleSource(sources[i])`` (the pinned
        solve_many contract), so splitting the batch reproduces each
        request's serial answer."""
        items = batch.items
        sources = [int(p.query.source) for p in items]
        padded = sources + [sources[-1]] * (self.lane_width - len(sources))
        res = plan.solve(MultiSource(np.asarray(padded, np.int32)))
        t_solve = self._clock()
        dist, pred = res.dist, res.pred
        outer = np.asarray(res.telemetry.buckets)
        inner = np.asarray(res.telemetry.inner_iters)
        over = np.asarray(res.telemetry.overflow)

        def lane(arr, i):
            return arr[i] if arr.ndim else arr

        n = plan.graph.n_nodes
        for i, p in enumerate(items):
            q = p.query
            tel = Telemetry(
                buckets=lane(outer, i),
                inner_iters=lane(inner, i),
                overflow=lane(over, i),
                fallback=res.telemetry.fallback,
            )
            p.ticket.trace.t_solve = t_solve
            if isinstance(q, SingleSource):
                p.ticket._resolve(SingleSourceResult(dist[i], pred[i], tel))
            elif isinstance(q, PointToPoint):
                distance = int(np.asarray(dist[i])[int(q.target)])
                path = None
                if distance < int(INF32) and plan.config.pred_mode != "none":
                    path = extract_path(
                        np.asarray(pred[i]), int(q.source), int(q.target), n)
                p.ticket._resolve(PointToPointResult(distance, path, tel))
            else:  # BoundedRadius: the full lane filtered to the radius
                within = dist[i] <= q.radius
                d = jnp.where(within, dist[i], jnp.int32(INF32))
                pr = jnp.where(within, pred[i], jnp.int32(-1))
                p.ticket._resolve(
                    BoundedRadiusResult(d, pr, int(q.radius), tel))

    # -- lifecycle -----------------------------------------------------------

    def start(self) -> "Server":
        """Run the batch loop on a daemon thread (continuous serving)."""
        if self._thread is None:
            self._closing = False
            self._thread = threading.Thread(
                target=self._loop, name="repro-serve", daemon=True)
            self._thread.start()
        return self

    def _loop(self) -> None:
        while True:
            with self._work:
                while not self._closing and not self._has_work_locked():
                    self._work.wait()
                if self._closing and not self._has_work_locked():
                    return
                batch = self._form_batch_locked()
            if batch is not None:
                self._execute(batch)

    def close(self, drain: bool = True) -> None:
        """Stop serving. ``drain=True`` (default) answers everything
        still queued first; ``drain=False`` sheds it with a typed
        ``"closed"`` rejection — accepted requests never just vanish."""
        with self._work:
            if not drain:
                for tenant in self._tenants.values():
                    while tenant.queue:
                        p = tenant.queue.popleft()
                        self._shed_locked(p.ticket, "closed",
                                          "server closed before serving")
            self._closing = True
            self._work.notify_all()
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        elif drain:
            self.drain()

    def __enter__(self) -> "Server":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close(drain=not any(exc))

    # -- telemetry -----------------------------------------------------------

    def stats(self) -> dict:
        """Aggregated serving telemetry: request accounting (submitted /
        completed / shed-by-reason / queued), batch counts and mean
        occupancy, tenancy state (resident plans, builds, evictions) and
        completed-request latency percentiles in milliseconds (over the
        last {window} requests).""".format(window=_LATENCY_WINDOW)
        with self._lock:
            lat = sorted(self._latencies)

            def pct(p: float) -> Optional[float]:
                if not lat:
                    return None
                return 1e3 * lat[min(len(lat) - 1, int(p * len(lat)))]

            n_batches = sum(self._batches.values())
            return {
                "submitted": self._submitted,
                "completed": self._completed,
                "shed": dict(sorted(self._shed.items())),
                "queued": sum(len(t.queue) for t in self._tenants.values()),
                "batches": dict(self._batches),
                "mean_occupancy": (
                    self._occupancy_sum / n_batches if n_batches else None),
                "resident": sorted(
                    t.name for t in self._tenants.values()
                    if t.plan is not None),
                "plans_built": self._plans_built,
                "evictions": self._evictions,
                "per_tenant": {
                    t.name: t.served for t in self._tenants.values()},
                "latency_p50_ms": pct(0.50),
                "latency_p99_ms": pct(0.99),
            }


__all__ = [
    "RequestRejected",
    "RequestTrace",
    "Server",
    "Ticket",
    "UpdateApplied",
]
