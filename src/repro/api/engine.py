"""The Query/Plan façade — the one public entry point of the engine.

``Engine(graph, config="auto").plan()`` resolves tuning / strategy /
caps exactly once and returns a ``Plan`` holding the pre-lowered jitted
drivers (the module-level jitted programs of ``core.delta_stepping``,
so plans over same-shaped graphs share compile cache entries exactly
like the deprecated ``DeltaSteppingSolver`` did); ``plan.solve(query)``
dispatches on the small query algebra of ``queries.py``.

Resolution (DESIGN.md §7/§10) happens in one place, ``Engine.plan``:

* a concrete ``DeltaConfig`` with no tuning inputs is used as-is;
* ``config="auto"`` — or a concrete config plus ``tune=True`` /
  ``tune_cache=...`` acting as the tuning *base* — goes through
  ``tune.resolve_record``, whose cap validation runs on the one shared
  ``build_safe_solver`` path: a tuning-chosen ``frontier_cap`` is
  re-validated against ``plan(sources=...)`` (and dropped on overflow)
  or dropped outright when the plan cannot know its future sources.
  The winning ``TuningRecord`` attaches to the plan (``plan.record``) —
  a Plan is the unit tuning evidence hangs off.

Overflow handling has one fallback point, ``Plan.solve``: with
``fallback=True`` (the serving configuration) a query whose solve trips
the compacted-frontier ``overflow`` flag is re-answered by a full-width
twin plan and the plan demotes to it permanently — capped solves may
move time, never answers. With ``fallback=False`` (the parity default)
the flag is reported in the result telemetry and the caller decides,
exactly like the pre-façade solver.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Sequence, Union

import jax.numpy as jnp
import numpy as np

from repro.api.paths import extract_path
from repro.api.queries import (
    BoundedRadius,
    BoundedRadiusResult,
    ManyToMany,
    ManyToManyResult,
    MultiSource,
    MultiSourceResult,
    PointToPoint,
    PointToPointResult,
    Query,
    Result,
    SingleSource,
    SingleSourceResult,
    Telemetry,
)
from repro.core.backends import dist_of, make_backend
from repro.core.delta_stepping import (
    DeltaConfig,
    _finish_pred,
    _finish_pred_many,
    _require_x64,
    _run_many_seq,
    _run_many_vmapped,
    _run_one,
    _run_one_bounded,
    _run_one_p2p,
)
from repro.graphs.structures import COOGraph, INF32


def _mark_fallback(res: Result) -> Result:
    tel = dataclasses.replace(res.telemetry, fallback=True)
    return dataclasses.replace(res, telemetry=tel)


def _check_vertex(name: str, v, n: int) -> int:
    """Host-side id validation: out-of-range ids would otherwise be
    silently dropped by the jitted scatter (an all-INF 'answer') or
    clamped by the gather (a wrong early exit)."""
    v = int(v)
    if not 0 <= v < n:
        raise ValueError(f"{name} {v} out of range for a {n}-vertex graph")
    return v


def _check_vertices(name: str, arr: np.ndarray, n: int) -> None:
    if arr.size and (int(arr.min()) < 0 or int(arr.max()) >= n):
        raise ValueError(f"{name} contain ids out of range, graph has {n}")


class Plan:
    """A compiled operating point for one graph: resolved config,
    relaxation backend, and partially-applied module-level jitted
    drivers for every query kind. Built by ``Engine.plan``; solvable
    immediately and repeatedly via ``solve(query)``."""

    def __init__(
        self,
        graph: COOGraph,
        config: DeltaConfig,
        *,
        free_mask=None,
        record=None,
        fallback: bool = False,
    ):
        if config.pred_mode == "packed":
            _require_x64()
        self.graph = graph
        self.config = config
        self.record = record
        self.free_mask = free_mask
        self.backend = make_backend(graph, config, free_mask=free_mask)
        packed = config.pred_mode == "packed"
        self._packed = packed
        n = graph.n_nodes
        self._run1 = partial(_run_one, n=n, packed=packed)
        many = _run_many_vmapped if self.backend.supports_vmap else _run_many_seq
        self._run_many = partial(many, n=n, packed=packed)
        self._run_p2p = partial(_run_one_p2p, n=n, packed=packed)
        self._run_bounded = partial(_run_one_bounded, n=n, packed=packed)
        # the one overflow-fallback point: only meaningful when a capped
        # compaction can actually overflow
        self._fallback = bool(fallback) and config.frontier_cap is not None
        self._demoted: Optional[Plan] = None

    # -- the one public operation -------------------------------------------

    def solve(self, query: Query) -> Result:
        """Answer one query. With fallback enabled, a solve that trips
        the compacted-frontier overflow flag is re-answered by the
        full-width twin plan (and the plan demotes to it permanently —
        a query mix that overflowed once would otherwise pay capped +
        uncapped solves on every call)."""
        if self._demoted is not None:
            return _mark_fallback(self._demoted._dispatch(query))
        res = self._dispatch(query)
        if self._fallback and bool(np.any(np.asarray(res.telemetry.overflow))):
            self._demoted = Plan(
                self.graph,
                dataclasses.replace(self.config, frontier_cap=None),
                free_mask=self.free_mask,
                record=self.record,
            )
            res = _mark_fallback(self._demoted._dispatch(query))
        return res

    def explain(self) -> dict:
        """Plan provenance for logs/telemetry: the resolved operating
        point plus the tuning record (if any) it came from."""
        cfg = self.config
        return {
            "delta": cfg.delta,
            "strategy": cfg.strategy,
            "pred_mode": cfg.pred_mode,
            "frontier_cap": cfg.frontier_cap,
            "n_shards": cfg.n_shards,
            "tuning_source": None if self.record is None else self.record.source,
            "fallback_taken": self._demoted is not None,
        }

    # -- query dispatch ------------------------------------------------------

    def _dispatch(self, query: Query) -> Result:
        if isinstance(query, SingleSource):
            return self._single(query)
        if isinstance(query, MultiSource):
            return self._multi(query)
        if isinstance(query, PointToPoint):
            return self._point_to_point(query)
        if isinstance(query, BoundedRadius):
            return self._bounded(query)
        if isinstance(query, ManyToMany):
            return self._many_to_many(query)
        raise TypeError(f"unknown query kind {type(query).__name__!r}")

    def _single(self, q: SingleSource) -> SingleSourceResult:
        src = jnp.asarray(
            _check_vertex("source", q.source, self.graph.n_nodes), jnp.int32
        )
        tent, outer, inner, over = self._run1(self.backend, src)
        dist, pred = _finish_pred(tent, self.graph, src, self.config)
        return SingleSourceResult(dist, pred, Telemetry(outer, inner, over))

    def _multi(self, q: MultiSource) -> MultiSourceResult:
        host = np.asarray(q.sources, np.int64)
        if host.ndim != 1:
            raise ValueError("sources must be a 1-D array of vertex ids")
        _check_vertices("sources", host, self.graph.n_nodes)
        srcs = jnp.asarray(host, jnp.int32)
        tent, outer, inner, over = self._run_many(self.backend, srcs)
        dist, pred = _finish_pred_many(tent, self.graph, srcs, self.config)
        return MultiSourceResult(dist, pred, Telemetry(outer, inner, over))

    def _point_to_point(self, q: PointToPoint) -> PointToPointResult:
        n = self.graph.n_nodes
        src = jnp.asarray(_check_vertex("source", q.source, n), jnp.int32)
        tgt = jnp.asarray(_check_vertex("target", q.target, n), jnp.int32)
        tent, outer, inner, over = self._run_p2p(self.backend, src, tgt)
        # every vertex on a shortest source->target path is settled at
        # early exit (its bucket precedes the target's), so the partial
        # predecessor state is exact along the returned path
        dist, pred = _finish_pred(tent, self.graph, src, self.config)
        distance = int(np.asarray(dist)[int(q.target)])
        path = None
        if distance < int(INF32) and self.config.pred_mode != "none":
            path = extract_path(
                np.asarray(pred), int(q.source), int(q.target), self.graph.n_nodes
            )
        return PointToPointResult(distance, path, Telemetry(outer, inner, over))

    def _bounded(self, q: BoundedRadius) -> BoundedRadiusResult:
        radius = int(q.radius)
        if not 0 <= radius < int(INF32):
            raise ValueError(f"radius must be in [0, INF32), got {radius}")
        src = jnp.asarray(
            _check_vertex("source", q.source, self.graph.n_nodes), jnp.int32
        )
        r_arr = jnp.asarray(radius, jnp.int32)
        tent, outer, inner, over = self._run_bounded(self.backend, src, r_arr)
        dist, pred = _finish_pred(tent, self.graph, src, self.config)
        # all buckets <= radius // delta were processed, so every vertex
        # with true distance <= radius is settled; the rest are filtered
        # to the unreachable sentinels (their tent values are bounds,
        # not answers)
        within = dist <= radius
        dist = jnp.where(within, dist, jnp.int32(INF32))
        pred = jnp.where(within, pred, jnp.int32(-1))
        return BoundedRadiusResult(dist, pred, radius, Telemetry(outer, inner, over))

    def _many_to_many(self, q: ManyToMany) -> ManyToManyResult:
        n = self.graph.n_nodes
        sources = [_check_vertex("source", s, n) for s in q.sources]
        targets = np.asarray([int(t) for t in q.targets], np.int64)
        if not sources or targets.size == 0:
            raise ValueError("ManyToMany needs non-empty sources and targets")
        _check_vertices("targets", targets, n)
        tile = int(q.tile) if q.tile is not None else min(len(sources), 8)
        if tile < 1:
            raise ValueError(f"tile must be >= 1, got {tile}")
        matrix = np.full((len(sources), len(targets)), int(INF32), np.int64)
        buckets, inner_total, over_any = 0, 0, False
        for lo in range(0, len(sources), tile):
            chunk = sources[lo : lo + tile]
            # short tiles repeat the last source so every tile runs the
            # same compiled shape (the padded lanes are discarded)
            padded = chunk + [chunk[-1]] * (tile - len(chunk))
            srcs = jnp.asarray(padded, jnp.int32)
            tent, outer, inner, over = self._run_many(self.backend, srcs)
            d = np.asarray(dist_of(tent, self._packed))
            matrix[lo : lo + len(chunk)] = d[: len(chunk)][:, targets]
            buckets = max(buckets, int(np.max(np.asarray(outer))))
            inner_total += int(np.sum(np.asarray(inner)))
            over_any = over_any or bool(np.any(np.asarray(over)))
        tel = Telemetry(np.int32(buckets), np.int32(inner_total), np.bool_(over_any))
        return ManyToManyResult(matrix, tel)


class Engine:
    """Façade entry point: holds the graph plus the tuning inputs, and
    mints ``Plan``s. ``config`` is a concrete ``DeltaConfig`` or
    ``"auto"``; with ``tune=True`` (measured search) or ``tune_cache``
    (persistent record store) a concrete config survives as the tuning
    *base* — its non-searched fields carry into the resolved plan."""

    def __init__(
        self,
        graph: COOGraph,
        config: Union[DeltaConfig, str] = "auto",
        *,
        free_mask=None,
        tune: bool = False,
        tune_cache: Optional[str] = None,
    ):
        if isinstance(config, str) and config != "auto":
            raise ValueError(
                f"unknown config string {config!r} (did you mean 'auto' "
                "or a DeltaConfig?)"
            )
        self.graph = graph
        self.free_mask = free_mask
        self._config = config
        self._tune = tune
        self._tune_cache = tune_cache

    def plan(
        self,
        *,
        sources: Optional[Sequence[int]] = None,
        fallback: bool = False,
    ) -> Plan:
        """Resolve the operating point once and return the compiled
        ``Plan``. ``sources`` are the vertices the caller will actually
        solve from: a tuning-chosen ``frontier_cap`` is validated
        against exactly those (one shared ``build_safe_solver`` path)
        and dropped on overflow; ``sources=None`` — a plan that cannot
        know its future queries — drops a tuned cap outright and can
        instead serve with ``fallback=True`` (per-query overflow
        re-solve, the ``SSSPServer`` configuration)."""
        cfg, record = self._resolve(sources)
        return Plan(
            self.graph,
            cfg,
            free_mask=self.free_mask,
            record=record,
            fallback=fallback,
        )

    def _resolve(self, sources):
        cfg = self._config
        auto = isinstance(cfg, str)
        if not (auto or self._tune or self._tune_cache is not None):
            return cfg, None  # concrete config, no tuning inputs: as-is
        from repro.tune import resolve_record  # lazy: tune builds on core/api

        base = DeltaConfig() if auto else cfg
        return resolve_record(
            self.graph,
            base,
            free_mask=self.free_mask,
            cache_path=self._tune_cache,
            measure=self._tune,
            sources=sources,
        )


__all__ = ["Engine", "Plan"]
