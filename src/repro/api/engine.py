"""The Query/Plan façade — the one public entry point of the engine.

``Engine(graph).plan()`` resolves tuning / strategy / caps exactly once
and returns a ``Plan`` holding the pre-lowered jitted drivers (the
module-level jitted programs of ``core.delta_stepping``, so plans over
same-shaped graphs share compile cache entries exactly like the
deprecated ``DeltaSteppingSolver`` did); ``plan.solve(query)``
dispatches on the small query algebra of ``queries.py``.

Resolution (DESIGN.md §7/§10) happens in one place, ``Engine.plan``,
steered by the one ``tuning=`` knob:

* a concrete ``DeltaConfig`` with ``tuning=None`` is used as-is;
* ``tuning="auto"`` / ``"measure"`` / a cache path / a ``Tuning(...)``
  — with any concrete config acting as the tuning *base* — goes through
  ``tune.resolve_record``, whose cap validation runs on the one shared
  ``build_safe_solver`` path: a tuning-chosen ``frontier_cap`` is
  re-validated against ``plan(sources=...)`` (and dropped on overflow)
  or dropped outright when the plan cannot know its future sources.
  The winning ``TuningRecord`` attaches to the plan (``plan.record``) —
  a Plan is the unit tuning evidence hangs off. The pre-redesign
  spellings (``config="auto"``, ``tune=``, ``tune_cache=``) are
  deprecated shims onto exactly these semantics.

Overflow handling has one fallback point, ``Plan.solve``: with
``fallback=True`` (the serving configuration) a query whose solve trips
the compacted-frontier ``overflow`` flag is re-answered by a full-width
twin plan and the plan demotes to it permanently — capped solves may
move time, never answers. With ``fallback=False`` (the parity default)
the flag is reported in the result telemetry and the caller decides,
exactly like the pre-façade solver.

Dynamic graphs (repro.dynamic, DESIGN.md §11): a plan is also the unit
of *residency*. Solving ``SingleSource`` keeps the converged answer and
a weight snapshot on the plan; ``plan.update(edge_ids, new_weights)``
swaps edge costs (topology fixed), and ``plan.resolve(warm=True)``
re-solves the resident problem by warm-start repair — bitwise identical
to a cold solve of the updated graph, at the cost of the repair cone
instead of the whole instance. ``plan.solve(UpdateBatch(...))`` is the
query-algebra packaging of the same pair.
"""

from __future__ import annotations

import dataclasses
import warnings
from functools import partial
from typing import Optional, Sequence, Union

import jax.numpy as jnp
import numpy as np

from repro.api.paths import extract_path, stitch_bidirectional_path
from repro.api.queries import (
    BoundedRadius,
    BoundedRadiusResult,
    ManyToMany,
    ManyToManyResult,
    MultiSource,
    MultiSourceResult,
    PointToPoint,
    PointToPointResult,
    Query,
    Result,
    SingleSource,
    SingleSourceResult,
    Telemetry,
    UpdateBatch,
)
from repro.core.backends import dist_of, make_backend
from repro.core.delta_stepping import (
    DeltaConfig,
    _finish_pred,
    _finish_pred_many,
    _require_x64,
    _run_many_seq,
    _run_many_vmapped,
    _run_one,
    _run_one_bounded,
    _run_one_p2p,
    _run_one_warm,
    _run_policy_bounded,
    _run_policy_many_seq,
    _run_policy_many_vmapped,
    _run_policy_one,
    _run_policy_p2p,
    _run_policy_warm,
    pred_argmin,
)
from repro.core.policies import RadiiStore, make_policy
from repro.dynamic import Resident, apply_weight_update, plan_repair
from repro.graphs.structures import COOGraph, INF32


class UpdateRefused(ValueError):
    """Structured refusal of a dynamic update the plan cannot apply.

    ``reason`` is a stable machine-readable tag (currently
    ``"grid_costs"``: grid-stencil plans take their costs from
    ``DeltaConfig.grid_costs``, not the COO weight array). The serving
    tier keys on it to shed the offending request per-ticket instead of
    treating the refusal as a batch-loop failure; direct callers still
    get an ordinary ``ValueError`` (this is a subclass).

    >>> try:
    ...     raise UpdateRefused("no", reason="grid_costs")
    ... except ValueError as e:
    ...     e.reason
    'grid_costs'
    """

    def __init__(self, message: str, *, reason: str):
        super().__init__(message)
        self.reason = reason


class LandmarkRefused(UpdateRefused):
    """Refusal of a weight batch that would invalidate the plan's
    landmark tables under the ``on_update="refuse"`` policy
    (``Plan.prepare_landmarks``): some new weight drops below its
    table-build value, so the precomputed ALT potentials would stop
    being admissible. Raised BEFORE any weight is applied — the plan
    (and its tables) are untouched, and the serving tier sheds the
    ticket on the standard ``UpdateRefused`` path.

    >>> try:
    ...     raise LandmarkRefused("no", reason="landmarks_stale")
    ... except UpdateRefused as e:
    ...     e.reason
    'landmarks_stale'
    """


@dataclasses.dataclass(frozen=True)
class Tuning:
    """The one tuning knob of ``Engine``: how the operating point is
    resolved. ``measure=True`` runs the successive-halving measured
    search (DESIGN.md §7); ``cache`` names the persistent fingerprint-
    keyed record store consulted first (and, for measured searches,
    written back). ``Tuning()`` — no measurement, no cache — is the
    zero-measurement estimator, spelled ``tuning="auto"`` for short;
    ``tuning="measure"`` is ``Tuning(measure=True)``; any other string
    is taken as a cache path.

    >>> Tuning(measure=True, cache="tuning.json").measure
    True
    """

    measure: bool = False
    cache: Optional[str] = None


def _normalize_tuning(tuning) -> Optional[Tuning]:
    if tuning is None or isinstance(tuning, Tuning):
        return tuning
    if isinstance(tuning, str):
        if tuning == "auto":
            return Tuning()
        if tuning == "measure":
            return Tuning(measure=True)
        return Tuning(cache=tuning)
    raise ValueError(
        "tuning must be None, 'auto', 'measure', a cache path or a "
        f"Tuning(...), got {tuning!r}"
    )


def _mark_fallback(res: Result) -> Result:
    tel = dataclasses.replace(res.telemetry, fallback=True)
    return dataclasses.replace(res, telemetry=tel)


def _check_vertex(name: str, v, n: int) -> int:
    """Host-side id validation: out-of-range ids would otherwise be
    silently dropped by the jitted scatter (an all-INF 'answer') or
    clamped by the gather (a wrong early exit)."""
    v = int(v)
    if not 0 <= v < n:
        raise ValueError(f"{name} {v} out of range for a {n}-vertex graph")
    return v


def _check_vertices(name: str, arr: np.ndarray, n: int) -> None:
    if arr.size and (int(arr.min()) < 0 or int(arr.max()) >= n):
        raise ValueError(f"{name} contain ids out of range, graph has {n}")


class Plan:
    """A compiled operating point for one graph: resolved config,
    relaxation backend, and partially-applied module-level jitted
    drivers for every query kind. Built by ``Engine.plan``; solvable
    immediately and repeatedly via ``solve(query)``, and updatable in
    place for dynamic edge costs via ``update`` / ``resolve``.

    >>> import jax.numpy as jnp
    >>> from repro.api import Engine, SingleSource
    >>> from repro.core import DeltaConfig
    >>> from repro.graphs.structures import COOGraph
    >>> g = COOGraph(jnp.array([0, 1], jnp.int32),
    ...              jnp.array([1, 2], jnp.int32),
    ...              jnp.array([2, 3], jnp.int32), 3)
    >>> plan = Engine(g, DeltaConfig(delta=4, pred_mode="argmin")).plan()
    >>> res = plan.solve(SingleSource(0))
    >>> [int(d) for d in res.dist]
    [0, 2, 5]
    >>> plan.update([0], [9]) is plan       # edge 0->1 now costs 9
    True
    >>> warm = plan.resolve(warm=True)      # repair, not a cold re-solve
    >>> [int(d) for d in warm.dist], bool(warm.telemetry.warm)
    ([0, 9, 12], True)
    """

    def __init__(
        self,
        graph: COOGraph,
        config: DeltaConfig,
        *,
        free_mask=None,
        record=None,
        fallback: bool = False,
        radii_store: Optional[str] = None,
    ):
        if config.pred_mode == "packed":
            _require_x64()
        if config.policy != "delta" and (
            free_mask is not None and config.strategy == "pallas"
        ):
            # the grid stencil recomputes bucket membership in-kernel
            # from tent // Δ — it has no frontier-mask input a policy
            # loop could drive
            raise ValueError(
                "the grid-stencil game-map path is delta-only; "
                f"policy={config.policy!r} needs a mask-driven backend"
            )
        self.graph = graph
        self.config = config
        self.record = record
        self.free_mask = free_mask
        self.backend = make_backend(graph, config, free_mask=free_mask)
        packed = config.pred_mode == "packed"
        self._packed = packed
        # frontier policy (DESIGN.md §15): 'delta' binds the classic
        # bucket-loop drivers (bit-for-bit the pre-policy plan); rho /
        # radius bind the generic policy loop over the same backend.
        # radius preprocessing persists beside the tuner cache when the
        # engine hands a store directory down.
        self._radii_store = radii_store
        self._policy = make_policy(
            graph, config,
            store=None if radii_store is None else RadiiStore(radii_store),
        )
        self._bind_drivers()
        # the one overflow-fallback point: only meaningful when a capped
        # compaction can actually overflow
        self._fallback = bool(fallback) and config.frontier_cap is not None
        self._demoted: Optional[Plan] = None
        # dynamic residency (repro.dynamic): the last SingleSource answer
        # plus the weight snapshot it was solved against
        self._resident: Optional[Resident] = None
        # warm-repair twin backend cache: (cap, graph version) -> backend
        self._graph_version = 0
        self._repair_twin = None
        self._repair_twin_key = None
        self._twin_width = None   # weight-independent ELL pad width
        self._twin_cap_floor = 64  # escalates on twin overflow (sticky)
        # landmark residency (repro.landmarks, DESIGN.md §14): built by
        # prepare_landmarks, or lazily with defaults on the first
        # landmark-mode PointToPoint
        self._landmarks = None

    def _bind_drivers(self) -> None:
        """Partially apply the module-level jitted drivers for the
        plan's policy. Every query kind dispatches through these five
        attributes, so the policy axis is invisible past this point."""
        n = self.graph.n_nodes
        packed = self._packed
        if self.config.policy == "delta":
            self._run1 = partial(_run_one, n=n, packed=packed)
            many = (_run_many_vmapped if self.backend.supports_vmap
                    else _run_many_seq)
            self._run_many = partial(many, n=n, packed=packed)
            self._run_p2p = partial(_run_one_p2p, n=n, packed=packed)
            self._run_bounded = partial(_run_one_bounded, n=n, packed=packed)
            self._run_warm = partial(_run_one_warm, n=n, packed=packed)
        else:
            pol = self._policy
            self._run1 = partial(_run_policy_one, policy=pol, n=n,
                                 packed=packed)
            many = (_run_policy_many_vmapped if self.backend.supports_vmap
                    else _run_policy_many_seq)
            self._run_many = partial(many, policy=pol, n=n, packed=packed)
            self._run_p2p = partial(_run_policy_p2p, policy=pol, n=n,
                                    packed=packed)
            self._run_bounded = partial(_run_policy_bounded, policy=pol,
                                        n=n, packed=packed)
            self._run_warm = partial(_run_policy_warm, policy=pol, n=n,
                                     packed=packed)

    # -- the one public operation -------------------------------------------

    def solve(self, query: Query) -> Result:
        """Answer one query. With fallback enabled, a solve that trips
        the compacted-frontier overflow flag is re-answered by the
        full-width twin plan (and the plan demotes to it permanently —
        a query mix that overflowed once would otherwise pay capped +
        uncapped solves on every call).

        ``UpdateBatch`` is the one query kind that mutates the plan: it
        routes through ``update`` + ``resolve`` and does not participate
        in overflow demotion (the warm contract itself refuses an
        overflowed resident state, and the overflow flag of the re-solve
        is reported in its telemetry)."""
        if isinstance(query, UpdateBatch):
            self.update(query.edge_ids, query.new_weights)
            return self.resolve(warm=query.warm)
        if self._demoted is not None:
            return _mark_fallback(self._demoted._dispatch(query))
        res = self._dispatch(query)
        if self._fallback and bool(np.any(np.asarray(res.telemetry.overflow))):
            self._demoted = Plan(
                self.graph,
                dataclasses.replace(self.config, frontier_cap=None),
                free_mask=self.free_mask,
                record=self.record,
                radii_store=self._radii_store,
            )
            # residency survives demotion: the resident answer was
            # solved on the same graph/pred_mode (only the cap differs),
            # so update/resolve keep working after an overflow demotes
            self._demoted._resident = self._resident
            # landmark residency too: tables/spec only depend on the
            # graph, never on the frontier cap
            self._demoted._landmarks = self._landmarks
            res = _mark_fallback(self._demoted._dispatch(query))
        return res

    # -- dynamic updates (repro.dynamic, DESIGN.md §11) ----------------------

    def update(self, edge_ids, new_weights) -> "Plan":
        """Apply an edge-cost update batch to the plan in place: swap
        weights (topology fixed), rebuild the relaxation backend on the
        updated graph, and leave the resident answer untouched until the
        next ``resolve`` diffs against its snapshot — so several update
        batches between resolves compose naturally. Returns ``self``.

        The tuning record attached at plan time stays attached: the
        cache fingerprint keys on structural statistics and the weight
        *range*, so in-range cost churn reuses the record
        (tests/test_dynamic.py asserts this)."""
        if self.free_mask is not None and self.config.strategy == "pallas":
            raise UpdateRefused(
                "grid-stencil (game-map) plans take their costs from "
                "DeltaConfig.grid_costs, not the COO weight array — "
                "edge-weight updates do not apply to them",
                reason="grid_costs",
            )
        lm = self._landmarks
        if (lm is not None and lm.spec.on_update == "refuse"
                and lm.would_invalidate(edge_ids, new_weights)):
            # checked BEFORE applying: a refused batch leaves both the
            # weights and the landmark tables exactly as they were
            raise LandmarkRefused(
                "weight decrease below the landmark-table build values "
                "would invalidate the precomputed ALT potentials (plan "
                "prepared with on_update='refuse')",
                reason="landmarks_stale",
            )
        self.graph = apply_weight_update(self.graph, edge_ids, new_weights)
        self.backend = self._rebuild_backend()
        self._graph_version += 1
        if self.config.policy == "radius":
            # step radii derive from the weights: recompute (or re-fetch)
            # and rebind the drivers. The radius policy keeps r as a
            # pytree *leaf*, so the rebinding swaps arrays without
            # retracing the compiled loop.
            self._policy = make_policy(
                self.graph, self.config,
                store=(None if self._radii_store is None
                       else RadiiStore(self._radii_store)),
            )
            self._bind_drivers()
        if lm is not None:
            lm.note_update(self.graph)
        if self._demoted is not None:
            self._demoted.update(edge_ids, new_weights)
        return self

    def _rebuild_backend(self):
        """Backend over the updated weights. The ELL-family strategies
        pad their light/heavy blocks to the tightest per-block width by
        default — a width that *moves with edge costs* (the split is
        w <= Δ), which would retrace the jitted drivers on every update
        batch. Rebuilds pin the weight-independent full adjacency
        degree instead: the first update may recompile once (wider
        shapes than the plan-time build), every later one reuses it.
        ``sharded_ell`` keeps the default partition build and may
        retrace when the split widths move."""
        if self.config.strategy == "ell":
            from repro.core.backends import EllBackend

            return EllBackend.build(
                self.graph, self.config, max_deg=self._adjacency_width()
            )
        if self.config.strategy == "pallas" and self.free_mask is None:
            from repro.core.backends import PallasEllBackend

            return PallasEllBackend.build(
                self.graph, self.config, max_deg=self._adjacency_width()
            )
        if self.config.strategy == "fused":
            from repro.core.backends import FusedBackend

            return FusedBackend.build(
                self.graph, self.config, max_deg=self._adjacency_width()
            )
        return make_backend(self.graph, self.config, free_mask=self.free_mask)

    def _adjacency_width(self) -> int:
        """Max out-degree — the weight-independent ELL pad width
        (topology never changes, so compute once per plan)."""
        if self._twin_width is None:
            deg = np.bincount(
                np.asarray(self.graph.src), minlength=self.graph.n_nodes
            )
            self._twin_width = max(1, int(deg.max())) if deg.size else 1
        return self._twin_width

    def _warm_backend(self, repaired: int):
        """Backend for a warm repair solve. Repair frontiers are tiny
        (the whole point), so for the single-device strategies the sweep
        runs on a frontier-compacted ELL twin whose capacity is a small
        power-of-two head-room over the seed count — O(cap·deg) work per
        sweep instead of O(|E|). Safe because the overflow flag guards a
        full-width re-run in ``resolve``, and *exact* because every
        warm-eligible mode has a schedule-free fixed point (dist always;
        packed words on the canonical class — DESIGN.md §11), so the
        twin converges to bitwise the same answer as the plan's own
        backend. A ``fused`` plan gets a capped *fused* twin — staying
        on the fused driver loop keeps the warm solve on the exact code
        path the cold-identity lemma was checked against.
        Sharded/pallas plans keep their own backend."""
        if self.config.strategy not in ("edge", "ell", "fused"):
            return self.backend
        n = self.graph.n_nodes
        cap = self._twin_cap_floor
        while cap < repaired * 2:
            cap *= 2
        own_cap = self.config.frontier_cap or n
        if cap >= n or (
            self.config.strategy in ("ell", "fused") and cap >= own_cap
        ):
            return self.backend
        key = (cap, self._graph_version)
        if self._repair_twin_key != key:
            from repro.core.backends import EllBackend, FusedBackend

            twin_strategy = "fused" if self.config.strategy == "fused" else "ell"
            twin_cls = FusedBackend if twin_strategy == "fused" else EllBackend
            twin_cfg = dataclasses.replace(
                self.config, strategy=twin_strategy, frontier_cap=cap
            )
            # pinned pad width: cost churn must not move the twin's
            # compiled shapes (see _rebuild_backend)
            self._repair_twin = twin_cls.build(
                self.graph, twin_cfg, max_deg=self._adjacency_width()
            )
            self._repair_twin_key = key
        return self._repair_twin

    def resolve(self, warm: bool = True) -> SingleSourceResult:
        """Re-solve the plan's resident single-source problem against
        the current (updated) weights. ``warm=True`` repairs from the
        resident answer: changed edges seed a repair frontier (decreases
        enter their new bucket directly; increases reset and re-seed the
        predecessor-tree cone) and the generalized bucket loop re-settles
        only what the perturbation can reach — bitwise identical to the
        cold solve, per the repro.dynamic contract. Updates outside the
        warm contract (see ``dynamic.plan_repair``) re-solve cold;
        telemetry reports which path ran (``warm``/``repaired``/``cone``).
        """
        if self._demoted is not None:
            return _mark_fallback(self._demoted.resolve(warm=warm))
        r = self._resident
        if r is None:
            raise ValueError(
                "resolve() repairs the plan's resident state — solve a "
                "SingleSource query first to establish it"
            )
        src = jnp.asarray(r.source, jnp.int32)
        rep = reason = None
        if warm:
            rep, reason = plan_repair(
                self.graph, r, pred_mode=self.config.pred_mode
            )
        if rep is not None and rep.repaired == 0:
            # distance-neutral churn: distances stand as-is. argmin
            # preds are a function of (distances, *current* weights),
            # and a tie can move without any distance moving (a decrease
            # landing exactly on dist[v] creates a new smaller-id tight
            # parent) — recompute the tree against the updated graph;
            # packed ties are covered by the repair's word-order seeds,
            # and 'none' tracks no tree
            dist = jnp.asarray(r.dist, jnp.int32)
            if self.config.pred_mode == "argmin":
                g = self.graph
                pred = pred_argmin(
                    dist, g.src, g.dst, g.w, src, n=g.n_nodes
                )
            else:
                pred = jnp.asarray(r.pred, jnp.int32)
            self._remember(r.source, dist, pred, r.overflow)
            zero = jnp.zeros((), jnp.int32)
            return SingleSourceResult(
                dist,
                pred,
                Telemetry(
                    zero, zero, jnp.zeros((), bool), warm=True, repaired=0, cone=0
                ),
            )
        if rep is not None:
            tent0 = jnp.asarray(rep.tent0)
            explored0 = jnp.asarray(rep.explored0)
            backend = self._warm_backend(rep.repaired)
            tent, outer, inner, over = self._run_warm(backend, tent0, explored0)
            if backend is not self.backend and bool(np.any(np.asarray(over))):
                # repair cascade outgrew the capped twin: re-run the
                # same warm state full-width (answers never depend on
                # the cap — it only moves time), and escalate the cap
                # floor so this workload's later repairs fit first try
                self._twin_cap_floor = min(backend.cap * 4, self.graph.n_nodes)
                tent, outer, inner, over = self._run_warm(
                    self.backend, tent0, explored0
                )
            tel = Telemetry(
                outer, inner, over, warm=True, repaired=rep.repaired, cone=rep.cone
            )
        else:
            tent, outer, inner, over = self._run1(self.backend, src)
            tel = Telemetry(outer, inner, over, warm=False)
        dist, pred = _finish_pred(tent, self.graph, src, self.config)
        self._remember(r.source, dist, pred, over)
        return SingleSourceResult(dist, pred, tel)

    def _remember(self, source, dist, pred, over) -> None:
        self._resident = Resident(
            source=int(source),
            dist=np.asarray(dist, np.int64),
            pred=np.asarray(pred, np.int32),
            w=np.array(np.asarray(self.graph.w), np.int32),
            overflow=bool(np.any(np.asarray(over))),
        )

    # -- landmark residency (repro.landmarks, DESIGN.md §14) -----------------

    def prepare_landmarks(
        self,
        k: int = 4,
        strategy: str = "farthest",
        seed: int = 0,
        *,
        store: Optional[str] = None,
        on_update: str = "recompute",
        build: bool = True,
    ) -> "Plan":
        """Attach landmark residency to the plan: ``k`` landmarks chosen
        by ``strategy`` (``farthest``/``random``), distance tables
        persisted in the fingerprint-keyed ``store`` directory (``None``
        = in-memory), and the ``on_update`` staleness policy for weight
        batches that would invalidate the tables (``recompute`` drops
        and lazily rebuilds them; ``refuse`` rejects the batch with
        ``LandmarkRefused`` before applying it). ``build=False`` defers
        the precompute to the first landmark-mode query (the serving
        tier's lazy per-tenant configuration). Returns ``self``."""
        from repro.landmarks import LandmarkSpec, LandmarkState, require_canonical

        spec = LandmarkSpec(k=k, strategy=strategy, seed=seed,
                            store=store, on_update=on_update)
        self._landmarks = LandmarkState(spec, self.config.delta)
        if build:
            # deferred builds re-check at the first landmark-mode query
            # (solve_p2p), so a server-wide landmarks knob cannot break
            # a non-canonical tenant that never asks for these modes
            require_canonical(self.graph)
            self._landmarks.ensure_tables(self.graph)
        if self._demoted is not None:
            self._demoted._landmarks = self._landmarks
        return self

    def _landmark_state(self):
        """The plan's landmark residency, created with the default spec
        on first use (a landmark-mode query against an unprepared plan
        still works — it just pays the table build lazily)."""
        if self._landmarks is None:
            from repro.landmarks import LandmarkSpec, LandmarkState

            self._landmarks = LandmarkState(LandmarkSpec(), self.config.delta)
        return self._landmarks

    @property
    def landmark_tables(self):
        """The resident ``LandmarkTables``, or ``None`` when unprepared,
        not yet built, or invalidated by a weight update."""
        return None if self._landmarks is None else self._landmarks.tables

    def explain(self) -> dict:
        """Plan provenance for logs/telemetry: the resolved operating
        point plus the tuning record (if any) it came from."""
        cfg = self.config
        return {
            "delta": cfg.delta,
            "strategy": cfg.strategy,
            "policy": cfg.policy,
            "pred_mode": cfg.pred_mode,
            "frontier_cap": cfg.frontier_cap,
            "n_shards": cfg.n_shards,
            "p2p_mode": cfg.p2p_mode,
            "landmarks": (
                None if self.landmark_tables is None
                else self.landmark_tables.k
            ),
            "tuning_source": None if self.record is None else self.record.source,
            "fallback_taken": self._demoted is not None,
            "resident_source": (
                None if self._resident is None else self._resident.source
            ),
        }

    # -- query dispatch ------------------------------------------------------

    def _dispatch(self, query: Query) -> Result:
        if isinstance(query, SingleSource):
            return self._single(query)
        if isinstance(query, MultiSource):
            return self._multi(query)
        if isinstance(query, PointToPoint):
            return self._point_to_point(query)
        if isinstance(query, BoundedRadius):
            return self._bounded(query)
        if isinstance(query, ManyToMany):
            return self._many_to_many(query)
        raise TypeError(f"unknown query kind {type(query).__name__!r}")

    def _single(self, q: SingleSource) -> SingleSourceResult:
        src = jnp.asarray(
            _check_vertex("source", q.source, self.graph.n_nodes), jnp.int32
        )
        tent, outer, inner, over = self._run1(self.backend, src)
        dist, pred = _finish_pred(tent, self.graph, src, self.config)
        self._remember(q.source, dist, pred, over)  # dynamic residency
        return SingleSourceResult(dist, pred, Telemetry(outer, inner, over))

    def _multi(self, q: MultiSource) -> MultiSourceResult:
        host = np.asarray(q.sources, np.int64)
        if host.ndim != 1:
            raise ValueError("sources must be a 1-D array of vertex ids")
        _check_vertices("sources", host, self.graph.n_nodes)
        srcs = jnp.asarray(host, jnp.int32)
        tent, outer, inner, over = self._run_many(self.backend, srcs)
        dist, pred = _finish_pred_many(tent, self.graph, srcs, self.config)
        return MultiSourceResult(dist, pred, Telemetry(outer, inner, over))

    def _point_to_point(self, q: PointToPoint) -> PointToPointResult:
        n = self.graph.n_nodes
        src = jnp.asarray(_check_vertex("source", q.source, n), jnp.int32)
        tgt = jnp.asarray(_check_vertex("target", q.target, n), jnp.int32)
        mode = q.mode if q.mode is not None else self.config.p2p_mode
        if mode != "early_exit":
            return self._p2p_landmark(q, mode)
        tent, outer, inner, over = self._run_p2p(self.backend, src, tgt)
        # every vertex on a shortest source->target path is settled at
        # early exit (its bucket precedes the target's), so the partial
        # predecessor state is exact along the returned path
        dist, pred = _finish_pred(tent, self.graph, src, self.config)
        distance = int(np.asarray(dist)[int(q.target)])
        path = None
        if distance < int(INF32) and self.config.pred_mode != "none":
            path = extract_path(
                np.asarray(pred), int(q.source), int(q.target), self.graph.n_nodes
            )
        return PointToPointResult(distance, path, Telemetry(outer, inner, over))

    def _p2p_landmark(self, q: PointToPoint, mode: str) -> PointToPointResult:
        """Goal-directed point-to-point (repro.landmarks): the landmark
        state solves over a reduced / doubled graph and hands back
        original-space predecessor trees; the distance is bitwise the
        unidirectional answer (tests/test_landmarks.py pins this), and
        the path goes through the same cycle-guarded extractors as every
        other query."""
        if self.config.policy != "delta":
            raise ValueError(
                "landmark p2p modes (alt/bidirectional) run the bucket "
                "loop's all-light drivers and are delta-only; "
                f"policy={self.config.policy!r} plans answer "
                "PointToPoint via mode='early_exit'"
            )
        lm = self._landmark_state()
        want_pred = self.config.pred_mode != "none"
        r = lm.solve_p2p(self.graph, q.source, q.target, mode,
                         want_pred=want_pred)
        tel = Telemetry(np.int32(r.outer), np.int32(r.inner),
                        np.bool_(r.overflow))
        if r.distance >= int(INF32) or not want_pred:
            return PointToPointResult(r.distance, None, tel)
        n = self.graph.n_nodes
        if r.pred_b is None:
            path = extract_path(np.asarray(r.pred_f), int(q.source),
                                int(q.target), n)
        else:
            path = stitch_bidirectional_path(
                np.asarray(r.pred_f), np.asarray(r.pred_b),
                int(q.source), int(q.target), r.meet, n)
        return PointToPointResult(r.distance, path, tel)

    def _bounded(self, q: BoundedRadius) -> BoundedRadiusResult:
        radius = int(q.radius)
        if not 0 <= radius < int(INF32):
            raise ValueError(f"radius must be in [0, INF32), got {radius}")
        src = jnp.asarray(
            _check_vertex("source", q.source, self.graph.n_nodes), jnp.int32
        )
        r_arr = jnp.asarray(radius, jnp.int32)
        tent, outer, inner, over = self._run_bounded(self.backend, src, r_arr)
        dist, pred = _finish_pred(tent, self.graph, src, self.config)
        # all buckets <= radius // delta were processed, so every vertex
        # with true distance <= radius is settled; the rest are filtered
        # to the unreachable sentinels (their tent values are bounds,
        # not answers)
        within = dist <= radius
        dist = jnp.where(within, dist, jnp.int32(INF32))
        pred = jnp.where(within, pred, jnp.int32(-1))
        return BoundedRadiusResult(dist, pred, radius, Telemetry(outer, inner, over))

    def _many_to_many(self, q: ManyToMany) -> ManyToManyResult:
        n = self.graph.n_nodes
        sources = [_check_vertex("source", s, n) for s in q.sources]
        targets = np.asarray([int(t) for t in q.targets], np.int64)
        if not sources or targets.size == 0:
            raise ValueError("ManyToMany needs non-empty sources and targets")
        _check_vertices("targets", targets, n)
        tile = int(q.tile) if q.tile is not None else min(len(sources), 8)
        if tile < 1:
            raise ValueError(f"tile must be >= 1, got {tile}")
        matrix = np.full((len(sources), len(targets)), int(INF32), np.int64)
        buckets, inner_total, over_any = 0, 0, False
        for lo in range(0, len(sources), tile):
            chunk = sources[lo : lo + tile]
            # short tiles repeat the last source so every tile runs the
            # same compiled shape (the padded lanes are discarded)
            padded = chunk + [chunk[-1]] * (tile - len(chunk))
            srcs = jnp.asarray(padded, jnp.int32)
            tent, outer, inner, over = self._run_many(self.backend, srcs)
            d = np.asarray(dist_of(tent, self._packed))
            matrix[lo : lo + len(chunk)] = d[: len(chunk)][:, targets]
            buckets = max(buckets, int(np.max(np.asarray(outer))))
            inner_total += int(np.sum(np.asarray(inner)))
            over_any = over_any or bool(np.any(np.asarray(over)))
        tel = Telemetry(np.int32(buckets), np.int32(inner_total), np.bool_(over_any))
        return ManyToManyResult(matrix, tel)


class Engine:
    """Façade entry point: holds the graph plus the tuning inputs, and
    mints ``Plan``s. ``config`` is a concrete ``DeltaConfig`` (used
    as-is, or as the tuning *base* — its non-searched fields carry into
    the resolved plan) or ``None``; ``tuning`` is the one resolution
    knob: ``None`` (concrete config as-is), ``"auto"`` (zero-measurement
    estimator), ``"measure"`` (measured search), a cache path, or a
    ``Tuning(measure=..., cache=...)``. ``Engine(graph)`` with neither
    defaults to ``tuning="auto"``. The pre-redesign spellings —
    ``config="auto"``, ``tune=True``, ``tune_cache=path`` — survive as
    deprecated shims mapping onto exactly those semantics.

    >>> import jax.numpy as jnp
    >>> from repro.api import Engine, PointToPoint
    >>> from repro.core import DeltaConfig
    >>> from repro.graphs.structures import COOGraph
    >>> g = COOGraph(jnp.array([0, 1, 0], jnp.int32),
    ...              jnp.array([1, 2, 2], jnp.int32),
    ...              jnp.array([1, 1, 5], jnp.int32), 3)
    >>> plan = Engine(g, DeltaConfig(delta=2, pred_mode="argmin")).plan()
    >>> res = plan.solve(PointToPoint(0, 2))
    >>> (res.distance, res.path)
    (2, [0, 1, 2])
    """

    def __init__(
        self,
        graph: COOGraph,
        config: Union[DeltaConfig, str, None] = None,
        *,
        free_mask=None,
        tuning: Union[Tuning, str, None] = None,
        tune: bool = False,
        tune_cache: Optional[str] = None,
    ):
        if isinstance(config, str):
            if config != "auto":
                raise ValueError(
                    f"unknown config string {config!r} (did you mean "
                    "'auto' or a DeltaConfig?)"
                )
            warnings.warn(
                "config='auto' is deprecated: use Engine(graph, "
                "tuning='auto') (or just Engine(graph))",
                DeprecationWarning,
                stacklevel=2,
            )
            config = None
            if tuning is None and not (tune or tune_cache is not None):
                tuning = "auto"
        if tune or tune_cache is not None:
            warnings.warn(
                "tune=/tune_cache= are deprecated: use tuning="
                "Tuning(measure=..., cache=...)",
                DeprecationWarning,
                stacklevel=2,
            )
            if tuning is None:
                tuning = Tuning(measure=bool(tune), cache=tune_cache)
        if config is None and tuning is None:
            tuning = "auto"  # Engine(graph) keeps its auto-resolve default
        self.graph = graph
        self.free_mask = free_mask
        self._config = config
        self._tuning = _normalize_tuning(tuning)

    def plan(
        self,
        *,
        sources: Optional[Sequence[int]] = None,
        fallback: bool = False,
    ) -> Plan:
        """Resolve the operating point once and return the compiled
        ``Plan``. ``sources`` are the vertices the caller will actually
        solve from: a tuning-chosen ``frontier_cap`` is validated
        against exactly those (one shared ``build_safe_solver`` path)
        and dropped on overflow; ``sources=None`` — a plan that cannot
        know its future queries — drops a tuned cap outright and can
        instead serve with ``fallback=True`` (per-query overflow
        re-solve, the ``SSSPServer`` configuration)."""
        cfg, record = self._resolve(sources)
        return Plan(
            self.graph,
            cfg,
            free_mask=self.free_mask,
            record=record,
            fallback=fallback,
            radii_store=self._radii_store_path(),
        )

    def _radii_store_path(self) -> Optional[str]:
        """Radius-stepping preprocessing lives beside the tuner cache
        (``<cache>.radii/``) whenever the engine has a persistent cache;
        engines without one keep radii in memory."""
        if self._tuning is not None and self._tuning.cache:
            return f"{self._tuning.cache}.radii"
        return None

    def _resolve(self, sources):
        if self._tuning is None:
            return self._config, None  # concrete config, no tuning: as-is
        from repro.tune import resolve_record  # lazy: tune builds on core/api

        base = DeltaConfig() if self._config is None else self._config
        return resolve_record(
            self.graph,
            base,
            free_mask=self.free_mask,
            cache_path=self._tuning.cache,
            measure=self._tuning.measure,
            sources=sources,
        )


__all__ = ["Engine", "LandmarkRefused", "Plan", "Tuning", "UpdateRefused"]
