"""Host-side path recovery from predecessor arrays.

One implementation shared by the façade's ``PointToPoint`` dispatch and
the serving layer (``serve.SSSPServer``), so path semantics cannot
diverge between the two: walk the predecessor chain target -> source,
bounded by ``n_nodes`` hops — a chain that does not reach the source
within n hops is either an unreachable target or an off-tree cycle
(``pred_mode='argmin'`` on a zero-weight tie, see pack.py) and yields
``None`` instead of looping forever.
"""

from __future__ import annotations

from typing import List, Optional

import numpy as np


def extract_path(
    pred: np.ndarray, source: int, target: int, n_nodes: int
) -> Optional[List[int]]:
    """Source->target vertex list from a predecessor array, or ``None``
    when the chain does not reach the source.

    >>> import numpy as np
    >>> pred = np.array([-1, 0, 1, -1], np.int32)   # tree 0 -> 1 -> 2
    >>> extract_path(pred, 0, 2, 4)
    [0, 1, 2]
    >>> extract_path(pred, 0, 0, 4)                 # source == target
    [0]
    >>> extract_path(pred, 0, 3, 4) is None         # unreachable target
    True
    """
    source, target = int(source), int(target)
    path = [target]
    for _ in range(n_nodes):
        if path[-1] == source:
            return path[::-1]
        p = int(pred[path[-1]])
        if p < 0:
            return None
        path.append(p)
    return path[::-1] if path[-1] == source else None


def stitch_bidirectional_path(
    pred_f: np.ndarray,
    pred_b: np.ndarray,
    source: int,
    target: int,
    meet: int,
    n_nodes: int,
) -> Optional[List[int]]:
    """Full source->target path through the meeting vertex of a
    bidirectional solve (repro.landmarks, DESIGN.md §14). ``pred_f`` is
    a forward shortest-path tree rooted at ``source`` on the original
    graph; ``pred_b`` a tree rooted at ``target`` on the REVERSED graph,
    so its root-ward walk from ``meet`` traverses an original-direction
    meet->target path. Both legs go through the same cycle-guarded
    :func:`extract_path`; either leg failing yields ``None``.

    >>> import numpy as np
    >>> pf = np.array([-1, 0, 1, -1], np.int32)     # 0 -> 1 -> 2
    >>> pb = np.array([-1, -1, 3, -1], np.int32)    # reversed tree: 3 -> 2
    >>> stitch_bidirectional_path(pf, pb, 0, 3, 2, 4)
    [0, 1, 2, 3]
    >>> stitch_bidirectional_path(pf, pb, 0, 3, 0, 4) is None
    True
    """
    fwd = extract_path(pred_f, source, meet, n_nodes)
    bwd = extract_path(pred_b, target, meet, n_nodes)
    if fwd is None or bwd is None:
        return None
    return fwd + bwd[::-1][1:]


__all__ = ["extract_path", "stitch_bidirectional_path"]
