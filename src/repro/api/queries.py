"""The query algebra and typed results of the Query/Plan façade.

A query names *what* to compute against a planned graph; the ``Plan``
(engine.py) decides *how* — which pre-lowered jitted driver runs and
which early-exit rule applies (DESIGN.md §10):

* ``SingleSource``  — the paper's kernel: full distance vector (+ tree).
* ``MultiSource``   — batched sources, one vmapped program; lane ``i``
  is bitwise identical to ``SingleSource(sources[i])``.
* ``PointToPoint``  — one (source, target) pair with early exit once
  the target's bucket settles (Kainer & Träff 2019): a settled bucket
  bounds all later tent values, so the target's distance is final as
  soon as its bucket index drops below the next bucket to process.
* ``BoundedRadius`` — all vertices within distance ``radius`` of the
  source (nearest-POI workloads); the outer loop stops at the first
  bucket past ``radius // delta`` and everything farther reports as
  unreachable.
* ``ManyToMany``    — an |S| x |T| distance matrix assembled from tiled
  multi-source solves (betweenness/matrix workloads); every tile runs
  the same compiled multi-source program.
* ``UpdateBatch``   — a dynamic-graph edge-cost update plus re-solve of
  the plan's resident single-source problem (DESIGN.md §11); with
  ``warm=True`` the re-solve repairs from the previous answer instead
  of starting cold, bitwise identically.

Every result carries a ``Telemetry`` record of what the solve actually
did — buckets processed, light-phase inner iterations, the compacted-
frontier overflow flag, whether the plan's overflow fallback re-solved
the query full-width, and (for dynamic re-solves) how many vertices the
warm repair actually touched.
"""

from __future__ import annotations

import dataclasses
from typing import Any, List, Optional, Sequence, Union


@dataclasses.dataclass(frozen=True)
class SingleSource:
    """Full SSSP from one source: distance vector + predecessor tree.

    Solving it also establishes the plan's *resident* state — the
    starting point ``Plan.update`` / ``Plan.resolve`` repair from.

    >>> SingleSource(7)
    SingleSource(source=7)
    """

    source: int


@dataclasses.dataclass(frozen=True)
class MultiSource:
    """Batched SSSP from several sources (one vmapped program; each
    lane is bitwise identical to the corresponding ``SingleSource``).

    >>> MultiSource([0, 3, 5]).sources
    [0, 3, 5]
    """

    sources: Sequence[int]


@dataclasses.dataclass(frozen=True)
class PointToPoint:
    """One source -> target distance (and path, when the plan tracks
    predecessors). ``mode`` picks the point-to-point algorithm
    (``core.P2P_MODES``): ``early_exit`` stops once the target's bucket
    settles; ``alt`` / ``bidirectional`` / ``alt_bidirectional`` are the
    goal-directed landmark modes (repro.landmarks, DESIGN.md §14) — all
    four return bitwise-identical distances. ``None`` defers to the
    plan's ``DeltaConfig.p2p_mode`` (tunable, see ``tune.tune_p2p``).

    >>> q = PointToPoint(source=0, target=42)
    >>> (q.source, q.target, q.mode)
    (0, 42, None)
    """

    source: int
    target: int
    mode: Optional[str] = None


@dataclasses.dataclass(frozen=True)
class BoundedRadius:
    """Distances of every vertex within ``radius`` of the source;
    vertices farther than ``radius`` report as unreachable.

    >>> BoundedRadius(0, 150).radius
    150
    """

    source: int
    radius: int


@dataclasses.dataclass(frozen=True)
class ManyToMany:
    """|S| x |T| distance matrix, assembled from multi-source solves
    tiled ``tile`` sources at a time (default: min(|S|, 8)).

    >>> ManyToMany(sources=[0, 1], targets=[5, 6, 7]).tile is None
    True
    """

    sources: Sequence[int]
    targets: Sequence[int]
    tile: Optional[int] = None


@dataclasses.dataclass(frozen=True)
class UpdateBatch:
    """Edge-cost update batch + re-solve of the resident single-source
    problem: ``plan.solve(UpdateBatch(ids, weights))`` is exactly
    ``plan.update(ids, weights)`` followed by ``plan.resolve(warm=...)``
    and returns the refreshed ``SingleSourceResult``. ``edge_ids`` index
    the graph's COO edge arrays; topology never changes, only costs.

    >>> UpdateBatch(edge_ids=[3, 9], new_weights=[12, 1])
    UpdateBatch(edge_ids=[3, 9], new_weights=[12, 1], warm=True)
    """

    edge_ids: Sequence[int]
    new_weights: Sequence[int]
    warm: bool = True


Query = Union[
    SingleSource,
    MultiSource,
    PointToPoint,
    BoundedRadius,
    ManyToMany,
    UpdateBatch,
]


@dataclasses.dataclass(frozen=True)
class Telemetry:
    """What one solve actually did. ``buckets`` / ``inner_iters`` /
    ``overflow`` are the driver's raw counters (jax scalars, or arrays
    with a leading batch axis for ``MultiSource``); ``fallback`` is True
    when the plan's overflow fallback answered the query full-width.

    The dynamic-update fields describe a ``Plan.resolve`` /
    ``UpdateBatch`` re-solve: ``warm`` is True when the answer came from
    the warm-start repair path (False: cold re-solve, e.g. an update
    outside the warm contract); ``repaired`` counts the vertices the
    repair re-seeded or reset, of which ``cone`` were reset by the
    increase cone — both ``None`` on ordinary queries.

    >>> t = Telemetry(buckets=4, inner_iters=9, overflow=False)
    >>> (t.fallback, t.warm, t.repaired, t.cone)
    (False, False, None, None)
    """

    buckets: Any
    inner_iters: Any
    overflow: Any
    fallback: bool = False
    warm: bool = False
    repaired: Optional[int] = None
    cone: Optional[int] = None


@dataclasses.dataclass(frozen=True)
class SingleSourceResult:
    """``dist`` int32[n] (INF32 = unreachable), ``pred`` int32[n]
    (-1 = source/unreachable) — bitwise identical to the deprecated
    ``DeltaSteppingSolver.solve`` fields."""

    dist: Any
    pred: Any
    telemetry: Telemetry


@dataclasses.dataclass(frozen=True)
class MultiSourceResult:
    """Per-lane ``dist`` int32[B, n] / ``pred`` int32[B, n] — bitwise
    identical to the deprecated ``DeltaSteppingSolver.solve_many``."""

    dist: Any
    pred: Any
    telemetry: Telemetry


@dataclasses.dataclass(frozen=True)
class PointToPointResult:
    """``distance`` is a host int (INF32 sentinel when unreachable);
    ``path`` is the source->target vertex list, or None when the target
    is unreachable or the plan tracks no predecessors."""

    distance: int
    path: Optional[List[int]]
    telemetry: Telemetry


@dataclasses.dataclass(frozen=True)
class BoundedRadiusResult:
    """``dist``/``pred`` filtered to the radius: vertices with
    dist > radius carry the INF32 / -1 sentinels (their true distances
    were never settled — the whole point of the early exit)."""

    dist: Any
    pred: Any
    radius: int
    telemetry: Telemetry


@dataclasses.dataclass(frozen=True)
class ManyToManyResult:
    """``matrix`` int64[|S|, |T|] host array with the INF32 sentinel for
    unreachable pairs; telemetry aggregates across tiles (max buckets,
    summed inner iterations, any-overflow)."""

    matrix: Any
    telemetry: Telemetry


Result = Union[
    SingleSourceResult,
    MultiSourceResult,
    PointToPointResult,
    BoundedRadiusResult,
    ManyToManyResult,
]

__all__ = [
    "BoundedRadius",
    "BoundedRadiusResult",
    "ManyToMany",
    "ManyToManyResult",
    "MultiSource",
    "MultiSourceResult",
    "PointToPoint",
    "PointToPointResult",
    "Query",
    "Result",
    "SingleSource",
    "SingleSourceResult",
    "Telemetry",
    "UpdateBatch",
]
