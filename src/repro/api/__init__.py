"""Query/Plan façade — the public entry point of the Δ-stepping engine
(DESIGN.md §10, §11).

    from repro.api import Engine, SingleSource, PointToPoint, UpdateBatch

    plan = Engine(graph, tuning="auto").plan()
    full = plan.solve(SingleSource(0))           # dist/pred + telemetry
    hop = plan.solve(PointToPoint(0, 42))        # early-exit distance+path
    plan.update(edge_ids, new_weights)           # dynamic edge costs ...
    res = plan.resolve(warm=True)                # ... warm-start re-solve

``Engine`` resolves tuning / strategy / caps exactly once per plan;
``Plan.solve`` dispatches the query algebra (``SingleSource``,
``MultiSource``, ``PointToPoint``, ``BoundedRadius``, ``ManyToMany``,
``UpdateBatch``) onto pre-lowered jitted drivers shared with every
other plan of the same shape. The pre-façade entry points —
``core.DeltaSteppingSolver``, ``core.delta_stepping``,
``serve.SSSPServer`` — survive as deprecated thin shims over this
package with bitwise-identical results.
"""

from repro.api.engine import (
    Engine,
    LandmarkRefused,
    Plan,
    Tuning,
    UpdateRefused,
)
from repro.api.paths import extract_path, stitch_bidirectional_path
from repro.api.queries import (
    BoundedRadius,
    BoundedRadiusResult,
    ManyToMany,
    ManyToManyResult,
    MultiSource,
    MultiSourceResult,
    PointToPoint,
    PointToPointResult,
    Query,
    Result,
    SingleSource,
    SingleSourceResult,
    Telemetry,
    UpdateBatch,
)

__all__ = [
    "BoundedRadius",
    "BoundedRadiusResult",
    "Engine",
    "LandmarkRefused",
    "ManyToMany",
    "ManyToManyResult",
    "MultiSource",
    "MultiSourceResult",
    "Plan",
    "PointToPoint",
    "PointToPointResult",
    "Query",
    "Result",
    "SingleSource",
    "SingleSourceResult",
    "Telemetry",
    "Tuning",
    "UpdateBatch",
    "UpdateRefused",
    "extract_path",
    "stitch_bidirectional_path",
]
