"""Query/Plan façade — the public entry point of the Δ-stepping engine
(DESIGN.md §10).

    from repro.api import Engine, SingleSource, PointToPoint

    plan = Engine(graph, config="auto").plan()
    full = plan.solve(SingleSource(0))           # dist/pred + telemetry
    hop = plan.solve(PointToPoint(0, 42))        # early-exit distance+path

``Engine`` resolves tuning / strategy / caps exactly once per plan;
``Plan.solve`` dispatches the query algebra (``SingleSource``,
``MultiSource``, ``PointToPoint``, ``BoundedRadius``, ``ManyToMany``)
onto pre-lowered jitted drivers shared with every other plan of the
same shape. The pre-façade entry points — ``core.DeltaSteppingSolver``,
``core.delta_stepping``, ``serve.SSSPServer`` — survive as deprecated
thin shims over this package with bitwise-identical results.
"""

from repro.api.engine import Engine, Plan
from repro.api.paths import extract_path
from repro.api.queries import (
    BoundedRadius,
    BoundedRadiusResult,
    ManyToMany,
    ManyToManyResult,
    MultiSource,
    MultiSourceResult,
    PointToPoint,
    PointToPointResult,
    Query,
    Result,
    SingleSource,
    SingleSourceResult,
    Telemetry,
)

__all__ = [
    "BoundedRadius",
    "BoundedRadiusResult",
    "Engine",
    "ManyToMany",
    "ManyToManyResult",
    "MultiSource",
    "MultiSourceResult",
    "Plan",
    "PointToPoint",
    "PointToPointResult",
    "Query",
    "Result",
    "SingleSource",
    "SingleSourceResult",
    "Telemetry",
    "extract_path",
]
