"""Synthetic graph generators matching the paper's experiment families.

All generators are host-side numpy (deterministic under a seed) and return
``COOGraph``. Weight conventions follow the paper (§4 Experimental setup):

* small-world / scale-free: integer weights from U(1, 20);
* game maps: 10 for straight moves, 14 for diagonal moves;
* lattices: unit or U(1, 20) weights (used for the large-diameter
  discussion in the paper's conclusion).
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp
import numpy as np

from repro.graphs.structures import COOGraph

__all__ = [
    "watts_strogatz",
    "rmat",
    "grid_map",
    "square_lattice",
    "random_graph",
]


def _finish(src, dst, w, n) -> COOGraph:
    return COOGraph(
        src=jnp.asarray(src.astype(np.int32)),
        dst=jnp.asarray(dst.astype(np.int32)),
        w=jnp.asarray(w.astype(np.int32)),
        n_nodes=int(n),
    )


def _uniform_weights(rng: np.random.Generator, m: int,
                     lo: int = 1, hi: int = 20) -> np.ndarray:
    return rng.integers(lo, hi + 1, size=m, dtype=np.int32)


def watts_strogatz(n: int, k: int, p: float, seed: int = 0,
                   w_lo: int = 1, w_hi: int = 20) -> COOGraph:
    """Watts–Strogatz small-world graph (paper §4 'Small-world graphs').

    Ring lattice with ``k`` nearest neighbours (k/2 each side), then each
    lattice edge has one endpoint rewired to a random node with
    probability ``p``. Undirected: both directions are emitted with the
    same weight. Vectorized rewiring with rejection of self loops; rare
    duplicate edges are kept (harmless for SSSP — scatter-min dedups).
    """
    if k % 2 != 0:
        raise ValueError("k must be even for a ring lattice")
    rng = np.random.default_rng(seed)
    half = k // 2
    u = np.repeat(np.arange(n, dtype=np.int64), half)
    offs = np.tile(np.arange(1, half + 1, dtype=np.int64), n)
    v = (u + offs) % n
    # Rewire the far endpoint with probability p.
    rew = rng.random(u.shape[0]) < p
    rand_v = rng.integers(0, n, size=u.shape[0], dtype=np.int64)
    v = np.where(rew, rand_v, v)
    keep = u != v  # drop self loops created by rewiring
    u, v = u[keep], v[keep]
    w = _uniform_weights(rng, u.shape[0], w_lo, w_hi)
    src = np.concatenate([u, v])
    dst = np.concatenate([v, u])
    ww = np.concatenate([w, w])
    return _finish(src, dst, ww, n)


def rmat(n: int, m: int, a: float = 0.5, b: float = 0.25, c: float = 0.1,
         d: float = 0.15, seed: int = 0, w_lo: int = 1,
         w_hi: int = 20) -> COOGraph:
    """R-MAT scale-free generator (paper §4, probabilities a=.5 b=.25 c=.1
    d=.15 citing Chakrabarti et al.). ``n`` is rounded up to a power of
    two internally for quadrant recursion; vertices >= n are folded back
    with a modulo, preserving the skewed degree distribution. Directed,
    duplicates kept (the paper's Boost generator does the same).
    """
    rng = np.random.default_rng(seed)
    scale = int(np.ceil(np.log2(max(n, 2))))
    src = np.zeros(m, dtype=np.int64)
    dst = np.zeros(m, dtype=np.int64)
    ab = a + b
    abc = a + b + c
    for _ in range(scale):
        r = rng.random(m)
        src <<= 1
        dst <<= 1
        # quadrant: a → (0,0), b → (0,1), c → (1,0), d → (1,1)
        right = (r >= a) & (r < ab) | (r >= abc)
        down = r >= ab
        dst += right.astype(np.int64)
        src += down.astype(np.int64)
    src %= n
    dst %= n
    keep = src != dst
    src, dst = src[keep], dst[keep]
    w = _uniform_weights(rng, src.shape[0], w_lo, w_hi)
    return _finish(src, dst, w, n)


def grid_map(height: int, width: int, obstacle_frac: float = 0.1,
             seed: int = 0, straight_cost: int = 10,
             diag_cost: int = 14) -> Tuple[COOGraph, np.ndarray]:
    """Game-map occupancy grid (paper §4 'Game Maps').

    Returns ``(graph, free_mask)`` where ``free_mask`` is the (H, W) bool
    occupancy grid (True = accessible). Node id = r * width + c. Edges
    connect 8-neighbouring free cells: cost 10 straight, 14 diagonal —
    the paper's convention with Δ = 13 making straight moves light and
    diagonal moves heavy.
    """
    rng = np.random.default_rng(seed)
    free = rng.random((height, width)) >= obstacle_frac
    idx = np.arange(height * width, dtype=np.int64).reshape(height, width)
    srcs, dsts, ws = [], [], []
    moves = [(-1, 0, straight_cost), (1, 0, straight_cost),
             (0, -1, straight_cost), (0, 1, straight_cost),
             (-1, -1, diag_cost), (-1, 1, diag_cost),
             (1, -1, diag_cost), (1, 1, diag_cost)]
    for dr, dc, cost in moves:
        rs = slice(max(0, -dr), height - max(0, dr))
        cs = slice(max(0, -dc), width - max(0, dc))
        rs2 = slice(max(0, dr), height + min(0, dr))
        cs2 = slice(max(0, dc), width + min(0, dc))
        ok = free[rs, cs] & free[rs2, cs2]
        srcs.append(idx[rs, cs][ok])
        dsts.append(idx[rs2, cs2][ok])
        ws.append(np.full(int(ok.sum()), cost, dtype=np.int32))
    src = np.concatenate(srcs)
    dst = np.concatenate(dsts)
    w = np.concatenate(ws)
    return _finish(src, dst, w, height * width), free


def square_lattice(side: int, seed: int = 0, weighted: bool = False) -> COOGraph:
    """2-D square lattice (4-neighbour), the large-diameter worst case the
    paper's conclusion analyses (Θ(|V|^{1/d}) iterations)."""
    rng = np.random.default_rng(seed)
    idx = np.arange(side * side, dtype=np.int64).reshape(side, side)
    srcs, dsts = [], []
    for dr, dc in [(0, 1), (1, 0)]:
        rs = slice(0, side - dr)
        cs = slice(0, side - dc)
        rs2 = slice(dr, side)
        cs2 = slice(dc, side)
        srcs.append(idx[rs, cs].ravel())
        dsts.append(idx[rs2, cs2].ravel())
    u = np.concatenate(srcs)
    v = np.concatenate(dsts)
    if weighted:
        w = _uniform_weights(rng, u.shape[0])
    else:
        w = np.ones(u.shape[0], dtype=np.int32)
    src = np.concatenate([u, v])
    dst = np.concatenate([v, u])
    ww = np.concatenate([w, w])
    return _finish(src, dst, ww, side * side)


def random_graph(n: int, m: int, seed: int = 0, w_lo: int = 1,
                 w_hi: int = 20, undirected: bool = False) -> COOGraph:
    """Erdős–Rényi-style G(n, m) used by the property-based test suite."""
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, size=m, dtype=np.int64)
    dst = rng.integers(0, n, size=m, dtype=np.int64)
    keep = src != dst
    src, dst = src[keep], dst[keep]
    w = _uniform_weights(rng, src.shape[0], w_lo, w_hi)
    if undirected:
        src, dst = np.concatenate([src, dst]), np.concatenate([dst, src])
        w = np.concatenate([w, w])
    return _finish(src, dst, w, n)
