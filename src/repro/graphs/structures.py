"""Graph containers used across the framework.

Three layouts, mirroring the paper's data structures and their TPU
adaptations (DESIGN.md §2):

* ``COOGraph`` — flat (src, dst, w) edge arrays. The edge-centric
  Δ-stepping relaxation and the GNN scatter aggregations consume this.
* ``CSRGraph`` — row_ptr/col/w. Host-side construction format; the
  neighbor sampler reads it directly.
* ``ELLGraph`` — padded (n+1, max_deg) neighbor/weight matrices with a
  sentinel row for out-of-frontier gathers. The frontier-centric
  relaxation strategy and the ``ell_relax`` Pallas kernel consume this.

All containers are registered pytrees so they can cross ``jax.jit`` /
``shard_map`` boundaries; static metadata (vertex counts, max degree)
lives in hashable aux data.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

INF32 = np.int32(2**31 - 1)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class COOGraph:
    """Edge-list graph. ``src``/``dst`` int32[E], ``w`` int32[E] >= 0."""

    src: jax.Array
    dst: jax.Array
    w: jax.Array
    n_nodes: int = dataclasses.field(metadata=dict(static=True))

    @property
    def n_edges(self) -> int:
        return int(self.src.shape[0])

    def reversed(self) -> "COOGraph":
        return COOGraph(self.dst, self.src, self.w, self.n_nodes)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class CSRGraph:
    """Compressed sparse row. ``row_ptr`` int32[n+1], ``col``/``w`` int32[E]."""

    row_ptr: jax.Array
    col: jax.Array
    w: jax.Array
    n_nodes: int = dataclasses.field(metadata=dict(static=True))

    @property
    def n_edges(self) -> int:
        return int(self.col.shape[0])

    def degrees(self):
        return self.row_ptr[1:] - self.row_ptr[:-1]


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ELLGraph:
    """ELLPACK-padded adjacency with one sentinel row.

    ``nbr``/``w`` have shape (n_nodes + 1, max_deg); invalid slots point at
    the sentinel row ``n_nodes`` with weight ``INF32`` so that a relaxation
    through them can never win a scatter-min. Row ``n_nodes`` itself is all
    sentinel, which makes gathers with out-of-range (padded) frontier
    indices harmless — the same trick the paper uses with its fixed-size
    bucket array (no synchronization, garbage writes are benign).
    """

    nbr: jax.Array
    w: jax.Array
    n_nodes: int = dataclasses.field(metadata=dict(static=True))
    max_deg: int = dataclasses.field(metadata=dict(static=True))

    @property
    def valid(self):
        return self.nbr != self.n_nodes


def coo_to_csr(g: COOGraph) -> CSRGraph:
    """Host-side COO→CSR (numpy; part of the data pipeline, not the jit path)."""
    src = np.asarray(g.src)
    dst = np.asarray(g.dst)
    w = np.asarray(g.w)
    order = np.argsort(src, kind="stable")
    src, dst, w = src[order], dst[order], w[order]
    counts = np.bincount(src, minlength=g.n_nodes).astype(np.int32)
    row_ptr = np.zeros(g.n_nodes + 1, dtype=np.int32)
    np.cumsum(counts, out=row_ptr[1:])
    return CSRGraph(
        row_ptr=jnp.asarray(row_ptr),
        col=jnp.asarray(dst.astype(np.int32)),
        w=jnp.asarray(w.astype(np.int32)),
        n_nodes=g.n_nodes,
    )


def csr_to_ell(g: CSRGraph, max_deg: int | None = None) -> ELLGraph:
    """Pad a CSR graph to ELL. Rows longer than ``max_deg`` are an error —
    callers choose truncation policies explicitly (we never silently drop
    edges of an SSSP instance)."""
    row_ptr = np.asarray(g.row_ptr)
    col = np.asarray(g.col)
    w = np.asarray(g.w)
    n = g.n_nodes
    deg = row_ptr[1:] - row_ptr[:-1]
    d = int(deg.max()) if deg.size else 0
    if max_deg is None:
        max_deg = max(d, 1)
    if d > max_deg:
        raise ValueError(f"max degree {d} exceeds ELL width {max_deg}")
    nbr = np.full((n + 1, max_deg), n, dtype=np.int32)
    ww = np.full((n + 1, max_deg), INF32, dtype=np.int32)
    # slot index of every edge within its row
    slot = np.arange(col.shape[0], dtype=np.int64) - row_ptr[:-1].repeat(deg)
    row = np.arange(n, dtype=np.int64).repeat(deg)
    nbr[row, slot] = col
    ww[row, slot] = w
    return ELLGraph(jnp.asarray(nbr), jnp.asarray(ww), n, max_deg)


def union_with_reverse(g: COOGraph) -> COOGraph:
    """Disjoint union of ``g`` with its edge-reversed copy: vertices
    ``0..n-1`` carry the original graph, vertices ``n..2n-1`` carry the
    reversed one (edge ``(u, v, w)`` also appears as ``(v+n, u+n, w)``).
    The two halves share no edges, so one Δ-stepping solve seeded at
    ``s`` (forward half) and ``t+n`` (reversed half) runs a forward and
    a backward search in bucket lockstep — the substrate of the
    bidirectional point-to-point modes (repro.landmarks, DESIGN.md §14).
    Host-side preprocessing (numpy), weight-independent up to the shared
    ``w`` array."""
    src = np.asarray(g.src)
    dst = np.asarray(g.dst)
    w = np.asarray(g.w)
    n = g.n_nodes
    return COOGraph(
        jnp.asarray(np.concatenate([src, dst + n]).astype(np.int32)),
        jnp.asarray(np.concatenate([dst, src + n]).astype(np.int32)),
        jnp.asarray(np.concatenate([w, w]).astype(np.int32)),
        2 * n,
    )


def light_heavy_split(g: CSRGraph, delta: int) -> Tuple[CSRGraph, CSRGraph]:
    """Paper Alg. 1 lines 3–5: split outgoing edges into light (w <= Δ) and
    heavy (w > Δ) CSR structures. Host-side preprocessing; the edge-centric
    jit path instead evaluates the mask on the fly (DESIGN.md §2)."""
    row_ptr = np.asarray(g.row_ptr)
    col = np.asarray(g.col)
    w = np.asarray(g.w)
    n = g.n_nodes
    deg = row_ptr[1:] - row_ptr[:-1]
    row = np.arange(n, dtype=np.int64).repeat(deg)
    light = w <= delta

    def build(mask):
        counts = np.bincount(row[mask], minlength=n).astype(np.int32)
        rp = np.zeros(n + 1, dtype=np.int32)
        np.cumsum(counts, out=rp[1:])
        return CSRGraph(jnp.asarray(rp), jnp.asarray(col[mask]),
                        jnp.asarray(w[mask]), n)

    return build(light), build(~light)
