"""Vertex partitioning for SPMD Δ-stepping (DESIGN.md §4, §9).

The paper distributes bucket entries over OpenMP threads with static
scheduling; we map that to a static 1-D partition of the vertex set over
a mesh axis. Each shard owns a contiguous vertex range plus every
outgoing edge of its range (CSR row ownership). Shards are padded to a
common edge count so the result stacks into dense arrays that
``shard_map`` can consume — padding edges use the sentinel source
``n_nodes`` which is never in any frontier.

Two stacked layouts:

* ``partition_edges`` → ``VertexPartition``: flat per-shard edge arrays
  (the ``sharded_edge`` backend and ``core.distributed`` consume this);
* ``partition_ell``   → ``ELLPartition``: per-shard light/heavy ELL row
  blocks over the owned vertex range (the ``sharded_ell`` backend
  consumes this — the paper's preprocessed light/heavy split, Alg. 1
  lines 3–5, partitioned by row owner).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.graphs.structures import (
    COOGraph,
    INF32,
    coo_to_csr,
    light_heavy_split,
)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class VertexPartition:
    """Stacked per-shard edge arrays.

    ``src``/``dst``/``w``: int32[n_shards, max_edges_per_shard]. Padding
    slots have src == n_nodes (sentinel) and w == INF32. ``vstart``:
    int32[n_shards] first owned vertex of each shard.
    """

    src: jax.Array
    dst: jax.Array
    w: jax.Array
    vstart: jax.Array
    n_nodes: int = dataclasses.field(metadata=dict(static=True))
    n_shards: int = dataclasses.field(metadata=dict(static=True))
    shard_nodes: int = dataclasses.field(metadata=dict(static=True))

    @property
    def edges_per_shard(self) -> int:
        return int(self.src.shape[1])


def partition_edges(g: COOGraph, n_shards: int) -> VertexPartition:
    """Static 1-D partition: shard i owns vertices [i*S, (i+1)*S) and all
    their outgoing edges. Host-side numpy."""
    src = np.asarray(g.src)
    dst = np.asarray(g.dst)
    w = np.asarray(g.w)
    n = g.n_nodes
    shard_nodes = -(-n // n_shards)  # ceil
    owner = np.minimum(src // shard_nodes, n_shards - 1).astype(np.int64)
    order = np.argsort(owner, kind="stable")
    src, dst, w, owner = src[order], dst[order], w[order], owner[order]
    counts = np.bincount(owner, minlength=n_shards)
    cap = int(counts.max()) if counts.size else 1
    # Round up so every shard's edge block tiles cleanly into lanes.
    cap = max(1, -(-cap // 128) * 128)
    ps = np.full((n_shards, cap), n, dtype=np.int32)
    pd = np.zeros((n_shards, cap), dtype=np.int32)
    pw = np.full((n_shards, cap), INF32, dtype=np.int32)
    starts = np.zeros(n_shards + 1, dtype=np.int64)
    np.cumsum(counts, out=starts[1:])
    for i in range(n_shards):
        lo, hi = starts[i], starts[i + 1]
        ps[i, : hi - lo] = src[lo:hi]
        pd[i, : hi - lo] = dst[lo:hi]
        pw[i, : hi - lo] = w[lo:hi]
    vstart = (np.arange(n_shards) * shard_nodes).astype(np.int32)
    return VertexPartition(
        src=jnp.asarray(ps),
        dst=jnp.asarray(pd),
        w=jnp.asarray(pw),
        vstart=jnp.asarray(vstart),
        n_nodes=n,
        n_shards=n_shards,
        shard_nodes=int(shard_nodes),
    )


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ELLPartition:
    """Stacked per-shard light/heavy ELL row blocks.

    ``light_nbr``/``light_w`` int32[n_shards, shard_nodes + 1, light_deg]
    (``heavy_*`` likewise): row ``r`` of shard ``i`` holds the light
    (heavy) outgoing edges of global vertex ``i * shard_nodes + r`` with
    *global* neighbor ids. Invalid slots — padding columns, rows past
    ``n_nodes``, and the sentinel row ``shard_nodes`` — carry neighbor
    ``n_nodes`` and weight ``INF32``, so gathers through them never win
    a scatter-min (the same benign-garbage trick as ``ELLGraph``).
    """

    light_nbr: jax.Array
    light_w: jax.Array
    heavy_nbr: jax.Array
    heavy_w: jax.Array
    n_nodes: int = dataclasses.field(metadata=dict(static=True))
    n_shards: int = dataclasses.field(metadata=dict(static=True))
    shard_nodes: int = dataclasses.field(metadata=dict(static=True))
    light_deg: int = dataclasses.field(metadata=dict(static=True))
    heavy_deg: int = dataclasses.field(metadata=dict(static=True))


def _stack_ell_blocks(csr, n_shards: int, shard_nodes: int):
    """One CSR split → stacked per-shard ELL blocks (numpy host-side)."""
    row_ptr = np.asarray(csr.row_ptr)
    col = np.asarray(csr.col)
    w = np.asarray(csr.w)
    n = csr.n_nodes
    deg = row_ptr[1:] - row_ptr[:-1]
    max_deg = max(int(deg.max()) if deg.size else 0, 1)
    nbr = np.full((n_shards, shard_nodes + 1, max_deg), n, dtype=np.int32)
    ww = np.full((n_shards, shard_nodes + 1, max_deg), INF32, dtype=np.int32)
    if col.size:
        slot = np.arange(col.shape[0], dtype=np.int64) \
            - row_ptr[:-1].repeat(deg)
        v = np.arange(n, dtype=np.int64).repeat(deg)
        nbr[v // shard_nodes, v % shard_nodes, slot] = col
        ww[v // shard_nodes, v % shard_nodes, slot] = w
    return jnp.asarray(nbr), jnp.asarray(ww), max_deg


def partition_ell(g: COOGraph, n_shards: int, delta: int) -> ELLPartition:
    """Static 1-D row partition of the light/heavy ELL blocks: shard i
    owns the ELL rows of vertices [i*S, (i+1)*S). Host-side numpy."""
    csr = coo_to_csr(g)
    light, heavy = light_heavy_split(csr, delta)
    shard_nodes = -(-g.n_nodes // n_shards)  # ceil
    l_nbr, l_w, l_deg = _stack_ell_blocks(light, n_shards, shard_nodes)
    h_nbr, h_w, h_deg = _stack_ell_blocks(heavy, n_shards, shard_nodes)
    return ELLPartition(
        light_nbr=l_nbr, light_w=l_w, heavy_nbr=h_nbr, heavy_w=h_w,
        n_nodes=g.n_nodes, n_shards=n_shards, shard_nodes=int(shard_nodes),
        light_deg=l_deg, heavy_deg=h_deg,
    )
