"""Vertex partitioning for SPMD Δ-stepping (DESIGN.md §4).

The paper distributes bucket entries over OpenMP threads with static
scheduling; we map that to a static 1-D partition of the vertex set over
the ``model`` mesh axis. Each shard owns a contiguous vertex range plus
every outgoing edge of its range (CSR row ownership). Shards are padded
to a common edge count so the result stacks into dense arrays that
``shard_map`` can consume — padding edges use the sentinel source
``n_nodes`` which is never in any frontier.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.graphs.structures import COOGraph, INF32


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class VertexPartition:
    """Stacked per-shard edge arrays.

    ``src``/``dst``/``w``: int32[n_shards, max_edges_per_shard]. Padding
    slots have src == n_nodes (sentinel) and w == INF32. ``vstart``:
    int32[n_shards] first owned vertex of each shard.
    """

    src: jax.Array
    dst: jax.Array
    w: jax.Array
    vstart: jax.Array
    n_nodes: int = dataclasses.field(metadata=dict(static=True))
    n_shards: int = dataclasses.field(metadata=dict(static=True))
    shard_nodes: int = dataclasses.field(metadata=dict(static=True))

    @property
    def edges_per_shard(self) -> int:
        return int(self.src.shape[1])


def partition_edges(g: COOGraph, n_shards: int) -> VertexPartition:
    """Static 1-D partition: shard i owns vertices [i*S, (i+1)*S) and all
    their outgoing edges. Host-side numpy."""
    src = np.asarray(g.src)
    dst = np.asarray(g.dst)
    w = np.asarray(g.w)
    n = g.n_nodes
    shard_nodes = -(-n // n_shards)  # ceil
    owner = np.minimum(src // shard_nodes, n_shards - 1).astype(np.int64)
    order = np.argsort(owner, kind="stable")
    src, dst, w, owner = src[order], dst[order], w[order], owner[order]
    counts = np.bincount(owner, minlength=n_shards)
    cap = int(counts.max()) if counts.size else 1
    # Round up so every shard's edge block tiles cleanly into lanes.
    cap = max(1, -(-cap // 128) * 128)
    ps = np.full((n_shards, cap), n, dtype=np.int32)
    pd = np.zeros((n_shards, cap), dtype=np.int32)
    pw = np.full((n_shards, cap), INF32, dtype=np.int32)
    starts = np.zeros(n_shards + 1, dtype=np.int64)
    np.cumsum(counts, out=starts[1:])
    for i in range(n_shards):
        lo, hi = starts[i], starts[i + 1]
        ps[i, : hi - lo] = src[lo:hi]
        pd[i, : hi - lo] = dst[lo:hi]
        pw[i, : hi - lo] = w[lo:hi]
    vstart = (np.arange(n_shards) * shard_nodes).astype(np.int32)
    return VertexPartition(
        src=jnp.asarray(ps),
        dst=jnp.asarray(pd),
        w=jnp.asarray(pw),
        vstart=jnp.asarray(vstart),
        n_nodes=n,
        n_shards=n_shards,
        shard_nodes=int(shard_nodes),
    )
