"""Uniform k-hop neighbor sampler (GraphSAGE-style) for minibatch GNN
training — required by the ``minibatch_lg`` shape (fanout 15-10).

Pure-JAX, jit-able: per hop, for each frontier node draw ``fanout``
neighbor slots uniformly *with replacement* from its CSR adjacency row
(standard GraphSAGE practice; nodes with degree 0 self-loop). Returns the
block structure the GNN layers consume: per-hop (src, dst) edge lists in
*local* index space plus the gathered node id table.
"""
from __future__ import annotations

from typing import NamedTuple, Sequence, Tuple

import jax
import jax.numpy as jnp


class SampledBlock(NamedTuple):
    """One hop of sampled message flow: edges src_local -> dst_local.

    ``src_local`` indexes into the *next* hop's node table (size
    n_dst * fanout + n_dst prefix), ``dst_local`` into the current one.
    """

    src_nodes: jax.Array   # int32[n_src] global node ids of this hop's inputs
    src_local: jax.Array   # int32[n_edges]
    dst_local: jax.Array   # int32[n_edges]
    n_dst: int


def _sample_one_hop(key, row_ptr, col, seeds, fanout: int):
    """seeds: int32[B] → sampled neighbor ids int32[B, fanout]."""
    deg = row_ptr[seeds + 1] - row_ptr[seeds]
    u = jax.random.uniform(key, (seeds.shape[0], fanout))
    slot = jnp.floor(u * jnp.maximum(deg, 1)[:, None]).astype(jnp.int32)
    idx = row_ptr[seeds][:, None] + slot
    nbrs = col[idx]
    # degree-0 nodes fall back to a self loop
    return jnp.where(deg[:, None] > 0, nbrs, seeds[:, None])


def sample_khop(key, row_ptr, col, seeds, fanouts: Sequence[int]
                ) -> Tuple[jax.Array, list[SampledBlock]]:
    """Layer-wise k-hop sampling from the outermost hop inwards.

    Returns ``(input_nodes, blocks)`` where ``blocks[h]`` flows messages
    from hop h+1's nodes into hop h's nodes and ``blocks[0].n_dst`` equals
    ``len(seeds)``. All shapes are static given (len(seeds), fanouts).
    """
    blocks = []
    cur = seeds.astype(jnp.int32)
    for h, fanout in enumerate(fanouts):
        key, sub = jax.random.split(key)
        nbrs = _sample_one_hop(sub, row_ptr, col, cur, fanout)  # [B, f]
        n_dst = cur.shape[0]
        # next hop's node table = [dst nodes (for self features)] ++ sampled
        nxt = jnp.concatenate([cur, nbrs.reshape(-1)])
        src_local = n_dst + jnp.arange(n_dst * fanout, dtype=jnp.int32)
        dst_local = jnp.repeat(jnp.arange(n_dst, dtype=jnp.int32), fanout)
        blocks.append(SampledBlock(src_nodes=cur, src_local=src_local,
                                   dst_local=dst_local, n_dst=n_dst))
        cur = nxt
    return cur, blocks
