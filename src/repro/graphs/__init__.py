"""Graph substrate: structures, generators, partitioning, sampling.

Everything the Δ-stepping engine and the GNN/recsys models share lives
here: COO/CSR/ELL containers, light/heavy edge splitting, synthetic graph
generators matching the paper's experimental families, a vertex
partitioner for SPMD sharding and a uniform neighbor sampler for
minibatch GNN training.
"""
from repro.graphs.structures import (
    COOGraph,
    CSRGraph,
    ELLGraph,
    coo_to_csr,
    csr_to_ell,
    light_heavy_split,
    union_with_reverse,
)
from repro.graphs.generators import (
    grid_map,
    random_graph,
    rmat,
    square_lattice,
    watts_strogatz,
)
from repro.graphs.partition import (
    ELLPartition,
    VertexPartition,
    partition_edges,
    partition_ell,
)
from repro.graphs.sampler import sample_khop

__all__ = [
    "COOGraph",
    "CSRGraph",
    "ELLGraph",
    "coo_to_csr",
    "csr_to_ell",
    "light_heavy_split",
    "union_with_reverse",
    "watts_strogatz",
    "rmat",
    "grid_map",
    "square_lattice",
    "random_graph",
    "VertexPartition",
    "ELLPartition",
    "partition_edges",
    "partition_ell",
    "sample_khop",
]
