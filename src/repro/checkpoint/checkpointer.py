"""Checkpointing: atomic, step-indexed, optionally async.

Layout: ``<dir>/step_<n>/arrays.npz`` + ``manifest.json`` (tree
structure). Writes go to ``step_<n>.tmp`` and are renamed only after
fsync — a crash mid-write can never corrupt the latest checkpoint, which
is the property the trainer's restart path relies on. ``AsyncWriter``
overlaps serialization with the next training steps (one in-flight
snapshot; the arrays are host-copied before the thread starts, so the
training loop may donate/overwrite device buffers immediately).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save(directory: str, step: int, tree: Any, keep: int = 3):
    os.makedirs(directory, exist_ok=True)
    leaves, treedef = _flatten(tree)
    tmp = os.path.join(directory, f"step_{step}.tmp")
    final = os.path.join(directory, f"step_{step}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    arrays = {f"leaf_{i}": np.asarray(l) for i, l in enumerate(leaves)}
    with open(os.path.join(tmp, "arrays.npz"), "wb") as f:
        np.savez(f, **arrays)
        f.flush()
        os.fsync(f.fileno())
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump({"step": step, "n_leaves": len(leaves),
                   "treedef": str(treedef)}, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _gc(directory, keep)


def _gc(directory: str, keep: int):
    steps = sorted(all_steps(directory))
    for s in steps[:-keep] if keep else []:
        shutil.rmtree(os.path.join(directory, f"step_{s}"),
                      ignore_errors=True)


def all_steps(directory: str):
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            try:
                out.append(int(name[5:]))
            except ValueError:
                pass
    return out


def latest_step(directory: str) -> Optional[int]:
    steps = all_steps(directory)
    return max(steps) if steps else None


def restore(directory: str, like: Any, step: Optional[int] = None):
    """Restore into the structure (and shardings) of ``like``. Returns
    (tree, step) or (None, None) when no checkpoint exists."""
    step = latest_step(directory) if step is None else step
    if step is None:
        return None, None
    path = os.path.join(directory, f"step_{step}")
    data = np.load(os.path.join(path, "arrays.npz"))
    leaves, treedef = _flatten(like)
    assert len(leaves) == len(data.files), \
        f"checkpoint has {len(data.files)} leaves, model needs {len(leaves)}"
    new_leaves = []
    for i, ref in enumerate(leaves):
        arr = data[f"leaf_{i}"]
        if hasattr(ref, "sharding"):
            arr = jax.device_put(arr.astype(ref.dtype), ref.sharding)
        new_leaves.append(arr)
    return treedef.unflatten(new_leaves), step


class AsyncWriter:
    """One-in-flight background checkpoint writer."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def submit(self, step: int, tree: Any):
        self.wait()
        host_tree = jax.tree.map(np.asarray, tree)   # copy off device now

        def work():
            save(self.directory, step, host_tree, self.keep)

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()
