"""Warm-start repair planning (DESIGN.md §11).

After a plan has solved ``SingleSource(s)`` once, a weight perturbation
does not invalidate the whole tentative-distance array — it invalidates
a bounded region, and the bucket structure is exactly the machinery
that re-settles that region cheaply (Dong et al. 2021's stepping
framework; the ALT-style reuse bound of radius stepping). This module
computes, on the host, the warm ``(tent0, explored0)`` state the
generalized bucket loop (``core.delta_stepping._run_one_warm``) is
entered with:

* **decreases** seed their endpoint's tent directly with the improved
  candidate word — the vertex lands in its *new* bucket, satisfies the
  frontier rule ``tent < explored`` and re-relaxes from there; the
  cascade of further improvements is Δ-stepping's own frontier
  propagation, so the repair is bounded by construction.
* **increases** cannot be expressed as a scatter-min (tent words never
  grow), so every vertex whose shortest path might have used a worsened
  edge — the predecessor-tree descendants of each *suspect root* (a
  tree child across an increased edge) — is reset to INF and re-seeded
  from the cone boundary: one min-scatter over all edges entering the
  cone from settled outside vertices, with *updated* weights.

Bitwise contract: the repaired warm solve converges to exactly the
state a cold solve of the updated graph converges to — dist always
(the min-plus fixed point is schedule-free), and packed (cost, pred)
words on the canonical-ties graph class (all weights >= 1), where the
word-order C4 filter makes the packed fixed point schedule-free too
(``core.backends.graph_is_canonical``). ``plan_repair`` refuses (with a
reason, so the caller re-solves cold) the cases outside the contract:
packed mode on zero-weight graphs, increases without a predecessor
tree, or a resident solve that tripped the overflow flag.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

from repro.core import pack as packing
from repro.graphs.structures import COOGraph, INF32

_INF = int(INF32)
_MASK32 = packing.MASK32
_INF_PACKED = packing.INF_PACKED


@dataclasses.dataclass(frozen=True)
class Resident:
    """The state a ``Plan`` keeps resident after a ``SingleSource``
    solve: converged distances and predecessors, the weight snapshot
    they were solved against (updates are diffed against it, so update
    batches compose), and the overflow flag (an overflowed resident
    state is not trustworthy warm-start material)."""

    source: int
    dist: np.ndarray        # int64[n], INF32 sentinel
    pred: np.ndarray        # int32[n], -1 sentinel
    w: np.ndarray           # int32[E] weight snapshot at solve time
    overflow: bool


@dataclasses.dataclass(frozen=True)
class RepairPlan:
    """Warm entry state for the bucket loop plus its telemetry counts.
    ``repaired == 0`` means the update batch was distance-neutral (no
    effective weight change) and the resident answer stands as-is."""

    tent0: Optional[np.ndarray]      # int32[n] dist or int64[n] packed words
    explored0: Optional[np.ndarray]  # int32[n]
    cone: int                        # vertices reset by the increase cone
    repaired: int                    # cone + directly re-seeded vertices


def resident_words(dist, pred, source: int, packed: bool) -> np.ndarray:
    """Reconstruct the converged tent-word array from (dist, pred) —
    bit-for-bit what the solver's final state held: ``pack(dist, pred)``
    for reachable vertices, ``pack(0, source)`` at the source (the cold
    init word, which ``_finish_pred``'s -1 masking hides), INF words for
    unreachable vertices (nothing ever scatters into them)."""
    dist = np.asarray(dist, np.int64)
    if not packed:
        return np.where(dist < _INF, dist, _INF).astype(np.int32)
    pred = np.asarray(pred, np.int64)
    words = np.where(
        dist < _INF,
        (dist << 32) | (pred & _MASK32),
        np.int64(_INF_PACKED),
    ).astype(np.int64)
    words[source] = np.int64(source)          # pack(0, source)
    return words


def _grow_descendants(in_cone: np.ndarray, pred: np.ndarray, n: int) -> None:
    """Mark every pred-tree descendant of the vertices already set in
    ``in_cone`` (in place). Level-order BFS over a sorted child list:
    O(n log n) to build the list once plus O(level size) per level — not
    the O(n · depth) a whole-array propagation would cost on the
    long-diameter lattice/game-map graphs this subsystem targets. Safe
    on a cyclic pred array (the argmin zero-weight hazard): already-
    marked vertices are never re-expanded."""
    kids = np.nonzero(pred >= 0)[0]
    if kids.size == 0:
        return
    order = np.argsort(pred[kids], kind="stable")
    kids_s = kids[order]
    par_s = pred[kids][order]
    begins = np.searchsorted(par_s, np.arange(n))
    ends = np.searchsorted(par_s, np.arange(n) + 1)
    frontier = np.nonzero(in_cone)[0]
    while frontier.size:
        b0, cnt = begins[frontier], ends[frontier] - begins[frontier]
        total = int(cnt.sum())
        if total == 0:
            break
        # vectorized multi-range gather of every frontier vertex's kids
        csum = np.cumsum(cnt)
        idx = np.arange(total) + np.repeat(b0 - (csum - cnt), cnt)
        children = kids_s[idx]
        frontier = children[~in_cone[children]]
        in_cone[frontier] = True


def plan_repair(
    graph: COOGraph, resident: Resident, *, pred_mode: str
) -> Tuple[Optional[RepairPlan], Optional[str]]:
    """Diff the graph's current weights against the resident snapshot
    and compute the warm entry state. Returns ``(plan, reason)``:
    ``reason`` is a human-readable explanation when the update lies
    outside the warm contract and the caller must re-solve cold."""
    packed = pred_mode == "packed"
    n = graph.n_nodes
    src = np.asarray(graph.src, np.int64)
    dst = np.asarray(graph.dst, np.int64)
    w_new = np.asarray(graph.w, np.int64)
    w_old = np.asarray(resident.w, np.int64)
    dist = np.asarray(resident.dist, np.int64)
    pred = np.asarray(resident.pred, np.int64)
    source = int(resident.source)

    if resident.overflow:
        return None, "resident solve tripped the frontier-cap overflow flag"
    if packed and (int(w_old.min(initial=1)) < 1 or int(w_new.min(initial=1)) < 1):
        return None, (
            "packed (cost, pred) repair needs the canonical-ties graph "
            "class (all weights >= 1, DESIGN.md §11)"
        )

    changed = np.nonzero(w_new != w_old)[0]
    if changed.size == 0:
        return RepairPlan(None, None, 0, 0), None
    increased = changed[w_new[changed] > w_old[changed]]
    decreased = changed[w_new[changed] < w_old[changed]]
    if increased.size and pred_mode == "none":
        return None, (
            "weight increases need the predecessor tree to bound the "
            "repair cone; pred_mode='none' tracks none"
        )

    # increase cone: pred-tree descendants of every suspect root (a tree
    # child across an increased edge). Over-approximate — a suspect whose
    # duplicate-edge tightness survives just costs re-settling work.
    in_cone = np.zeros(n, bool)
    if increased.size:
        a, b = src[increased], dst[increased]
        hit = (b != source) & (pred[b] == a)
        in_cone[b[hit]] = True
        if in_cone.any():
            _grow_descendants(in_cone, pred, n)
    cone = int(in_cone.sum())

    base = resident_words(dist, pred, source, packed)
    tent0 = base.copy()
    explored0 = np.where(dist < _INF, dist, _INF).astype(np.int32)
    if cone:
        tent0[in_cone] = np.int64(_INF_PACKED) if packed else np.int32(_INF)
        explored0[in_cone] = np.int32(_INF)

    # seeds: (a) every edge entering the cone from a settled outside
    # vertex (updated weights — the cone's whole re-entry surface, heavy
    # edges included, since settled vertices never re-enter the
    # frontier); (b) every decreased edge whose source is outside the
    # cone (its old distance is still a valid upper bound there).
    live = dist[src] < _INF
    mask = live & ~in_cone[src] & in_cone[dst]
    if decreased.size:
        mdec = np.zeros(src.shape[0], bool)
        mdec[decreased] = True
        mask |= mdec & live & ~in_cone[src]
    e = np.nonzero(mask)[0]
    if e.size:
        cand = dist[src[e]] + w_new[e]
        keep = cand < _INF
        e, cand = e[keep], cand[keep]
    if e.size:
        if packed:
            words = (cand << 32) | (src[e] & _MASK32)
            np.minimum.at(tent0, dst[e], words)
        else:
            np.minimum.at(tent0, dst[e], cand.astype(np.int32))
    seeded = int(np.count_nonzero((tent0 != base) & ~in_cone))
    if cone == 0 and seeded == 0:
        # weight churn with no effect on any settled upper bound
        return RepairPlan(None, None, 0, 0), None
    return RepairPlan(tent0, explored0, cone, cone + seeded), None


__all__ = ["Resident", "RepairPlan", "plan_repair", "resident_words"]
