"""Weight-update application for dynamic graphs.

Topology is immutable (the game-map/traffic workload changes edge
*costs*, not the road network), so an update batch is a pure function
``COOGraph -> COOGraph`` swapping entries of the weight array. Keeping
``src``/``dst`` untouched means every backend rebuild reuses identical
index structure — the edge backend's arrays keep their shapes, so the
module-level jitted drivers never recompile across updates.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.graphs.structures import COOGraph, INF32


def apply_weight_update(graph: COOGraph, edge_ids, new_weights) -> COOGraph:
    """New ``COOGraph`` with ``w[edge_ids] = new_weights`` (host-side).

    Validated hard: out-of-range ids or negative/INF weights would
    otherwise corrupt the engine's non-negative int32 invariant inside a
    jitted scatter where nothing can raise. Duplicate ids within one
    batch resolve last-wins (numpy fancy-assignment order), matching the
    'stream of cost observations' reading of an update feed.
    """
    ids = np.asarray(edge_ids, dtype=np.int64).ravel()
    w_new = np.asarray(new_weights, dtype=np.int64).ravel()
    if ids.shape != w_new.shape:
        raise ValueError(
            f"edge_ids and new_weights disagree: {ids.shape} vs {w_new.shape}"
        )
    m = graph.n_edges
    if ids.size:
        if int(ids.min()) < 0 or int(ids.max()) >= m:
            raise ValueError(f"edge_ids out of range for a {m}-edge graph")
        if int(w_new.min()) < 0 or int(w_new.max()) >= int(INF32):
            raise ValueError("new_weights must be non-negative int32 below INF32")
    w = np.asarray(graph.w).astype(np.int32).copy()
    w[ids] = w_new.astype(np.int32)
    return COOGraph(graph.src, graph.dst, jnp.asarray(w), graph.n_nodes)


__all__ = ["apply_weight_update"]
