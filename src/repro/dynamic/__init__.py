"""Dynamic-graph update subsystem: warm-start re-solves after edge-cost
changes (DESIGN.md §11).

The paper's workloads — game maps, small-world traffic graphs — change
edge costs constantly in production, yet a classic SSSP engine pays a
full cold solve per change. This package makes an update a first-class
engine operation behind the Query/Plan façade:

    plan = Engine(graph, config).plan()
    plan.solve(SingleSource(0))            # establishes residency
    plan.update(edge_ids, new_weights)     # swap weights, keep topology
    res = plan.resolve(warm=True)          # bounded repair, not a re-solve

``update.apply_weight_update`` is the pure graph transform;
``repair.plan_repair`` diffs the plan's weights against the resident
snapshot and builds the warm ``(tent0, explored0)`` entry state for the
generalized bucket loop — decreases enter their new bucket directly,
increases reset and re-seed the predecessor-tree cone. Warm results are
bitwise identical to a cold solve of the updated graph (dist and pred,
packed words included on the canonical-ties weight class); updates
outside the warm contract fall back to a cold re-solve with the reason
recorded.
"""

from repro.dynamic.repair import (
    RepairPlan,
    Resident,
    plan_repair,
    resident_words,
)
from repro.dynamic.update import apply_weight_update

__all__ = [
    "RepairPlan",
    "Resident",
    "apply_weight_update",
    "plan_repair",
    "resident_words",
]
