"""On-disk persistence for landmark tables (DESIGN.md §14).

One ``.npz`` file per (fingerprint, whash, k, strategy, seed) key,
living in a directory next to the tuner cache and following the same
durability contract as ``tune.cache.TuningCache``: writes go to a temp
file in the destination directory and land via atomic ``os.replace``
(a crashed precompute never leaves a torn table), and a corrupt or
mismatched file reads as a miss rather than an error. ``path=None``
keeps everything in memory — the default for short-lived plans and for
tests.

The key includes ``whash`` (exact edge-array content hash) on top of
the structural fingerprint: tuning records only steer performance, but
a landmark table reused across same-fingerprint graphs with different
weights would produce inadmissible potentials and wrong answers.
"""

from __future__ import annotations

import hashlib
import os
import tempfile
from typing import Optional

import numpy as np

from repro.landmarks.tables import LandmarkTables


def _key(fingerprint: str, whash: str, k: int, strategy: str, seed: int) -> str:
    raw = f"{fingerprint}|{whash}|k={k}|{strategy}|seed={seed}"
    return hashlib.sha1(raw.encode()).hexdigest()


class LandmarkStore:
    """Fingerprint-keyed table store; ``path=None`` is in-memory only."""

    def __init__(self, path: Optional[str] = None):
        self.path = path
        self._mem: dict[str, LandmarkTables] = {}
        if path is not None:
            os.makedirs(path, exist_ok=True)

    def _file(self, key: str) -> str:
        return os.path.join(self.path, f"landmarks_{key}.npz")

    def get(
        self,
        fingerprint: str,
        whash: str,
        k: int,
        strategy: str,
        seed: int,
    ) -> Optional[LandmarkTables]:
        key = _key(fingerprint, whash, k, strategy, seed)
        hit = self._mem.get(key)
        if hit is not None or self.path is None:
            return hit
        fname = self._file(key)
        if not os.path.exists(fname):
            return None
        try:
            with np.load(fname, allow_pickle=False) as z:
                tables = LandmarkTables(
                    fingerprint=str(z["fingerprint"]),
                    whash=str(z["whash"]),
                    strategy=str(z["strategy"]),
                    seed=int(z["seed"]),
                    landmarks=np.asarray(z["landmarks"], np.int32),
                    d_out=np.asarray(z["d_out"], np.int32),
                    d_in=np.asarray(z["d_in"], np.int32),
                )
        except Exception:
            return None          # corrupt file == miss, same as TuningCache
        if tables.fingerprint != fingerprint or tables.whash != whash:
            return None          # hash-collision paranoia: verify payload
        self._mem[key] = tables
        return tables

    def put(self, tables: LandmarkTables) -> None:
        key = _key(tables.fingerprint, tables.whash, tables.k,
                   tables.strategy, tables.seed)
        self._mem[key] = tables
        if self.path is None:
            return
        fd, tmp = tempfile.mkstemp(dir=self.path, suffix=".npz.tmp")
        try:
            with os.fdopen(fd, "wb") as f:
                np.savez(
                    f,
                    fingerprint=np.str_(tables.fingerprint),
                    whash=np.str_(tables.whash),
                    strategy=np.str_(tables.strategy),
                    seed=np.int64(tables.seed),
                    landmarks=tables.landmarks,
                    d_out=tables.d_out,
                    d_in=tables.d_in,
                )
            os.replace(tmp, self._file(key))
        except Exception:
            if os.path.exists(tmp):
                os.unlink(tmp)
            raise


__all__ = ["LandmarkStore"]
