"""ALT potentials and the goal-directed point-to-point solvers
(DESIGN.md §14).

The three landmark-backed ``p2p_mode`` values all reduce to the stock
Δ-stepping bucket loop over a *reweighted* (and, for the bidirectional
pair, *doubled*) graph — no new driver semantics:

* ``alt``               — forward Δ-stepping over reduced edge costs
  ``w'(u, v) = w + π(v) − π(u)`` with the landmark potential π, behind
  the existing early-exit stop (``_run_one_p2p``). π is consistent, so
  ``w' >= 0`` and every bucket invariant (and the Dijkstra-oracle
  differential harness) carries over verbatim; the true distance is the
  reduced one plus ``π(s)``.
* ``bidirectional``     — forward and backward searches as ONE solve
  over the disjoint union of the graph with its reversed copy
  (``graphs.union_with_reverse``), stopped by the meeting rule
  (``_run_one_bidir``).
* ``alt_bidirectional`` — both: the same π reduces *both* union halves
  (the backward half with the opposite sign, so a backward edge carries
  exactly the reduced cost of its forward twin), and the meeting sum
  telescopes to ``dist(s, t) − π(s)``.

Potentials (Goldberg & Harrelson): for target t, per landmark L the
triangle inequality gives two lower bounds on dist(v, t) —
``d(L, t) − d(L, v)`` and ``d(v, L) − d(t, L)``; π(v) is their max over
landmarks, floored at 0 and clamped to ``POTENTIAL_CLIP`` so reduced
int32 arithmetic can never overflow. Unreachability is exact, not
saturated: a ``d(L, t) = INF`` landmark contributes nothing, a
``d(t, L) = INF`` landmark contributes nothing, and ``d(v, L) = INF``
with ``d(t, L)`` finite means v cannot reach t at all (any v→t path
would extend to v→L), so assigning the clamp ceiling directly is both
admissible (vacuous) and consistent (no edge leaves such a v into the
reachable set). Min-clamping a consistent potential with a constant
preserves consistency and admissibility, and π(t) = 0 always.

The query solves run over an all-light ELL adjacency: the full (union)
adjacency padded to one ELL block with an empty heavy block. The ELL
sweep's light/heavy distinction is purely structural (which block is
gathered — there is no per-edge weight test), so relaxing a heavy edge
during the light phase is merely an earlier-than-usual relaxation:
tent values stay upper bounds, relaxation is idempotent, and the
settled-bucket invariant is untouched. This keeps the block structure
weight-independent, so per-query ALT reweighting is a single jnp
gather/add instead of a host-side re-split.

Path recovery never walks the reduced space: converged reduced tents
are converted back to original-space distance bounds (exact on every
shortest s–t path vertex) and ``pred_argmin`` runs over the ORIGINAL
forward / reversed edge arrays. Under an upper-bound distance array, a
tight edge's source value is forced exact by the triangle inequality,
so the recovered walks terminate at the roots — given w >= 1, which is
why every landmark mode is gated to canonical graphs.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.backends import EllBackend, graph_is_canonical
from repro.core.delta_stepping import (
    P2P_MODES,
    _run_one_bidir,
    _run_one_p2p,
    pred_argmin,
)
from repro.graphs.structures import (
    COOGraph,
    ELLGraph,
    INF32,
    coo_to_csr,
    csr_to_ell,
    union_with_reverse,
)
from repro.landmarks.store import LandmarkStore
from repro.landmarks.tables import (
    LandmarkTables,
    SELECT_STRATEGIES,
    build_tables,
    graph_whash,
)

# π is clamped to [0, 2^28]; with w + 2·clip < 2^31 the reduced weights
# and the engine's no-overflow distance assumption both stay in int32
POTENTIAL_CLIP = np.int64(2**28)

LANDMARK_MODES = tuple(m for m in P2P_MODES if m != "early_exit")
_INF = int(INF32)


def potentials(tables: LandmarkTables, target: int) -> np.ndarray:
    """ALT potential π for ``target``: int64[n], 0 <= π <= clip,
    π(target) == 0, consistent and admissible w.r.t. the weights the
    tables were built at (and any elementwise-increased weights —
    DESIGN.md §14 bound repair)."""
    t = int(target)
    clip = np.int64(POTENTIAL_CLIP)
    d_out = tables.d_out.astype(np.int64)
    d_in = tables.d_in.astype(np.int64)
    pi = np.zeros(tables.n, np.int64)
    for j in range(tables.k):
        d_lt = d_out[j, t]
        if d_lt < _INF:
            # d_out[j] == INF makes the term very negative: excluded
            pi = np.maximum(pi, np.minimum(d_lt - d_out[j], clip))
        d_tl = d_in[j, t]
        if d_tl < _INF:
            term = np.where(d_in[j] < _INF,
                            np.minimum(d_in[j] - d_tl, clip), clip)
            pi = np.maximum(pi, term)
    return pi


@partial(jax.jit, static_argnums=3)
def reduce_forward(w_ell, nbr, pi, n: int):
    """Reduced weights of a forward ELL adjacency ((n+1, D) with
    sentinel row n): ``w' = w + π[nbr] − π[row]``; invalid slots keep
    INF32. ``pi`` int32[n]."""
    pi_ext = jnp.concatenate([pi, jnp.zeros((1,), jnp.int32)])
    rows = jnp.arange(n + 1, dtype=jnp.int32)[:, None]
    diff = jnp.take(pi_ext, nbr, mode="clip") - pi_ext[rows]
    return jnp.where(w_ell < INF32, w_ell + diff, INF32)


@partial(jax.jit, static_argnums=3)
def reduce_union(w_ell, nbr, pi, half: int):
    """Reduced weights of the union ELL adjacency ((2·half+1, D),
    sentinel row 2·half). Forward rows get ``w + π[v] − π[u]``; backward
    rows the opposite sign, so the backward copy of edge (u, v) carries
    exactly the forward reduced cost and the backward tents telescope to
    ``dist(v, t) − π(v)``."""
    pi_ext = jnp.concatenate([pi, pi, jnp.zeros((1,), jnp.int32)])
    rows = jnp.arange(2 * half + 1, dtype=jnp.int32)
    sign = jnp.where(rows < half, 1, -1).astype(jnp.int32)[:, None]
    diff = jnp.take(pi_ext, nbr, mode="clip") - pi_ext[rows[:, None]]
    return jnp.where(w_ell < INF32, w_ell + sign * diff, INF32)


def _all_light_backend(nbr, w_ell, n: int, delta: int) -> EllBackend:
    """EllBackend whose light block is the FULL adjacency and whose
    heavy block is empty (width-1 all-sentinel). ``canonical=False`` is
    inert here — the flag is only consulted in packed mode and the
    landmark solves always run packed=False. ``cap=n`` (full width)
    rules out frontier overflow."""
    light = ELLGraph(nbr, w_ell, n, int(nbr.shape[1]))
    heavy = ELLGraph(
        jnp.full((n + 1, 1), n, jnp.int32),
        jnp.full((n + 1, 1), INF32, jnp.int32),
        n, 1)
    return EllBackend(light, heavy, delta, n, n, False)


def require_canonical(graph: COOGraph) -> None:
    if not graph_is_canonical(graph):
        raise ValueError(
            "landmark p2p modes require canonical weights (w >= 1): the "
            "path-recovery walk needs strictly decreasing distances")


@dataclasses.dataclass(frozen=True)
class LandmarkSpec:
    """Landmark residency policy of one Plan (``Plan.prepare_landmarks``).

    ``store`` is a directory for the persistent :class:`LandmarkStore`
    (``None`` = in-memory). ``on_update`` picks the stale-table policy
    for weight batches that DECREASE some weight below its table-build
    value (increase-only batches always keep the tables — the old π
    stays admissible and consistent, DESIGN.md §14):

    * ``recompute`` — drop the tables; the next ALT query lazily
      rebuilds against the new weights.
    * ``refuse``    — reject the batch with ``LandmarkRefused`` BEFORE
      any weight is applied, typed like every other ``UpdateRefused``
      so the serving tier sheds it on the standard path.
    """

    k: int = 4
    strategy: str = "farthest"
    seed: int = 0
    store: Optional[str] = None
    on_update: str = "recompute"

    def __post_init__(self):
        if self.strategy not in SELECT_STRATEGIES:
            raise ValueError(f"unknown landmark strategy {self.strategy!r}")
        if self.on_update not in ("recompute", "refuse"):
            raise ValueError(f"unknown on_update policy {self.on_update!r}")
        if self.k < 1:
            raise ValueError("need at least one landmark")


class P2PSolve(NamedTuple):
    """Raw landmark-mode solve result; the Plan façade turns it into a
    PointToPointResult (path stitching lives in ``api.paths``)."""

    distance: int
    pred_f: Optional[jax.Array]     # forward tree (original graph)
    pred_b: Optional[jax.Array]     # backward tree (reversed graph)
    meet: Optional[int]             # meeting vertex (bidirectional only)
    outer: int
    inner: int
    overflow: bool


class LandmarkState:
    """Landmark residency of one Plan: the distance tables plus the
    weight-independent all-light ELL structure the goal-directed modes
    solve over. Tables build lazily (store hit or precompute) on the
    first ALT query; ``note_update`` re-validates them against the
    bound-repair condition after every weight batch."""

    def __init__(self, spec: LandmarkSpec, delta: int,
                 store: Optional[LandmarkStore] = None):
        self.spec = spec
        self.delta = int(delta)
        self.store = store if store is not None else LandmarkStore(spec.store)
        self.tables: Optional[LandmarkTables] = None
        self._w_base: Optional[np.ndarray] = None   # weights at table build
        self._version = 0                           # bumped per weight batch
        self._ell_version: Optional[int] = None
        self._union_ell: Optional[ELLGraph] = None
        self._fwd_ell: Optional[ELLGraph] = None
        self._coo_f = None                          # device COO, forward
        self._coo_b = None                          # device COO, reversed

    # -- tables -----------------------------------------------------------

    def ensure_tables(self, graph: COOGraph) -> LandmarkTables:
        if self.tables is None:
            from repro.tune.estimator import fingerprint, graph_stats

            fp = fingerprint(graph_stats(graph))
            wh = graph_whash(graph)
            s = self.spec
            hit = self.store.get(fp, wh, min(s.k, graph.n_nodes),
                                 s.strategy, s.seed)
            if hit is None:
                hit = build_tables(graph, k=s.k, strategy=s.strategy,
                                   seed=s.seed, delta=self.delta,
                                   fingerprint=fp)
                self.store.put(hit)
            self.tables = hit
            self._w_base = np.asarray(graph.w, np.int64).copy()
        return self.tables

    def would_invalidate(self, edge_ids, new_weights) -> bool:
        """True iff applying the batch drops some weight below its
        table-build value (last-wins duplicate semantics, matching
        ``dynamic.apply_weight_update``)."""
        if self.tables is None:
            return False
        ids = np.asarray(edge_ids, np.int64).ravel()[::-1]
        nw = np.asarray(new_weights, np.int64).ravel()[::-1]
        uniq, first = np.unique(ids, return_index=True)
        return bool((nw[first] < self._w_base[uniq]).any())

    def note_update(self, graph: COOGraph) -> str:
        """Record an applied weight batch: always refreshes the ELL
        weight caches; keeps the tables iff every current weight still
        dominates its table-build value (π stays admissible AND
        consistent — increases only ever grow true distances and slacken
        the per-edge consistency inequality)."""
        self._version += 1
        if self.tables is None:
            return "none"
        w_now = np.asarray(graph.w, np.int64)
        if w_now.shape == self._w_base.shape and (w_now >= self._w_base).all():
            return "kept"
        self.tables = None
        self._w_base = None
        return "stale"

    # -- query path -------------------------------------------------------

    def _ells(self, graph: COOGraph):
        """Weight-version cache of everything query-independent, committed
        to the device ONCE: the union / forward all-light ELL adjacencies
        and the COO triples path recovery scatters over. Keeping these
        resident turns the per-query host work into O(n) (the π vector)
        instead of O(n·width) array rebuild + transfer."""
        if self._ell_version != self._version or self._union_ell is None:
            union = csr_to_ell(coo_to_csr(union_with_reverse(graph)))
            fwd = csr_to_ell(coo_to_csr(graph))
            rev = graph.reversed()
            self._union_ell = dataclasses.replace(
                union, nbr=jnp.asarray(union.nbr), w=jnp.asarray(union.w))
            self._fwd_ell = dataclasses.replace(
                fwd, nbr=jnp.asarray(fwd.nbr), w=jnp.asarray(fwd.w))
            self._coo_f = (jnp.asarray(graph.src), jnp.asarray(graph.dst),
                           jnp.asarray(graph.w))
            self._coo_b = (jnp.asarray(rev.src), jnp.asarray(rev.dst),
                           jnp.asarray(rev.w))
            self._ell_version = self._version
        return self._union_ell, self._fwd_ell

    def solve_p2p(self, graph: COOGraph, source, target, mode: str, *,
                  want_pred: bool = True) -> P2PSolve:
        if mode not in LANDMARK_MODES:
            raise ValueError(f"unknown landmark p2p mode {mode!r}")
        require_canonical(graph)
        n = graph.n_nodes
        s, t = int(source), int(target)
        use_alt = mode in ("alt", "alt_bidirectional")
        pi = None
        if use_alt:
            pi = potentials(self.ensure_tables(graph), t)
        union_ell, fwd_ell = self._ells(graph)
        if mode == "alt":
            return self._solve_alt_forward(graph, fwd_ell, pi, s, t,
                                           want_pred)
        return self._solve_bidir(graph, union_ell, pi, s, t, use_alt,
                                 want_pred)

    def _solve_alt_forward(self, graph, ell, pi, s, t, want_pred):
        n = graph.n_nodes
        pi32 = jnp.asarray(pi.astype(np.int32))
        w_red = reduce_forward(ell.w, ell.nbr, pi32, n)
        backend = _all_light_backend(ell.nbr, w_red, n, self.delta)
        tent, outer, inner, over = _run_one_p2p(
            backend, jnp.int32(s), jnp.int32(t), n=n, packed=False,
            all_light=True)
        th = np.asarray(tent, np.int64)
        if th[t] >= _INF:
            return P2PSolve(_INF, None, None, None,
                            int(outer), int(inner), bool(over))
        distance = int(th[t] + pi[s])               # π(t) == 0
        pred_f = None
        if want_pred:
            # back to original space: d̂(v) = tent'(v) + π(s) − π(v),
            # an upper bound everywhere and exact on the s–t path
            dhat = np.where(th < _INF, th + (pi[s] - pi), np.int64(_INF))
            dhat = np.minimum(dhat, _INF).astype(np.int32)
            src, dst, w = self._coo_f
            pred_f = pred_argmin(jnp.asarray(dhat), src, dst, w,
                                 jnp.int32(s), n=n)
        return P2PSolve(distance, pred_f, None, None,
                        int(outer), int(inner), bool(over))

    def _solve_bidir(self, graph, ell, pi, s, t, use_alt, want_pred):
        n = graph.n_nodes
        if use_alt:
            pi32 = jnp.asarray(pi.astype(np.int32))
            w_use = reduce_union(ell.w, ell.nbr, pi32, n)
        else:
            w_use = ell.w
        backend = _all_light_backend(ell.nbr, w_use, 2 * n, self.delta)
        tent0 = (jnp.full((2 * n,), INF32, jnp.int32)
                 .at[s].set(0).at[t + n].set(0))
        explored0 = jnp.full((2 * n,), INF32, jnp.int32)
        tent, outer, inner, over = _run_one_bidir(
            backend, tent0, explored0, n=2 * n, packed=False,
            all_light=True)
        th = np.asarray(tent, np.int64)
        f, b = th[:n], th[n:]
        fin = (f < _INF) & (b < _INF)
        sums = np.where(fin, f + b, np.int64(_INF))
        mu = int(sums.min())
        counters = (int(outer), int(inner), bool(over))
        if mu >= _INF:
            return P2PSolve(_INF, None, None, None, *counters)
        meet = int(sums.argmin())
        distance = mu + (int(pi[s]) if use_alt else 0)
        pred_f = pred_b = None
        if want_pred:
            if use_alt:
                df = np.where(f < _INF, f + (pi[s] - pi), np.int64(_INF))
                db = np.where(b < _INF, b + pi, np.int64(_INF))
            else:
                df, db = f, b
            df = np.minimum(df, _INF).astype(np.int32)
            db = np.minimum(db, _INF).astype(np.int32)
            sf, tf, wf = self._coo_f
            sb, tb, wb = self._coo_b
            pred_f = pred_argmin(jnp.asarray(df), sf, tf, wf,
                                 jnp.int32(s), n=n)
            pred_b = pred_argmin(jnp.asarray(db), sb, tb, wb,
                                 jnp.int32(t), n=n)
        return P2PSolve(distance, pred_f, pred_b, meet, *counters)


__all__ = [
    "LANDMARK_MODES",
    "LandmarkSpec",
    "LandmarkState",
    "P2PSolve",
    "POTENTIAL_CLIP",
    "potentials",
    "reduce_forward",
    "reduce_union",
    "require_canonical",
]
