"""Goal-directed (point-to-point) query subsystem: landmark tables,
ALT potentials, bidirectional Δ-stepping (DESIGN.md §14).

Consumed through the Plan façade (``api.PointToPoint(mode=...)``,
``Plan.prepare_landmarks``); everything here is also usable directly
for tests and offline precompute.
"""
from repro.landmarks.alt import (
    LANDMARK_MODES,
    LandmarkSpec,
    LandmarkState,
    P2PSolve,
    POTENTIAL_CLIP,
    potentials,
    reduce_forward,
    reduce_union,
    require_canonical,
)
from repro.landmarks.store import LandmarkStore
from repro.landmarks.tables import (
    LandmarkTables,
    SELECT_STRATEGIES,
    build_tables,
    graph_whash,
    select_landmarks,
)

__all__ = [
    "LANDMARK_MODES",
    "LandmarkSpec",
    "LandmarkState",
    "LandmarkStore",
    "LandmarkTables",
    "P2PSolve",
    "POTENTIAL_CLIP",
    "SELECT_STRATEGIES",
    "build_tables",
    "graph_whash",
    "potentials",
    "reduce_forward",
    "reduce_union",
    "require_canonical",
    "select_landmarks",
]
