"""Landmark selection and distance-table precompute (DESIGN.md §14).

A landmark table holds, for ``k`` chosen vertices ``L``, both distance
rows the ALT potentials need on a directed graph: ``d_out[L, v] =
dist(L -> v)`` and ``d_in[L, v] = dist(v -> L)``. Both rows of one
landmark come out of ONE batched solve over the disjoint union of the
graph with its reversed copy (``graphs.union_with_reverse``): seeding
lane ``L`` in the forward half yields the out-row, seeding lane
``L + n`` in the reversed half yields the in-row — the same
bitwise-stable ``solve_many`` driver (``_run_many_vmapped``) every
multi-source query runs, so table entries are exactly the engine's own
distances, not a second implementation's.

Selection strategies (Goldberg & Harrelson's classic pair):

* ``random``   — ``k`` distinct vertices from a seeded generator; one
  batched solve computes all ``2k`` rows.
* ``farthest`` — greedy k-center on the table rows themselves: each new
  landmark maximizes the minimum (in/out) distance to the already
  chosen set, so landmarks spread to the graph periphery where their
  triangle-inequality bounds are tightest. Each round reuses the rows
  the round's solve just produced — selection costs nothing beyond the
  table build.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core.delta_stepping import DeltaConfig, _run_many_vmapped
from repro.graphs.structures import COOGraph, INF32, union_with_reverse

SELECT_STRATEGIES = ("farthest", "random")


@dataclasses.dataclass(frozen=True)
class LandmarkTables:
    """Precomputed landmark distance rows for one (graph, selection)
    pair. ``fingerprint`` is the tuner's structural fingerprint
    (``tune.estimator.fingerprint``); ``whash`` is a content hash of the
    exact (src, dst, w) arrays — unlike a tuning record, a landmark
    table moves *answers* if reused across same-fingerprint graphs with
    different weights, so the store keys on both. ``d_out``/``d_in`` are
    int32[k, n] with the INF32 unreachable sentinel."""

    fingerprint: str
    whash: str
    strategy: str
    seed: int
    landmarks: np.ndarray   # int32[k]
    d_out: np.ndarray       # int32[k, n]: dist(L -> v)
    d_in: np.ndarray        # int32[k, n]: dist(v -> L)

    @property
    def k(self) -> int:
        return int(self.landmarks.shape[0])

    @property
    def n(self) -> int:
        return int(self.d_out.shape[1])


def graph_whash(graph: COOGraph) -> str:
    """Content hash of the exact edge arrays (the store-key integrity
    term next to the structural fingerprint)."""
    import hashlib

    h = hashlib.sha1()
    for arr in (graph.src, graph.dst, graph.w):
        h.update(np.ascontiguousarray(np.asarray(arr, np.int32)).tobytes())
    return h.hexdigest()[:16]


def _solve_rows(union: COOGraph, sources, delta: int) -> np.ndarray:
    """Distance rows of a batch of union-graph sources via the engine's
    own batched driver (edge backend, pred-free)."""
    from repro.core.backends import EdgeBackend

    cfg = DeltaConfig(delta=delta, strategy="edge", pred_mode="none")
    backend = EdgeBackend.build(union, cfg)
    srcs = jnp.asarray(np.asarray(sources, np.int32))
    tent, _, _, _ = _run_many_vmapped(
        backend, srcs, n=union.n_nodes, packed=False)
    return np.asarray(tent, np.int32)


def select_landmarks(
    graph: COOGraph,
    k: int,
    strategy: str = "farthest",
    seed: int = 0,
    delta: int = 10,
):
    """Pick ``k`` landmarks and return ``(landmarks, d_out, d_in)``.
    Exposed for tests/inspection; :func:`build_tables` is the packaged
    entry point."""
    if strategy not in SELECT_STRATEGIES:
        raise ValueError(f"unknown landmark strategy {strategy!r}")
    n = graph.n_nodes
    k = max(1, min(int(k), n))
    rng = np.random.default_rng(seed)
    union = union_with_reverse(graph)
    if strategy == "random":
        lms = np.sort(rng.choice(n, size=k, replace=False)).astype(np.int32)
        rows = _solve_rows(union, np.concatenate([lms, lms + n]), delta)
        d_out = rows[:k, :n]
        d_in = rows[k:, n:]
        return lms, d_out, d_in
    # farthest: greedy k-center on min(in, out) distance to the chosen set
    lms = [int(rng.integers(n))]
    outs, ins = [], []
    cover = np.full(n, np.iinfo(np.int64).max, np.int64)
    while True:
        L = lms[-1]
        rows = _solve_rows(union, [L, L + n], delta)
        d_out_row = rows[0, :n]
        d_in_row = rows[1, n:]
        outs.append(d_out_row)
        ins.append(d_in_row)
        if len(lms) == k:
            break
        both = np.minimum(d_out_row.astype(np.int64),
                          d_in_row.astype(np.int64))
        cover = np.minimum(cover, both)
        cand = cover.copy()
        cand[np.asarray(lms)] = -1          # never re-pick a landmark
        finite = cand < int(INF32)
        if finite.any():
            cand[~finite] = -1
            nxt = int(cand.argmax())
        else:                               # chosen set sees nothing new:
            free = np.setdiff1d(np.arange(n), np.asarray(lms))
            nxt = int(rng.choice(free))     # fall back to a random pick
        lms.append(nxt)
    order = np.argsort(np.asarray(lms))
    return (
        np.asarray(lms, np.int32)[order],
        np.stack(outs)[order].astype(np.int32),
        np.stack(ins)[order].astype(np.int32),
    )


def build_tables(
    graph: COOGraph,
    *,
    k: int = 4,
    strategy: str = "farthest",
    seed: int = 0,
    delta: int = 10,
    fingerprint: str = "",
) -> LandmarkTables:
    """Select landmarks and precompute their distance rows. ``delta``
    steers the solves only (any value is exact); ``fingerprint`` is
    attached verbatim — compute it once at the residency layer so the
    O(diameter·|E|) probe is not re-paid per build."""
    lms, d_out, d_in = select_landmarks(
        graph, k, strategy=strategy, seed=seed, delta=delta)
    return LandmarkTables(
        fingerprint=fingerprint,
        whash=graph_whash(graph),
        strategy=strategy,
        seed=int(seed),
        landmarks=lms,
        d_out=d_out,
        d_in=d_in,
    )


__all__ = [
    "LandmarkTables",
    "SELECT_STRATEGIES",
    "build_tables",
    "graph_whash",
    "select_landmarks",
]
