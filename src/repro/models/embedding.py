"""EmbeddingBag built from gather + segment-reduce.

JAX has no native ``nn.EmbeddingBag`` and no CSR sparse — the lookup is
``jnp.take`` over the table followed by ``jax.ops.segment_sum`` over bag
ids (the same gather/segment-reduce primitive family as the GNN
aggregations and the Δ-stepping scatter-min; DESIGN.md §5).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def embedding_bag(table, indices, segment_ids, num_bags: int,
                  mode: str = "sum", weights=None):
    """table: (V, D); indices: int32[N] rows to gather; segment_ids:
    int32[N] bag of each index (sorted not required); → (num_bags, D)."""
    rows = jnp.take(table, indices, axis=0, mode="fill", fill_value=0)
    if weights is not None:
        rows = rows * weights[:, None]
    if mode == "sum":
        return jax.ops.segment_sum(rows, segment_ids, num_segments=num_bags)
    if mode == "mean":
        s = jax.ops.segment_sum(rows, segment_ids, num_segments=num_bags)
        c = jax.ops.segment_sum(jnp.ones_like(segment_ids, jnp.float32),
                                segment_ids, num_segments=num_bags)
        return s / jnp.maximum(c, 1.0)[:, None]
    if mode == "max":
        return jax.ops.segment_max(rows, segment_ids, num_segments=num_bags)
    raise ValueError(mode)


def multi_hot_lookup(table, hot_indices):
    """Fixed multi-hot layout: hot_indices int32[B, H] (H hots per sample,
    -1 = padding) → summed embeddings (B, D). The DLRM fast path (no
    ragged segment ids needed when every sample has the same hot count)."""
    mask = (hot_indices >= 0)[..., None]
    rows = jnp.take(table, jnp.maximum(hot_indices, 0), axis=0)
    return jnp.where(mask, rows, 0).sum(axis=1)
