"""Parameter / state / batch PartitionSpec trees, by parameter path.

The LM zoo uses hybrid FSDP + TP: weight matrices shard their input dim
over the batch axes ('dp' — ZeRO-3 style, required for the 1T config)
and their output/head/expert dim over 'tp'. Optimizer states inherit
parameter sharding automatically (zeros_like); Adafactor's factored
vectors are replicated (tiny).

Specs here are *logical* (dp/tp/sp); ``to_mesh_spec`` translates through
a mapping (see models/sharding.py) into mesh-axis PartitionSpecs for a
concrete mesh, dropping axes the mesh does not have.
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.sharding import DEFAULT_MAPPING


def _path_str(kp) -> str:
    out = []
    for k in kp:
        if hasattr(k, "key"):
            out.append(str(k.key))
        elif hasattr(k, "idx"):
            out.append(str(k.idx))
    return "/".join(out)


def _lm_rule(path: str, ndim: int) -> P:
    last = path.split("/")[-1]
    if "layers" in path:
        # leading stacked-layer dim
        if last in ("wq", "wk", "wv"):
            return P(None, "fsdp", "tp")
        if last == "wo":
            return P(None, "tp", "fsdp")
        if "moe" in path:
            if last == "router":
                return P(None, None, None)
            if "shared" in path:
                if last in ("w_gate", "w_up"):
                    return P(None, "fsdp", "tp")
                return P(None, "tp", "fsdp")
            if last in ("w_gate", "w_up"):        # (L, E, d, f)
                return P(None, "tp", None, "fsdp")
            if last == "w_down":                  # (L, E, f, d)
                return P(None, "tp", "fsdp", None)
        if last in ("w_gate", "w_up"):
            return P(None, "fsdp", "tp")
        if last == "w_down":
            return P(None, "tp", "fsdp")
        if last == "eps":
            return P(None)
        return P(None, None)                      # norms (L, d)
    if last == "embed":
        # rows over tp only: sharding d over fsdp would force the loss
        # matmul to re-shard activations from batch- to d-sharded (the
        # same physical axes), replicating the batch — observed 60+ GiB
        # of f32 loss-path temps on the 1T config.
        return P("tp", None)
    if last == "unembed":
        return P(None, "tp")
    return P(None)                                # final_norm etc.


def lm_param_specs(params_shape) -> Any:
    return jax.tree_util.tree_map_with_path(
        lambda kp, x: _lm_rule(_path_str(kp), x.ndim), params_shape)


def lm_layer_slice_rule(path_in_layer: str) -> P:
    """Spec of ONE layer's slice (leading stacked-L dim stripped) — used
    to re-constrain the slice inside the layer scan so weight gathers
    happen per-layer inside the loop, not on the full stack before it."""
    full = _lm_rule("layers/" + path_in_layer, 0)
    return P(*tuple(full)[1:])


def gnn_param_specs(params_shape) -> Any:
    # GNN weights are tiny — replicate everything.
    return jax.tree.map(lambda x: P(), params_shape)


def dlrm_param_specs(params_shape) -> Any:
    def rule(kp, x):
        path = _path_str(kp)
        if "tables" in path:
            # rows over the batch axes (padded to x512 at init), embedding
            # dim over tp — both always divisible.
            return P("fsdp", "tp")
        return P()
    return jax.tree_util.tree_map_with_path(rule, params_shape)


def cache_specs(long_context: bool = False) -> Any:
    """KV cache (L, B, S, Hk, Dh): batch over dp, sequence over sp
    (flash-decoding style partial-softmax combining — KV head counts
    (1/8/16) don't divide a 16-wide tp axis, and head-replicated caches
    would not fit HBM). Long-context (B == 1) maps sp to *all* axes."""
    kv = P(None, None if long_context else "dp", "sp", None, None)
    return {"k": kv, "v": kv, "len": P()}


def state_specs_like(param_specs, state_shape) -> Any:
    """Optimizer-state specs: moment trees mirror the parameter tree and
    inherit its specs (ZeRO for free); factored/scalar state replicates."""
    out = {}
    for k, v in state_shape.items():
        if k in ("m", "v"):                       # adam/sgd moments
            out[k] = param_specs
        elif k == "f":                            # adafactor factors
            out[k] = jax.tree.map(lambda x: P(), v)
        else:                                     # step, grad_norm, ...
            out[k] = P()
    return out


def to_mesh_spec(spec: P, mesh: Mesh, mapping=None) -> P:
    mapping = dict(DEFAULT_MAPPING, **(mapping or {}))
    out = []
    for entry in spec:
        if entry is None:
            out.append(None)
            continue
        logical = entry if isinstance(entry, tuple) else (entry,)
        axes = []
        for name in logical:
            ax = mapping.get(name, name)
            if ax is None:
                continue
            for a in (ax if isinstance(ax, tuple) else (ax,)):
                if a in mesh.axis_names and a not in axes:
                    axes.append(a)
        out.append(tuple(axes) if len(axes) > 1 else (axes[0] if axes
                                                      else None))
    return P(*out)


def tree_to_mesh(spec_tree, mesh: Mesh, mapping=None):
    return jax.tree.map(
        lambda s: to_mesh_spec(s, mesh, mapping), spec_tree,
        is_leaf=lambda s: isinstance(s, P))


def tree_to_shardings(spec_tree, mesh: Mesh, mapping=None):
    mesh_specs = tree_to_mesh(spec_tree, mesh, mapping)
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), mesh_specs,
        is_leaf=lambda s: isinstance(s, P))
