"""Model zoo: transformer LM (dense/MoE), GNNs, DLRM — pure-functional
param-pytree models sharing the gather/segment-reduce substrate."""
from repro.models import dlrm, embedding, gnn, layers, moe, transformer
from repro.models.sharding import constrain, sharding_rules

__all__ = ["dlrm", "embedding", "gnn", "layers", "moe", "transformer",
           "constrain", "sharding_rules"]
