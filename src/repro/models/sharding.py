"""Logical-axis sharding rules (MaxText-style, trimmed).

Models annotate activations/intermediates with *logical* axis names via
``constrain``; a context installed by the launcher maps logical names to
mesh axes. Outside any context ``constrain`` is a no-op, so unit tests
and single-device smoke runs never touch device APIs.

Logical axes used across the zoo:
  dp   — batch-like (data × pod)
  tp   — tensor-parallel (heads / d_ff / vocab / experts)
  sp   — sequence (long-context KV sharding)
  ep_cap — MoE capacity rows (sharded over dp to bound dispatch memory)
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_RULES: contextvars.ContextVar = contextvars.ContextVar(
    "sharding_rules", default=None)


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    mesh: Mesh
    mapping: dict  # logical name -> mesh axis | tuple | None


DEFAULT_MAPPING = {
    "dp": ("pod", "data"),        # batch-like activations
    "fsdp": ("pod", "data"),      # weight sharding (ZeRO-3 / row-sharded)
    "tp": "model",
    "sp": "model",
    "ep_cap": ("pod", "data"),
}


def _translate(rules: ShardingRules, name):
    if name is None:
        return None
    ax = rules.mapping.get(name)
    if ax is None:
        return None
    if isinstance(ax, tuple):
        present = tuple(a for a in ax if a in rules.mesh.axis_names)
        return present or None
    return ax if ax in rules.mesh.axis_names else None


@contextlib.contextmanager
def sharding_rules(mesh: Mesh, mapping: Optional[dict] = None):
    rules = ShardingRules(mesh, dict(DEFAULT_MAPPING, **(mapping or {})))
    token = _RULES.set(rules)
    try:
        yield rules
    finally:
        _RULES.reset(token)


def constrain(x, *logical_names):
    """Apply a sharding constraint expressed in logical axis names; no-op
    when no rules are installed (CPU tests)."""
    rules = _RULES.get()
    if rules is None:
        return x
    spec = P(*[_translate(rules, n) for n in logical_names])
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(rules.mesh, spec))


def constrain_spec(x, spec):
    """Like ``constrain`` but takes a PartitionSpec of logical names
    (entries may be tuples of logical names). Drops axes whose size does
    not divide the dimension."""
    rules = _RULES.get()
    if rules is None:
        return x
    out = []
    for dim, entry in zip(x.shape, tuple(spec) + (None,) * (x.ndim
                                                            - len(spec))):
        if entry is None:
            out.append(None)
            continue
        logical = entry if isinstance(entry, tuple) else (entry,)
        axes = []
        for name in logical:
            ax = _translate(rules, name)
            if ax is None:
                continue
            for a in (ax if isinstance(ax, tuple) else (ax,)):
                if a not in axes:
                    axes.append(a)
        while axes:
            prod = 1
            for a in axes:
                prod *= rules.mesh.shape[a]
            if dim % prod == 0:
                break
            axes.pop()
        out.append(tuple(axes) if len(axes) > 1 else (axes[0] if axes
                                                      else None))
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(rules.mesh, P(*out)))


def current_mesh() -> Optional[Mesh]:
    rules = _RULES.get()
    return rules.mesh if rules else None
