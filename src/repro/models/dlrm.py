"""DLRM (MLPerf config): bottom MLP over dense features, 26 sparse
embedding lookups (EmbeddingBag substrate — JAX has no native one), dot
feature interaction, top MLP → CTR logit. Also the retrieval-scoring
serve path (one query vs 10^6 candidates as a batched dot, not a loop).

Sharding: tables are row-sharded over ``tp`` (the biggest Criteo tables
have 40M rows); lookups gather cross-shard (GSPMD all-gathers only the
hit rows), MLPs are replicated, batch over ``dp``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import DLRMConfig
from repro.models.layers import _dense_init
from repro.models.sharding import constrain


def _mlp_init(key, dims, dtype):
    ks = jax.random.split(key, len(dims) - 1)
    return [{"w": _dense_init(k, (a, b), dtype), "b": jnp.zeros((b,), dtype)}
            for k, a, b in zip(ks, dims[:-1], dims[1:])]


def _mlp(params, x, final_act=False):
    for i, p in enumerate(params):
        x = x @ p["w"] + p["b"]
        if i < len(params) - 1 or final_act:
            x = jax.nn.relu(x)
    return x


def init_dlrm(cfg: DLRMConfig, key):
    dt = jnp.dtype(cfg.dtype)
    k_bot, k_top, k_emb = jax.random.split(key, 3)
    tables = []
    for i, v in enumerate(cfg.vocab_sizes):
        k = jax.random.fold_in(k_emb, i)
        scale = 1.0 / jnp.sqrt(cfg.embed_dim)
        # Row counts padded to a shardable multiple (standard MLPerf DLRM
        # practice) — Criteo cardinalities are not divisible by any mesh.
        rows = -(-v // 512) * 512
        t = (jax.random.uniform(k, (rows, cfg.embed_dim), minval=-scale,
                                maxval=scale)).astype(dt)
        tables.append(constrain(t, "fsdp", "tp"))
    n_feat = 1 + cfg.n_sparse                      # bottom out + embeddings
    d_inter = cfg.embed_dim + n_feat * (n_feat - 1) // 2
    return {
        "bot": _mlp_init(k_bot, (cfg.n_dense,) + cfg.bot_mlp, dt),
        "tables": tables,
        "top": _mlp_init(k_top, (d_inter,) + cfg.top_mlp, dt),
    }


def _interact_dot(feats):
    """feats: (B, F, D) → (B, D + F·(F−1)/2): bottom output concatenated
    with the strictly-lower-triangular pairwise dot products."""
    b, f, d = feats.shape
    z = jnp.einsum("bfd,bgd->bfg", feats, feats)
    iu, ju = jnp.tril_indices(f, k=-1)
    return jnp.concatenate([feats[:, 0], z[:, iu, ju]], axis=-1)


def apply_dlrm(params, cfg: DLRMConfig, dense, sparse_ids):
    """dense f32[B, n_dense]; sparse_ids int32[B, n_sparse] (single-hot;
    multi-hot callers pre-reduce via embedding_bag). → logits f32[B]."""
    x0 = _mlp(params["bot"], dense, final_act=True)       # (B, D)
    embs = [jnp.take(t, sparse_ids[:, i], axis=0, mode="clip")
            for i, t in enumerate(params["tables"])]
    feats = jnp.stack([x0] + embs, axis=1)                # (B, F, D)
    feats = constrain(feats, "dp", None, None)
    z = _interact_dot(feats)
    return _mlp(params["top"], z)[:, 0]


def dlrm_loss(params, cfg: DLRMConfig, dense, sparse_ids, labels):
    logits = apply_dlrm(params, cfg, dense, sparse_ids)
    loss = jnp.mean(
        jnp.maximum(logits, 0) - logits * labels
        + jnp.log1p(jnp.exp(-jnp.abs(logits))))           # stable BCE
    return loss, {"bce": loss}


def retrieval_score(params, cfg: DLRMConfig, dense, sparse_ids,
                    candidate_table, top_k: int = 100):
    """Retrieval cell: embed one (or few) queries through the bottom MLP +
    interaction trunk, score against ``candidate_table`` (N_cand, D) with
    one batched matmul, return top-k (scores, indices)."""
    x0 = _mlp(params["bot"], dense, final_act=True)
    embs = [jnp.take(t, sparse_ids[:, i], axis=0, mode="clip")
            for i, t in enumerate(params["tables"])]
    q = x0 + sum(embs)                                    # (B, D) query vec
    q = constrain(q, "dp", None)
    scores = q @ candidate_table.T                        # (B, N_cand)
    scores = constrain(scores, "dp", "tp")
    return jax.lax.top_k(scores, top_k)
