"""Explicit expert-parallel MoE (shard_map) — the schedule GSPMD cannot
find on its own (auto-sharding the sort/gather dispatch rematerializes
the token array; the 1T config needs this path to fit).

Layout inside the region (per device, mesh axes pod×data×model):
  tokens   — sharded over (pod, data); *replicated* over model, arriving
             from the sequence-parallel residual stream via the region
             boundary's all-gather (Megatron-SP pattern).
  experts  — E/|model| local experts per model rank ("no-token-movement"
             EP: every rank routes the full local token block but
             computes only its own experts; the final psum-scatter sums
             the per-rank partial combines AND returns the result
             sequence-sharded — one collective, half an all-reduce).
  weights  — expert dim over model, f dim over (pod, data) [FSDP];
             gathered per layer *inside* the region with an explicit
             all_gather, so exactly one layer's experts are ever live.

Routing uses the same sort + run-length gather dispatch as moe.py
(capacity drop, per model-rank capacity C = T_loc·k·cf/E).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.models.sharding import _RULES, _translate


def _axes_tuple(ax):
    if ax is None:
        return ()
    return ax if isinstance(ax, tuple) else (ax,)


def moe_apply_ep(p, cfg, x):
    """x: (B, S, d) → (out (B, S, d), aux). Requires an active
    sharding_rules context with a 'tp' model axis."""
    rules = _RULES.get()
    mesh = rules.mesh
    model_ax = _translate(rules, "tp")
    fsdp_axes = _axes_tuple(_translate(rules, "fsdp"))
    dp_axes = _axes_tuple(_translate(rules, "dp"))
    m = cfg.moe
    b, s, d = x.shape
    e, k = m.n_experts, m.top_k
    p_model = mesh.shape[model_ax]
    e_loc = e // p_model
    xt = x.reshape(-1, d)
    act = jax.nn.silu if cfg.mlp == "swiglu" else jax.nn.gelu

    def region(xt, router, wg, wu, wd):
        t_loc = xt.shape[0]
        tk = t_loc * k
        cap = max(1, int(t_loc * k / e * m.capacity_factor))
        rank = lax.axis_index(model_ax)
        e0 = rank * e_loc

        # FSDP: gather this layer's expert weights over the batch axes
        if fsdp_axes:
            wg = lax.all_gather(wg, fsdp_axes, axis=2, tiled=True)
            wu = lax.all_gather(wu, fsdp_axes, axis=2, tiled=True)
            wd = lax.all_gather(wd, fsdp_axes, axis=1, tiled=True)

        # matmul in activation dtype (an f32 copy of the token block is
        # ~1 GiB/layer); softmax/top-k stay f32
        logits = (xt @ router.astype(xt.dtype)).astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        gate, ids = jax.lax.top_k(probs, k)
        gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)
        gate = gate.astype(x.dtype)   # combine path stays bf16 end-to-end

        ids_flat = ids.reshape(-1)
        order = jnp.argsort(ids_flat)
        sorted_ids = jnp.take(ids_flat, order)
        counts = jnp.bincount(ids_flat, length=e)
        starts = jnp.searchsorted(sorted_ids, jnp.arange(e))
        aux = m.router_aux_weight * e * jnp.sum(
            probs.mean(0) * counts.astype(jnp.float32) / tk)

        # dispatch: gather the capacity runs of the local experts only
        starts_loc = lax.dynamic_slice(starts, (e0,), (e_loc,))
        counts_loc = lax.dynamic_slice(counts, (e0,), (e_loc,))
        slot_idx = starts_loc[:, None] + jnp.arange(cap)[None, :]
        valid = jnp.arange(cap)[None, :] \
            < jnp.minimum(counts_loc, cap)[:, None]
        pair = jnp.take(order, jnp.clip(slot_idx, 0, tk - 1))
        disp = jnp.take(xt, pair // k, axis=0)             # (E_loc, C, d)
        disp = jnp.where(valid[..., None], disp, 0)

        h = act(jnp.einsum("ecd,edf->ecf", disp, wg)) \
            * jnp.einsum("ecd,edf->ecf", disp, wu)
        out_e = jnp.einsum("ecf,efd->ecd", h, wd)          # (E_loc, C, d)

        # combine: this rank's contribution to every (token, choice), as
        # one flat (T·k, d) bf16 gather. (A k-loop lax.scan variant was
        # tried to cut the buffer 8x — REFUTED: the scan saves its (T, d)
        # carry per step for backward, costing more than it saved. The
        # earlier 7 GiB figure was f32 promotion, fixed by pinning bf16.)
        rank_of = jnp.zeros((tk,), jnp.int32).at[order].set(
            jnp.arange(tk, dtype=jnp.int32))
        slot_flat = rank_of - jnp.take(starts, ids_flat)
        local = (ids_flat >= e0) & (ids_flat < e0 + e_loc) \
            & (slot_flat < cap)
        gathered = out_e[jnp.clip(ids_flat - e0, 0, e_loc - 1),
                         jnp.clip(slot_flat, 0, cap - 1)]
        gathered = jnp.where(local[:, None], gathered.astype(x.dtype), 0)
        partial = (gathered * gate.reshape(-1, 1)).reshape(
            t_loc, k, d).sum(1)
        if use_scatter:
            # sum expert ranks AND return sequence-sharded (SP re-entry)
            out = lax.psum_scatter(partial, model_ax, scatter_dimension=0,
                                   tiled=True)
        else:
            # decode-sized token blocks (< |model|): plain all-reduce
            out = lax.psum(partial, model_ax)
        return out, aux

    dp_prod = 1
    for a in dp_axes:
        dp_prod *= mesh.shape[a]
    t_local = xt.shape[0] // dp_prod
    use_scatter = t_local % p_model == 0 and t_local >= p_model
    tok_out_spec = (P((dp_axes + (model_ax,)) if dp_axes else model_ax,
                      None) if use_scatter else P(dp_axes or None, None))
    wg_spec = P(model_ax, None, fsdp_axes or None)
    wd_spec = P(model_ax, fsdp_axes or None, None)
    out = shard_map(
        region, mesh=mesh,
        in_specs=(P(dp_axes or None, None), P(None, None),
                  wg_spec, wg_spec, wd_spec),
        out_specs=(tok_out_spec, P()),
        check_vma=False,
    )(xt, p["router"], p["w_gate"], p["w_up"], p["w_down"])
    y, aux = out
    return y.reshape(b, s, d), aux
