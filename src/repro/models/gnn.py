"""GNN zoo: GIN, GatedGCN, GAT, SchNet — all built on the edge-index →
segment-reduce message-passing primitive (``jax.ops.segment_sum`` /
``segment_max``), the SpMM/SDDMM regime of the kernel taxonomy. The
same substrate carries the Δ-stepping scatter-min (DESIGN.md §5):
message passing over (src, dst, feat) is the GNN face of the paper's
relaxation sweep over (src, dst, weight).

Graphs arrive as fixed-shape COO edge lists (src, dst int32[E]); padding
edges use src == dst == n (rows gather zeros via fill, scatters drop).
All layers are pure functions over parameter pytrees.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import GNNConfig
from repro.models.layers import _dense_init
from repro.models.sharding import constrain


def _seg_sum(x, ids, n):
    return jax.ops.segment_sum(x, ids, num_segments=n)


def _gather(x, idx):
    return jnp.take(x, idx, axis=0, mode="fill", fill_value=0)


def _mlp_init(key, dims, dtype):
    ks = jax.random.split(key, len(dims) - 1)
    return [{"w": _dense_init(k, (a, b), dtype), "b": jnp.zeros((b,), dtype)}
            for k, a, b in zip(ks, dims[:-1], dims[1:])]


def _mlp(params, x, act=jax.nn.relu, final_act=False):
    for i, p in enumerate(params):
        x = x @ p["w"] + p["b"]
        if i < len(params) - 1 or final_act:
            x = act(x)
    return x


# ------------------------------------------------------------------------ GIN

def init_gin(cfg: GNNConfig, key):
    ks = jax.random.split(key, cfg.n_layers + 2)
    d = cfg.d_hidden
    layers = []
    for li in range(cfg.n_layers):
        d_in = cfg.d_in if li == 0 else d
        layers.append({
            "mlp": _mlp_init(ks[li], (d_in, d, d), jnp.dtype(cfg.dtype)),
            "eps": jnp.zeros((), jnp.float32),
        })
    return {"layers": layers,
            "readout": _mlp_init(ks[-1], (d, cfg.n_classes),
                                 jnp.dtype(cfg.dtype))}


def apply_gin(params, cfg: GNNConfig, x, src, dst):
    n = x.shape[0]
    for lp in params["layers"]:
        agg = _seg_sum(_gather(x, src), dst, n)           # sum aggregator
        x = _mlp(lp["mlp"], (1.0 + lp["eps"]) * x + agg)
        x = constrain(x, "tp", None)
    return _mlp(params["readout"], x)


# -------------------------------------------------------------------- GatedGCN

def init_gatedgcn(cfg: GNNConfig, key):
    ks = jax.random.split(key, cfg.n_layers * 5 + 2)
    d = cfg.d_hidden
    dt = jnp.dtype(cfg.dtype)
    layers = []
    for li in range(cfg.n_layers):
        o = li * 5
        layers.append({k: _dense_init(ks[o + j], (d, d), dt)
                       for j, k in enumerate(["A", "B", "C", "U", "V"])})
    return {"embed": _dense_init(ks[-2], (cfg.d_in, d), dt),
            "embed_e": _dense_init(ks[-1], (1, d), dt),
            "layers": layers,
            "readout": _mlp_init(jax.random.fold_in(key, 7),
                                 (d, cfg.n_classes), dt)}


def apply_gatedgcn(params, cfg: GNNConfig, x, src, dst, e_feat=None):
    n = x.shape[0]
    h = x @ params["embed"]
    if e_feat is None:
        e_feat = jnp.ones((src.shape[0], 1), h.dtype)
    e = e_feat @ params["embed_e"]

    def layer(carry, lp):
        # NOTE: jax.checkpoint here made ogb_products WORSE (49→63 GiB:
        # the bwd re-gathers are extra all-gathers and GSPMD replicates
        # the recomputed edge tensors). Sharding constraints only;
        # full-graph GatedGCN at 61.9M edges wants explicit shard_map
        # edge partitioning (like core/distributed) — documented limit.
        h, e = carry
        hi, hj = _gather(h, dst), _gather(h, src)
        e = e + hi @ lp["A"] + hj @ lp["B"]              # edge update
        e = constrain(e, "nodes", None)
        gate = jax.nn.sigmoid(e)
        msg = gate * (hj @ lp["V"])
        denom = _seg_sum(gate, dst, n) + 1e-6
        h_new = h @ lp["U"] + _seg_sum(msg, dst, n) / denom
        h = jax.nn.relu(h_new) + h                        # residual
        h = constrain(h, "nodes", None)
        return (h, e), None

    for lp in params["layers"]:
        (h, e), _ = layer((h, e), lp)
    return _mlp(params["readout"], h)


# ------------------------------------------------------------------------ GAT

def init_gat(cfg: GNNConfig, key):
    ks = jax.random.split(key, 2 * cfg.n_layers + 1)
    dt = jnp.dtype(cfg.dtype)
    d, hh = cfg.d_hidden, cfg.n_heads
    layers = []
    for li in range(cfg.n_layers):
        d_in = cfg.d_in if li == 0 else d * hh
        d_out = cfg.n_classes if li == cfg.n_layers - 1 else d
        layers.append({
            "w": _dense_init(ks[2 * li], (d_in, hh * d_out), dt),
            "a_src": _dense_init(ks[2 * li + 1], (hh, d_out), dt),
            "a_dst": _dense_init(jax.random.fold_in(ks[2 * li + 1], 1),
                                 (hh, d_out), dt),
        })
    return {"layers": layers}


def _edge_softmax(scores, dst, n):
    """Per-destination softmax over incoming edges (SDDMM → segment
    softmax), padding-safe via segment_max normalization."""
    smax = jax.ops.segment_max(scores, dst, num_segments=n)
    smax = jnp.where(jnp.isfinite(smax), smax, 0.0)
    ex = jnp.exp(scores - _gather(smax, dst))
    denom = _seg_sum(ex, dst, n)
    return ex / jnp.maximum(_gather(denom, dst), 1e-9)


def apply_gat(params, cfg: GNNConfig, x, src, dst):
    n = x.shape[0]
    n_layers = len(params["layers"])
    h = x
    for li, lp in enumerate(params["layers"]):
        hh = cfg.n_heads
        d_out = lp["w"].shape[1] // hh
        z = (h @ lp["w"]).reshape(n, hh, d_out)
        s_src = (z * lp["a_src"]).sum(-1)                 # (n, H)
        s_dst = (z * lp["a_dst"]).sum(-1)
        scores = jax.nn.leaky_relu(
            _gather(s_src, src) + _gather(s_dst, dst), 0.2)
        alpha = _edge_softmax(scores, dst, n)             # (E, H)
        msg = _gather(z, src) * alpha[..., None]
        out = _seg_sum(msg, dst, n)                       # (n, H, d_out)
        if li < n_layers - 1:
            h = jax.nn.elu(out.reshape(n, hh * d_out))
        else:
            h = out.mean(axis=1)                          # avg heads
        h = constrain(h, "tp", None)
    return h


# --------------------------------------------------------------------- SchNet

def init_schnet(cfg: GNNConfig, key):
    ks = jax.random.split(key, cfg.n_layers * 4 + 3)
    dt = jnp.dtype(cfg.dtype)
    d = cfg.d_hidden
    inter = []
    for li in range(cfg.n_layers):
        o = li * 4
        inter.append({
            "filter": _mlp_init(ks[o], (cfg.n_rbf, d, d), dt),
            "w_in": _dense_init(ks[o + 1], (d, d), dt),
            "w_out": _mlp_init(ks[o + 2], (d, d, d), dt),
        })
    return {"embed": _dense_init(ks[-3], (100, d), dt),   # atom types < 100
            "interactions": inter,
            "readout": _mlp_init(ks[-1], (d, d // 2, 1), dt)}


def _rbf_expand(dist, n_rbf: int, cutoff: float):
    centers = jnp.linspace(0.0, cutoff, n_rbf)
    gamma = 10.0 / cutoff
    return jnp.exp(-gamma * (dist[:, None] - centers) ** 2)


def _ssp(x):  # shifted softplus, SchNet's activation
    return jax.nn.softplus(x) - jnp.log(2.0)


def apply_schnet(params, cfg: GNNConfig, atom_z, pos, src, dst):
    """atom_z int32[n] atomic numbers, pos f32[n, 3], edges (src, dst).
    Returns per-graph energy contribution per atom (n, 1) — callers
    segment-sum over molecules."""
    n = atom_z.shape[0]
    h = jnp.take(params["embed"], atom_z, axis=0, mode="clip")
    diff = _gather(pos, src) - _gather(pos, dst)
    dist = jnp.sqrt((diff ** 2).sum(-1) + 1e-12)
    rbf = _rbf_expand(dist, cfg.n_rbf, cfg.cutoff)        # (E, n_rbf)
    fcut = 0.5 * (jnp.cos(jnp.pi * jnp.clip(dist / cfg.cutoff, 0, 1)) + 1.0)
    for lp in params["interactions"]:
        w = _mlp(lp["filter"], rbf, act=_ssp, final_act=True)
        w = w * fcut[:, None]                             # smooth cutoff
        m = _gather(h @ lp["w_in"], src) * w              # cfconv messages
        agg = _seg_sum(m, dst, n)
        h = h + _mlp(lp["w_out"], agg, act=_ssp)
        h = constrain(h, "tp", None)
    return _mlp(params["readout"], h, act=_ssp)


# ---------------------------------------------------------------- dispatcher

INIT = {"gin": init_gin, "gatedgcn": init_gatedgcn, "gat": init_gat,
        "schnet": init_schnet}


def init_gnn(cfg: GNNConfig, key):
    return INIT[cfg.arch](cfg, key)


def apply_gnn(params, cfg: GNNConfig, inputs):
    """inputs: dict with keys per arch (x/src/dst [+ pos/atom_z])."""
    if cfg.arch == "gin":
        return apply_gin(params, cfg, inputs["x"], inputs["src"],
                         inputs["dst"])
    if cfg.arch == "gatedgcn":
        return apply_gatedgcn(params, cfg, inputs["x"], inputs["src"],
                              inputs["dst"], inputs.get("e_feat"))
    if cfg.arch == "gat":
        return apply_gat(params, cfg, inputs["x"], inputs["src"],
                         inputs["dst"])
    if cfg.arch == "schnet":
        return apply_schnet(params, cfg, inputs["atom_z"], inputs["pos"],
                            inputs["src"], inputs["dst"])
    raise ValueError(cfg.arch)


def gnn_loss(params, cfg: GNNConfig, inputs, labels, label_mask=None):
    out = apply_gnn(params, cfg, inputs)
    if cfg.arch == "schnet":                              # energy regression
        n_mol = labels.shape[0]
        energy = jax.ops.segment_sum(out[:, 0], inputs["mol_id"],
                                     num_segments=n_mol)
        return jnp.mean((energy - labels) ** 2), {"mse": True}
    logp = jax.nn.log_softmax(out.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    if label_mask is not None:
        return (nll * label_mask).sum() / jnp.maximum(label_mask.sum(), 1), {}
    return nll.mean(), {}
