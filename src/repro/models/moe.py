"""Mixture-of-Experts FFN (token-choice top-k routing, capacity drop).

Dispatch is **gather-based** (sort + run-length indexing) rather than the
classic (T, E, C) one-hot einsum or scatter-with-cumsum:

  1. sort the flattened (token, choice) expert assignments;
  2. each expert's tokens form a contiguous run — its k-th capacity slot
     is ``order[start_e + k]``;
  3. the (E, C, d) dispatch buffer is a pure *gather* from the token
     array, and the combine is a pure gather from the expert outputs.

Gathers partition far better than scatters under GSPMD (no full-operand
rematerialization), and the intermediates are O(T·k) + O(E·C·d) with the
buffer sharded over experts ('tp') × capacity ('ep_cap') — this is what
lets the 1T config compile at 512 devices. Tokens overflowing an
expert's capacity are dropped (their gate weight contributes nothing),
standard capacity-factor semantics.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import _dense_init
from repro.models.sharding import constrain


def init_moe(key, cfg, dtype):
    m = cfg.moe
    d, f = cfg.d_model, m.d_ff_expert
    ks = jax.random.split(key, 5)
    e = m.n_experts
    p = {
        "router": _dense_init(ks[0], (d, e), jnp.float32),
        "w_gate": _dense_init(ks[1], (e, d, f), dtype),
        "w_up": _dense_init(ks[2], (e, d, f), dtype),
        "w_down": _dense_init(ks[3], (e, f, d), dtype),
    }
    if m.n_shared_experts:
        fs = f * m.n_shared_experts
        kss = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w_gate": _dense_init(kss[0], (d, fs), dtype),
            "w_up": _dense_init(kss[1], (d, fs), dtype),
            "w_down": _dense_init(kss[2], (fs, d), dtype),
        }
    return p


def moe_apply(p, cfg, x):
    """x: (B, S, d) → (out (B, S, d), aux_loss scalar). Dispatches to the
    explicit expert-parallel shard_map path when configured and a mesh
    context is active (production); falls back to the auto-sharded dense
    formulation otherwise (single-device tests)."""
    m = cfg.moe
    if m.impl == "ep":
        from repro.models.sharding import _RULES
        if _RULES.get() is not None:
            from repro.models.moe_ep import moe_apply_ep
            out, aux = moe_apply_ep(p, cfg, x)
            if m.n_shared_experts:
                sp = p["shared"]
                act = jax.nn.silu if cfg.mlp == "swiglu" else jax.nn.gelu
                xt = x.reshape(-1, x.shape[-1])
                hs = act(xt @ sp["w_gate"]) * (xt @ sp["w_up"])
                out = out + (hs @ sp["w_down"]).reshape(x.shape)
            return out, aux
    b, s, d = x.shape
    t = b * s
    xt = x.reshape(t, d)
    e, k = m.n_experts, m.top_k
    tk = t * k

    logits = (xt @ p["router"].astype(xt.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, ids = jax.lax.top_k(probs, k)                    # (T, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    ids_flat = ids.reshape(-1)                             # (TK,)
    order = jnp.argsort(ids_flat)                          # stable
    sorted_ids = jnp.take(ids_flat, order)
    counts = jnp.bincount(ids_flat, length=e)              # (E,)
    starts = jnp.searchsorted(sorted_ids, jnp.arange(e))   # (E,)

    # load-balancing aux loss (Switch-style)
    me = probs.mean(0)
    aux = m.router_aux_weight * e * jnp.sum(
        me * counts.astype(jnp.float32) / tk)

    cap = max(1, int(t * k / e * m.capacity_factor))

    # --- dispatch: gather each expert's capacity run ------------------
    slot_idx = starts[:, None] + jnp.arange(cap)[None, :]          # (E, C)
    valid = jnp.arange(cap)[None, :] < jnp.minimum(counts, cap)[:, None]
    pair = jnp.take(order, jnp.clip(slot_idx, 0, tk - 1))          # (E, C)
    tok = pair // k
    disp = jnp.take(xt, tok, axis=0)                               # (E, C, d)
    disp = jnp.where(valid[..., None], disp, 0)
    disp = constrain(disp, "tp", "ep_cap", None)

    act = jax.nn.silu if cfg.mlp == "swiglu" else jax.nn.gelu
    h = act(jnp.einsum("ecd,edf->ecf", disp, p["w_gate"])) \
        * jnp.einsum("ecd,edf->ecf", disp, p["w_up"])
    h = constrain(h, "tp", "ep_cap", None)
    out_e = jnp.einsum("ecf,efd->ecd", h, p["w_down"])             # (E, C, d)
    out_e = constrain(out_e, "tp", "ep_cap", None)

    # --- combine: gather every (token, choice)'s expert output --------
    rank = jnp.zeros((tk,), jnp.int32).at[order].set(
        jnp.arange(tk, dtype=jnp.int32))
    slot_flat = rank - jnp.take(starts, ids_flat)                  # (TK,)
    keep = slot_flat < cap
    gathered = out_e[ids_flat, jnp.clip(slot_flat, 0, cap - 1)]    # (TK, d)
    gathered = jnp.where(keep[:, None], gathered, 0)
    w_flat = gate.reshape(-1, 1).astype(x.dtype)
    out = (gathered * w_flat).reshape(t, k, d).sum(1)              # (T, d)

    if m.n_shared_experts:
        sp = p["shared"]
        hs = act(xt @ sp["w_gate"]) * (xt @ sp["w_up"])
        out = out + hs @ sp["w_down"]
    return out.reshape(b, s, d), aux
