"""Decoder-only transformer LM: dense and MoE variants, GQA, sliding
window (gemma2 alternating pattern), attention/logit softcaps, tied
embeddings — the five assigned LM architectures are instances of this
one module (configs/*.py).

Layers are **scanned** (stacked parameters, ``lax.scan`` over the layer
axis): at 61 layers × 512 devices this keeps dry-run compile times and
HLO size flat in depth. Alternating local attention is handled with a
traced per-layer window so the scan body stays uniform.

API (all pure):
  init_lm(cfg, key)                      → params
  apply_lm(params, cfg, tokens)          → (logits, aux)    # training
  lm_loss(params, cfg, tokens, labels)   → (loss, metrics)  # chunked xent
  init_cache(cfg, batch, max_len, dtype) → cache
  prefill(params, cfg, tokens, cache)    → (logits_last, cache)
  decode_step(params, cfg, cache, tok)   → (logits, cache)
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import LMConfig
from repro.models import moe as moe_lib
from repro.models.layers import (
    _dense_init,
    attention_apply,
    init_attention,
    init_mlp,
    init_rms_norm,
    mlp_apply,
    rms_norm,
)
from repro.models.sharding import constrain

_BIG_WINDOW = 1 << 30


def _init_layer(cfg: LMConfig, key):
    ka, km, kn = jax.random.split(key, 3)
    p = {
        "attn": init_attention(key=ka, cfg=cfg, dtype=cfg.pdtype),
        "attn_norm": init_rms_norm(cfg.d_model, cfg.pdtype),
        "mlp_norm": init_rms_norm(cfg.d_model, cfg.pdtype),
    }
    if cfg.moe is not None:
        p["moe"] = moe_lib.init_moe(km, cfg, cfg.pdtype)
    else:
        p["mlp"] = init_mlp(km, cfg.d_model, cfg.d_ff, cfg.mlp, cfg.pdtype)
    if cfg.attn_softcap is not None:  # gemma2 family: post-block norms
        p["post_attn_norm"] = init_rms_norm(cfg.d_model, cfg.pdtype)
        p["post_mlp_norm"] = init_rms_norm(cfg.d_model, cfg.pdtype)
    return p


def init_lm(cfg: LMConfig, key):
    ke, kl, ku = jax.random.split(key, 3)
    layer_keys = jax.random.split(kl, cfg.n_layers)
    layers = jax.vmap(partial(_init_layer, cfg))(layer_keys)
    params = {
        "embed": _dense_init(ke, (cfg.vocab, cfg.d_model), cfg.pdtype,
                             scale=0.02),
        "layers": layers,
        "final_norm": init_rms_norm(cfg.d_model, cfg.pdtype),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = _dense_init(ku, (cfg.d_model, cfg.vocab),
                                        cfg.pdtype)
    return params


def _layer_window(cfg: LMConfig, idx):
    """Traced per-layer attention window (None ⇒ no window op at all)."""
    if cfg.window is None:
        return None
    if cfg.window_pattern <= 1:
        return jnp.int32(cfg.window)
    return jnp.where(idx % cfg.window_pattern == 0,
                     jnp.int32(cfg.window), jnp.int32(_BIG_WINDOW))


def _constrain_layer_slice(lp):
    """Pin this layer's parameter slice to its (layer-dim-stripped)
    sharding. Without this, GSPMD may hoist the FSDP all-gather of the
    *whole stacked* (L, ...) weight array out of the scan — 61 layers of
    gathered expert weights live at once sank the 1T config. No-op
    outside a sharding_rules context."""
    from repro.models.param_sharding import lm_layer_slice_rule
    from repro.models.sharding import constrain_spec

    def rule(kp, x):
        path = "/".join(str(getattr(k, "key", getattr(k, "idx", "")))
                        for k in kp)
        return constrain_spec(x, lm_layer_slice_rule(path))

    return jax.tree_util.tree_map_with_path(rule, lp)


def _block(cfg: LMConfig, lp, x, idx, kv=None, cache_len=None):
    """One transformer block. Returns (x, aux, new_kv)."""
    lp = _constrain_layer_slice(lp)
    window = _layer_window(cfg, idx)
    # Pin the loop-carried residual to its sequence-parallel sharding at
    # body ENTRY as well: otherwise GSPMD resolves the carry's layout
    # from the attention all-gather that immediately consumes it, and
    # every layer's saved remat checkpoint materializes seq-gathered
    # (~470 MB/layer on the 1T config instead of ~30 MB).
    x = constrain(x, "dp", "act_seq", None)
    h = rms_norm(lp["attn_norm"], x)
    attn_out, new_kv = attention_apply(
        lp["attn"], cfg, h, window_arr=window, kv_cache=kv,
        cache_len=cache_len)
    if cfg.attn_softcap is not None:
        attn_out = rms_norm(lp["post_attn_norm"], attn_out)
    # 'act_seq' maps to the model axis during training (sequence
    # parallelism): the residual stream — and therefore each layer's saved
    # remat checkpoint — is sharded over seq, not replicated across tp.
    x = constrain(x + attn_out, "dp", "act_seq", None)
    h = rms_norm(lp["mlp_norm"], x)
    if cfg.moe is not None:
        ff, aux = moe_lib.moe_apply(lp["moe"], cfg, h)
    else:
        ff, aux = mlp_apply(lp["mlp"], h, cfg.mlp), jnp.zeros((),
                                                              jnp.float32)
    if cfg.attn_softcap is not None:
        ff = rms_norm(lp["post_mlp_norm"], ff)
    x = constrain(x + ff, "dp", "act_seq", None)
    return x, aux, new_kv


def _stack_scan(cfg: LMConfig, params, x, cache=None, cache_len=None):
    """Scan over stacked layer params (and KV cache slices, if serving)."""
    idxs = jnp.arange(cfg.n_layers, dtype=jnp.int32)

    if cache is None:
        def body(carry, scanned):
            x, aux = carry
            lp, idx = scanned
            x, a, _ = _block(cfg, lp, x, idx)
            return (x, aux + a), None

        body = jax.checkpoint(body) if cfg.remat else body
        (x, aux), _ = lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                               (params["layers"], idxs))
        return x, aux, None

    def body(carry, scanned):
        x, aux = carry
        lp, idx, ck, cv = scanned
        x, a, (nk, nv) = _block(cfg, lp, x, idx, kv=(ck, cv),
                                cache_len=cache_len)
        return (x, aux + a), (nk, nv)

    body = jax.checkpoint(body) if cfg.remat else body
    (x, aux), (nk, nv) = lax.scan(
        body, (x, jnp.zeros((), jnp.float32)),
        (params["layers"], idxs, cache["k"], cache["v"]))
    return x, aux, {"k": nk, "v": nv}


def _logits(params, cfg: LMConfig, x):
    x = rms_norm(params["final_norm"], x)
    w = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    logits = x @ w.astype(x.dtype)
    if cfg.logit_softcap is not None:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    return constrain(logits, "dp", None, "tp")


def _embed(params, cfg: LMConfig, tokens):
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.adtype)
    return constrain(x, "dp", None, None)


def apply_lm(params, cfg: LMConfig, tokens):
    """Training forward: tokens int32[B, S] → (logits f32[B, S, V], aux)."""
    x = _embed(params, cfg, tokens)
    x, aux, _ = _stack_scan(cfg, params, x)
    return _logits(params, cfg, x), aux


def lm_loss(params, cfg: LMConfig, tokens, labels, loss_chunk: int = 512):
    """Next-token cross-entropy, computed over sequence chunks so the
    (B, S, V) logits tensor is never fully materialized (vocab 160k ×
    1M tokens would be ~600 GB)."""
    x = _embed(params, cfg, tokens)
    x, aux, _ = _stack_scan(cfg, params, x)
    x = rms_norm(params["final_norm"], x)
    w = (params["embed"].T if cfg.tie_embeddings
         else params["unembed"]).astype(x.dtype)

    b, s, d = x.shape
    c = min(loss_chunk, s)
    assert s % c == 0
    xc = x.reshape(b, s // c, c, d).swapaxes(0, 1)       # (S/c, B, c, d)
    lc = labels.reshape(b, s // c, c).swapaxes(0, 1)

    @jax.checkpoint
    def chunk_loss(carry, xs):
        # checkpointed: without it the scan saves every chunk's (B, c, V)
        # logits for backward — ~21 GiB/device at vocab 164k.
        xi, li = xs
        logits = xi @ w
        if cfg.logit_softcap is not None:
            logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
        logits = constrain(logits.astype(jnp.float32), "dp", None, "tp")
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, li[..., None], axis=-1)[..., 0]
        return carry + (lse - gold).sum(), None

    total, _ = lax.scan(chunk_loss, jnp.zeros((), jnp.float32), (xc, lc))
    loss = total / (b * s) + aux
    return loss, {"xent": total / (b * s), "aux": aux}


# ------------------------------------------------------------------ serving

def init_cache(cfg: LMConfig, batch: int, max_len: int, dtype=None):
    dtype = dtype or cfg.adtype
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.head_dim_)
    return {
        "k": constrain(jnp.zeros(shape, dtype), None, "dp", "sp", None, None),
        "v": constrain(jnp.zeros(shape, dtype), None, "dp", "sp", None, None),
        "len": jnp.zeros((), jnp.int32),
    }


def prefill(params, cfg: LMConfig, tokens, cache):
    """Run the prompt through the model, filling cache[0:S]."""
    x = _embed(params, cfg, tokens)
    x, _, kv = _stack_scan(cfg, params, x, cache=cache,
                           cache_len=jnp.zeros((), jnp.int32))
    cache = {"k": kv["k"], "v": kv["v"],
             "len": jnp.asarray(tokens.shape[1], jnp.int32)}
    return _logits(params, cfg, x[:, -1:]), cache


def decode_step(params, cfg: LMConfig, cache, tokens):
    """One decode step: tokens int32[B, 1] → (logits [B, 1, V], cache)."""
    x = _embed(params, cfg, tokens)
    x, _, kv = _stack_scan(cfg, params, x, cache=cache,
                           cache_len=cache["len"])
    cache = {"k": kv["k"], "v": kv["v"], "len": cache["len"] + 1}
    return _logits(params, cfg, x), cache
