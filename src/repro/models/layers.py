"""Transformer building blocks: RMSNorm, RoPE, GQA attention (sliding
window, logit softcap, chunked/flash-style query blocking), gated MLPs.

Pure-functional: params are plain pytrees (nested dicts of arrays);
every layer is ``f(params, x, ...) -> y``. Sharding is applied outside
via PartitionSpec trees matched to parameter paths (models/sharding.py),
keeping model math independent of the mesh.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

# ----------------------------------------------------------------- init utils


def _dense_init(key, shape, dtype, scale=None):
    fan_in = shape[0]
    scale = scale if scale is not None else 1.0 / jnp.sqrt(fan_in)
    return (jax.random.normal(key, shape) * scale).astype(dtype)


# --------------------------------------------------------------------- norms

def rms_norm(g, x, eps=1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + g.astype(jnp.float32))).astype(x.dtype)


def init_rms_norm(d, dtype):
    return jnp.zeros((d,), dtype)


# ---------------------------------------------------------------------- RoPE

def rope(x, positions, theta=10000.0):
    """x: (..., S, H, Dh); positions: broadcastable to (..., S)."""
    dh = x.shape[-1]
    half = dh // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., :, None, None].astype(jnp.float32) * freq  # (...,S,1,half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------- attention

def _softcap(x, cap: Optional[float]):
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


def gqa_attention(q, k, v, *, q_offset, causal: bool = True,
                  window: Optional[int] = None,
                  softcap: Optional[float] = None,
                  kv_len: Optional[jax.Array] = None,
                  chunk: Optional[int] = None):
    """Grouped-query attention.

    q: (B, S, H, Dh); k, v: (B, T, Hk, Dh) with H % Hk == 0.
    q_offset: traced or static — absolute position of q[0] (decode uses
    the cache length). kv_len: optional traced valid KV length (entries
    beyond it are masked; used by decode with a preallocated cache).
    chunk: query-block size for flash-style blocking (bounds the live
    score tile to (B, Hk, G, chunk, T)).
    """
    from repro.models.sharding import constrain

    b, s, h, dh = q.shape
    t, hk = k.shape[1], k.shape[2]
    g = h // hk
    scale = dh ** -0.5
    # Repeat KV to full head count instead of a (hk, g) grouped einsum:
    # hk·g factorizations (8×8 at tp=16) cannot shard evenly and GSPMD
    # pads the f32 score tensor 2x and all-gathers it; the repeated KV is
    # head-sharded over tp, so scores stay (b, h_local, c, t) per device.
    # EXCEPT decode (s == 1): there K/V is the whole sequence-sharded
    # cache — repeating it materializes G full cache copies (observed
    # 30 GiB at 524k context). Scores are tiny at s == 1, so the grouped
    # einsum (with padded head sharding) is strictly better.
    if g > 1 and s == 1:
        qg = q.reshape(b, 1, hk, g, dh)
        scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                            k.astype(jnp.float32)) * scale
        scores = _softcap(scores, softcap)
        kpos = jnp.arange(t)[None, :]
        qpos = q_offset + jnp.zeros((1, 1), jnp.int32)
        mask = jnp.ones((1, t), bool)
        if causal:
            mask &= qpos >= kpos
        if window is not None:
            mask &= qpos - kpos < window
        if kv_len is not None:
            mask &= kpos < kv_len
        scores = jnp.where(mask[None, None, None], scores, -1e30)
        p = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
        return out.astype(q.dtype).reshape(b, 1, h, dh)
    if g > 1:
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
        k = constrain(k, "dp", None, "tp", None)
        v = constrain(v, "dp", None, "tp", None)

    def block(q_blk, off):
        # q_blk: (B, c, H, Dh)
        scores = jnp.einsum("bqhd,bkhd->bhqk", q_blk.astype(jnp.float32),
                            k.astype(jnp.float32)) * scale
        scores = _softcap(scores, softcap)
        qpos = off + jnp.arange(q_blk.shape[1])[:, None]
        kpos = jnp.arange(t)[None, :]
        mask = jnp.ones((q_blk.shape[1], t), bool)
        if causal:
            mask &= qpos >= kpos
        if window is not None:
            mask &= qpos - kpos < window
        if kv_len is not None:
            mask &= kpos < kv_len
        scores = jnp.where(mask[None, None], scores, -1e30)
        p = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
        return out.astype(q.dtype)

    if chunk is None or chunk >= s or s % chunk != 0:
        out = block(q, q_offset)
    else:
        qb = q.reshape(b, s // chunk, chunk, h, dh)
        offs = q_offset + jnp.arange(s // chunk) * chunk

        @jax.checkpoint
        def step(_, xs):
            # checkpointed: otherwise the scan saves every chunk's f32
            # softmax probabilities for backward (stacked (n_chunks, B,
            # H, c, T) — GiBs at 4k x 4k).
            qi, oi = xs
            return None, block(qi, oi)

        _, outs = lax.scan(step, None, (qb.swapaxes(0, 1), offs))
        out = outs.swapaxes(0, 1).reshape(b, s, h, dh)
    return out


# ---------------------------------------------------------------------- MLPs

def mlp_apply(p, x, kind: str):
    if kind in ("swiglu", "geglu"):
        act = jax.nn.silu if kind == "swiglu" else jax.nn.gelu
        h = act(x @ p["w_gate"]) * (x @ p["w_up"])
        return h @ p["w_down"]
    h = jax.nn.gelu(x @ p["w_up"])
    return h @ p["w_down"]


def init_mlp(key, d_model, d_ff, kind: str, dtype):
    ks = jax.random.split(key, 3)
    p = {"w_up": _dense_init(ks[0], (d_model, d_ff), dtype),
         "w_down": _dense_init(ks[1], (d_ff, d_model), dtype)}
    if kind in ("swiglu", "geglu"):
        p["w_gate"] = _dense_init(ks[2], (d_model, d_ff), dtype)
    return p


# ----------------------------------------------------------- attention block

def init_attention(key, cfg, dtype):
    d, hd = cfg.d_model, cfg.head_dim_
    ks = jax.random.split(key, 4)
    return {
        "wq": _dense_init(ks[0], (d, cfg.n_heads * hd), dtype),
        "wk": _dense_init(ks[1], (d, cfg.n_kv_heads * hd), dtype),
        "wv": _dense_init(ks[2], (d, cfg.n_kv_heads * hd), dtype),
        "wo": _dense_init(ks[3], (cfg.n_heads * hd, d), dtype),
    }


def attention_apply(p, cfg, x, *, window_arr=None, kv_cache=None,
                    cache_len=None):
    """Returns (out, new_kv) — new_kv is (k, v) of this call's tokens when
    kv_cache is None, else the updated cache tuple. ``window_arr`` is a
    traced (or static) window size so alternating local/global layers can
    share one scanned block; None disables windowing entirely."""
    from repro.models.sharding import constrain

    b, s, d = x.shape
    hd = cfg.head_dim_
    offset = cache_len if cache_len is not None else jnp.zeros((), jnp.int32)
    q = (x @ p["wq"]).reshape(b, s, cfg.n_heads, hd)
    k = (x @ p["wk"]).reshape(b, s, cfg.n_kv_heads, hd)
    v = (x @ p["wv"]).reshape(b, s, cfg.n_kv_heads, hd)
    q = constrain(q, "dp", None, "tp", None)
    k = constrain(k, "dp", None, "tp", None)
    v = constrain(v, "dp", None, "tp", None)
    positions = offset + jnp.arange(s)[None, :]
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)

    if kv_cache is not None:
        ck, cv = kv_cache
        ck = lax.dynamic_update_slice_in_dim(ck, k.astype(ck.dtype),
                                             cache_len, axis=1)
        cv = lax.dynamic_update_slice_in_dim(cv, v.astype(cv.dtype),
                                             cache_len, axis=1)
        out = gqa_attention(q, ck, cv, q_offset=offset,
                            window=window_arr, softcap=cfg.attn_softcap,
                            kv_len=cache_len + s,
                            chunk=cfg.attention_chunk)
        new_kv = (ck, cv)
    else:
        out = gqa_attention(q, k, v, q_offset=0, window=window_arr,
                            softcap=cfg.attn_softcap,
                            chunk=cfg.attention_chunk)
        new_kv = (k, v)
    out = out.reshape(b, s, cfg.n_heads * hd) @ p["wo"]
    return out, new_kv
