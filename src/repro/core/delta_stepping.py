"""Single-device Δ-stepping SSSP engine (paper Alg. 1/2, DESIGN.md §2).

The paper's shared-memory mechanisms map onto JAX dataflow:

* dense bucket array (C1)   → ``bucket_id = tent // Δ`` recomputed by a
  full vector scan each inner iteration; the frontier is a boolean mask.
* CAS minimum loop (C2)     → ``tent.at[dst].min(candidates)`` scatter-min.
* 64-bit (cost, pred) packing (C3) → ``pred_mode='packed'`` scatter-mins
  one int64 word per vertex; ``pred_mode='argmin'`` is the 32-bit
  two-pass TPU-idiomatic equivalent (post-hoc tree recovery).
* private request sets + dup work (C4) → relaxations are emitted without
  dedup and filtered early with ``cand < tent[dst]`` before the scatter.
* read/write decoupling (C5) → gather phase and scatter phase are
  separate XLA ops by construction.

Two relaxation strategies (config.strategy):

* ``edge``: edge-centric — every inner iteration sweeps all |E| edges,
  masked by frontier membership of their source. Fixed shapes, no
  compaction; optimal for the paper's low-diameter graph classes.
* ``ell``: frontier-centric — compacts the frontier into a fixed-capacity
  index buffer and expands ELL-padded light/heavy adjacency rows
  (preprocessed split, paper Alg. 1 lines 3–5). Work scales with
  |frontier|·max_deg instead of |E|.

Weights must be non-negative int32; ``pred_mode='argmin'`` additionally
assumes weights >= 1 (zero-weight ties could close a predecessor cycle;
``packed`` mode is safe for zero weights, see pack.py).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import pack as packing
from repro.graphs.structures import (
    COOGraph,
    CSRGraph,
    ELLGraph,
    INF32,
    coo_to_csr,
    csr_to_ell,
    light_heavy_split,
)

_IMAX = jnp.int32(2**31 - 1)


@dataclasses.dataclass(frozen=True)
class DeltaConfig:
    """Configuration of the Δ-stepping engine.

    delta        — bucket width Δ (paper's tuning parameter, Fig. 1).
    strategy     — 'edge' | 'ell' relaxation strategy (see module doc).
    pred_mode    — 'none' | 'argmin' | 'packed' predecessor tracking.
    frontier_cap — 'ell' only: static capacity of the compacted frontier
                   (defaults to |V|; smaller saves work if an upper bound
                   on per-bucket frontier size is known).
    """

    delta: int = 10
    strategy: str = "edge"
    pred_mode: str = "argmin"
    frontier_cap: Optional[int] = None

    def __post_init__(self):
        if self.strategy not in ("edge", "ell"):
            raise ValueError(f"unknown strategy {self.strategy!r}")
        if self.pred_mode not in ("none", "argmin", "packed"):
            raise ValueError(f"unknown pred_mode {self.pred_mode!r}")
        if self.delta < 1:
            raise ValueError("delta must be >= 1")


class SSSPResult(NamedTuple):
    dist: jax.Array          # int32[n], INF32 = unreachable
    pred: jax.Array          # int32[n], -1 = source/unreachable
    outer_iters: jax.Array   # int32: number of buckets processed
    inner_iters: jax.Array   # int32: total light-phase sweeps
    overflow: jax.Array      # bool: 'ell' frontier capacity exceeded


def _require_x64():
    if jnp.zeros((), jnp.int64).dtype != jnp.int64:
        raise RuntimeError(
            "pred_mode='packed' packs (dist, pred) into int64 and requires "
            "x64 (wrap the call in jax.experimental.enable_x64())."
        )


# ---------------------------------------------------------------------------
# value-word helpers: the engine is generic over 'plain int32 distance' vs
# 'packed int64 (distance, predecessor)' words.
# ---------------------------------------------------------------------------

def _init_tent(n: int, source, packed: bool):
    if packed:
        tent = jnp.full((n,), packing.INF_PACKED, dtype=jnp.int64)
        src_word = packing.pack(jnp.zeros((), jnp.int32),
                                jnp.asarray(source, jnp.int32))
        return tent.at[source].set(src_word)
    return jnp.full((n,), INF32, jnp.int32).at[source].set(0)


def _dist_of(tent, packed: bool):
    return packing.unpack_dist(tent) if packed else tent


def _candidate_words(cand_d, src_ids, ok, packed: bool):
    if packed:
        word = packing.pack(cand_d, src_ids)
        return jnp.where(ok, word, packing.INF_PACKED)
    return jnp.where(ok, cand_d, INF32)


# ---------------------------------------------------------------------------
# edge-centric sweep (shared with the distributed engine)
# ---------------------------------------------------------------------------

def edge_sweep(tent, frontier, src, dst, w, *, delta: int, light: bool,
               packed: bool):
    """One relaxation sweep over an edge array, masked by frontier[src]
    and the light/heavy phase. Padding edges may carry src == n (sentinel):
    out-of-range gathers are filled inactive, out-of-range scatters drop —
    the TPU version of the paper's 'benign garbage writes' argument."""
    d = _dist_of(tent, packed)
    f = jnp.take(frontier, src, mode="fill", fill_value=False)
    d_src = jnp.take(d, src, mode="fill", fill_value=INF32)
    active = f & (d_src < INF32)
    cand = jnp.where(active, d_src, 0) + jnp.where(active, w, 0)
    phase = (w <= delta) if light else (w > delta)
    d_dst = jnp.take(d, dst, mode="fill", fill_value=INF32)
    ok = active & phase & (cand < d_dst)   # C4: early filter before scatter
    words = _candidate_words(cand, src, ok, packed)
    return tent.at[dst].min(words, mode="drop")


def _frontier_of(tent, explored, i, *, delta: int, packed: bool):
    d = _dist_of(tent, packed)
    return (d < INF32) & (d // delta == i) & (d < explored)


def _next_bucket(tent, i, *, delta: int, packed: bool):
    d = _dist_of(tent, packed)
    b = jnp.where(d < INF32, d // delta, _IMAX)
    b = jnp.where(b > i, b, _IMAX)
    return b.min()


@partial(jax.jit, static_argnames=("n", "delta", "packed"))
def _solve_edge(src, dst, w, source, *, n: int, delta: int, packed: bool):
    tent0 = _init_tent(n, source, packed)
    explored0 = jnp.full((n,), INF32, jnp.int32)

    def light_phase(tent, explored, i, inner):
        in_s0 = jnp.zeros((n,), bool)
        f0 = _frontier_of(tent, explored, i, delta=delta, packed=packed)

        def cond(c):
            return c[3].any()

        def body(c):
            tent, explored, in_s, f, inner = c
            d = _dist_of(tent, packed)
            explored = jnp.where(f, d, explored)   # paper: move into S
            in_s = in_s | f
            tent = edge_sweep(tent, f, src, dst, w, delta=delta, light=True,
                              packed=packed)
            f = _frontier_of(tent, explored, i, delta=delta, packed=packed)
            return (tent, explored, in_s, f, inner + 1)

        return lax.while_loop(cond, body, (tent, explored, in_s0, f0, inner))

    def outer_body(c):
        tent, explored, i, outer, inner = c
        tent, explored, in_s, _, inner = light_phase(tent, explored, i, inner)
        # heavy pass from S (paper Alg. 1 lines 19-20)
        tent = edge_sweep(tent, in_s, src, dst, w, delta=delta, light=False,
                          packed=packed)
        i = _next_bucket(tent, i, delta=delta, packed=packed)
        return (tent, explored, i, outer + 1, inner)

    def outer_cond(c):
        return c[2] < _IMAX

    i0 = jnp.zeros((), jnp.int32)  # relax(s, 0) puts the source in B_0
    tent, _, _, outer, inner = lax.while_loop(
        outer_cond, outer_body,
        (tent0, explored0, i0, jnp.zeros((), jnp.int32),
         jnp.zeros((), jnp.int32)))
    return tent, outer, inner


# ---------------------------------------------------------------------------
# frontier-centric (ELL) sweep
# ---------------------------------------------------------------------------

def ell_sweep(tent, fidx, nbr, w_ell, *, n: int, packed: bool):
    """Expand compacted frontier rows of an ELL adjacency block.
    ``fidx`` int32[cap] with sentinel value n for padding slots."""
    d = _dist_of(tent, packed)
    rows_n = nbr[fidx]                      # (cap, D); row n is all-sentinel
    rows_w = w_ell[fidx]
    d_f = jnp.take(d, fidx, mode="fill", fill_value=INF32)
    valid = (rows_n < n) & (rows_w < INF32) & (d_f[:, None] < INF32)
    cand = (jnp.where(valid, d_f[:, None], 0)
            + jnp.where(valid, rows_w, 0))
    d_dst = jnp.take(d, rows_n, mode="fill", fill_value=INF32)
    ok = valid & (cand < d_dst)
    src_ids = jnp.broadcast_to(fidx[:, None], rows_n.shape)
    words = _candidate_words(cand, src_ids, ok, packed)
    return tent.at[rows_n.ravel()].min(words.ravel(), mode="drop")


@partial(jax.jit, static_argnames=("n", "delta", "packed", "cap"))
def _solve_ell(lnbr, lw, hnbr, hw, source, *, n: int, delta: int,
               packed: bool, cap: int):
    tent0 = _init_tent(n, source, packed)
    explored0 = jnp.full((n,), INF32, jnp.int32)

    def compact(mask):
        idx = jnp.nonzero(mask, size=cap, fill_value=n)[0].astype(jnp.int32)
        over = mask.sum() > cap
        return idx, over

    def light_phase(tent, explored, i, inner, over):
        in_s0 = jnp.zeros((n,), bool)
        f0 = _frontier_of(tent, explored, i, delta=delta, packed=packed)

        def cond(c):
            return c[3].any()

        def body(c):
            tent, explored, in_s, f, inner, over = c
            d = _dist_of(tent, packed)
            explored = jnp.where(f, d, explored)
            in_s = in_s | f
            fidx, o = compact(f)
            tent = ell_sweep(tent, fidx, lnbr, lw, n=n, packed=packed)
            f = _frontier_of(tent, explored, i, delta=delta, packed=packed)
            return (tent, explored, in_s, f, inner + 1, over | o)

        return lax.while_loop(cond, body,
                              (tent, explored, in_s0, f0, inner, over))

    def outer_body(c):
        tent, explored, i, outer, inner, over = c
        tent, explored, in_s, _, inner, over = light_phase(
            tent, explored, i, inner, over)
        sidx, o = compact(in_s)
        tent = ell_sweep(tent, sidx, hnbr, hw, n=n, packed=packed)
        i = _next_bucket(tent, i, delta=delta, packed=packed)
        return (tent, explored, i, outer + 1, inner, over | o)

    def outer_cond(c):
        return c[2] < _IMAX

    i0 = jnp.zeros((), jnp.int32)
    tent, _, _, outer, inner, over = lax.while_loop(
        outer_cond, outer_body,
        (tent0, explored0, i0, jnp.zeros((), jnp.int32),
         jnp.zeros((), jnp.int32), jnp.zeros((), bool)))
    return tent, outer, inner, over


# ---------------------------------------------------------------------------
# predecessor recovery (two-pass argmin mode)
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("n",))
def pred_argmin(dist, src, dst, w, source, *, n: int):
    """Recover a shortest-path tree from converged distances: for every
    edge achieving dist[src] + w == dist[dst], scatter-min the source id.
    Deterministic (smallest-id parent wins), matching packed-mode ties."""
    d_src = jnp.take(dist, src, mode="fill", fill_value=INF32)
    d_dst = jnp.take(dist, dst, mode="fill", fill_value=INF32)
    cand = jnp.where(d_src < INF32, d_src, 0) + jnp.where(d_src < INF32, w, 0)
    ok = (d_src < INF32) & (d_dst < INF32) & (cand == d_dst)
    p = jnp.full((n,), _IMAX, jnp.int32).at[dst].min(
        jnp.where(ok, src, _IMAX), mode="drop")
    pred = jnp.where((p < _IMAX) & (dist < INF32), p, -1)
    return pred.at[source].set(-1)


def _finish_pred(tent, coo: COOGraph, source, cfg: DeltaConfig):
    packed = cfg.pred_mode == "packed"
    dist = _dist_of(tent, packed)
    if cfg.pred_mode == "none":
        pred = jnp.full((coo.n_nodes,), -1, jnp.int32)
    elif cfg.pred_mode == "packed":
        pred = packing.unpack_pred(tent)
        pred = jnp.where(dist < INF32, pred, -1).at[source].set(-1)
    else:
        pred = pred_argmin(dist, coo.src, coo.dst, coo.w, source,
                           n=coo.n_nodes)
    return dist, pred


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------

class DeltaSteppingSolver:
    """Preprocesses a graph once (paper's parallel preprocessing stage) and
    solves SSSP from arbitrary sources with a single jitted program."""

    def __init__(self, graph: COOGraph, config: DeltaConfig = DeltaConfig()):
        self.config = config
        self.graph = graph
        if config.pred_mode == "packed":
            _require_x64()
        if config.strategy == "ell":
            csr = coo_to_csr(graph)
            light, heavy = light_heavy_split(csr, config.delta)
            self._ell_light = csr_to_ell(light)
            self._ell_heavy = csr_to_ell(heavy)
            self._cap = config.frontier_cap or graph.n_nodes

    def solve(self, source: int) -> SSSPResult:
        cfg = self.config
        packed = cfg.pred_mode == "packed"
        src_arr = jnp.asarray(source, jnp.int32)
        if cfg.strategy == "edge":
            tent, outer, inner = _solve_edge(
                self.graph.src, self.graph.dst, self.graph.w, src_arr,
                n=self.graph.n_nodes, delta=cfg.delta, packed=packed)
            over = jnp.zeros((), bool)
        else:
            tent, outer, inner, over = _solve_ell(
                self._ell_light.nbr, self._ell_light.w,
                self._ell_heavy.nbr, self._ell_heavy.w, src_arr,
                n=self.graph.n_nodes, delta=cfg.delta, packed=packed,
                cap=self._cap)
        dist, pred = _finish_pred(tent, self.graph, src_arr, cfg)
        return SSSPResult(dist, pred, outer, inner, over)


def delta_stepping(graph: COOGraph, source: int,
                   config: DeltaConfig = DeltaConfig()) -> SSSPResult:
    """One-shot convenience wrapper around :class:`DeltaSteppingSolver`."""
    return DeltaSteppingSolver(graph, config).solve(source)
