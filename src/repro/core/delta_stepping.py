"""Single-device Δ-stepping SSSP engine (paper Alg. 1/2, DESIGN.md §2–3).

The paper's shared-memory mechanisms map onto JAX dataflow:

* dense bucket array (C1)   → ``bucket_id = tent // Δ`` recomputed by a
  full vector scan each inner iteration; the frontier is a boolean mask.
* CAS minimum loop (C2)     → ``tent.at[dst].min(candidates)`` scatter-min.
* 64-bit (cost, pred) packing (C3) → ``pred_mode='packed'`` scatter-mins
  one int64 word per vertex; ``pred_mode='argmin'`` is the 32-bit
  two-pass TPU-idiomatic equivalent (post-hoc tree recovery).
* private request sets + dup work (C4) → relaxations are emitted without
  dedup and filtered early with ``cand < tent[dst]`` before the scatter.
* read/write decoupling (C5) → gather phase and scatter phase are
  separate XLA ops by construction.

One generic outer/inner loop driver hosts every relaxation strategy via
the ``RelaxBackend`` protocol (core.backends): ``edge`` (edge-centric
|E| sweeps), ``ell`` (frontier-compacted ELL expansion) and ``pallas``
(the ELL expansion and bucket scan on the Pallas TPU kernels under
``kernels/``; game-map instances use the grid stencil kernel).

Batched multi-source solving (``DeltaSteppingSolver.solve_many``) vmaps
the driver over a batch of sources: the carried state (tent / explored /
frontier, bucket index, iteration counters) gains a leading batch axis,
the while-loops run until every lane converges, and converged lanes are
frozen by the batching rule's select — so per-source counters and
results are bitwise identical to per-source ``solve``.

Weights must be non-negative int32; ``pred_mode='argmin'`` additionally
assumes weights >= 1 (zero-weight ties could close a predecessor cycle;
``packed`` mode is safe for zero weights, see pack.py).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import pack as packing
from repro.core.backends import (
    RelaxBackend,
    dist_of as _dist_of,
    init_tent as _init_tent,
    make_backend,
)
from repro.graphs.structures import COOGraph, INF32

_IMAX = jnp.int32(2**31 - 1)


@dataclasses.dataclass(frozen=True)
class DeltaConfig:
    """Configuration of the Δ-stepping engine.

    delta        — bucket width Δ (paper's tuning parameter, Fig. 1).
    strategy     — 'edge' | 'ell' | 'pallas' | 'sharded_edge' |
                   'sharded_ell' relaxation backend (see module doc /
                   DESIGN.md §3, §9).
    pred_mode    — 'none' | 'argmin' | 'packed' predecessor tracking.
    frontier_cap — 'ell'/'pallas' only: static capacity of the compacted
                   frontier (defaults to |V|; smaller saves work if an
                   upper bound on per-bucket frontier size is known —
                   the ``overflow`` result flag reports violations).
                   For 'sharded_ell' the cap is *per shard* (defaults to
                   the owned vertex range, which cannot overflow).
    interpret    — 'pallas' only: run kernels in interpret mode (CPU).
    grid_costs   — 'pallas' on game maps: (straight, diagonal) move
                   costs of the occupancy-grid stencil (paper §4).
    n_shards     — 'sharded_*' only: width of the 1-D device mesh the
                   relaxation is partitioned over (None = every local
                   device; DESIGN.md §9).
    """

    delta: int = 10
    strategy: str = "edge"
    pred_mode: str = "argmin"
    frontier_cap: Optional[int] = None
    interpret: bool = False
    grid_costs: Tuple[int, int] = (10, 14)
    n_shards: Optional[int] = None

    def __post_init__(self):
        if self.strategy not in ("edge", "ell", "pallas",
                                 "sharded_edge", "sharded_ell"):
            raise ValueError(f"unknown strategy {self.strategy!r}")
        if self.pred_mode not in ("none", "argmin", "packed"):
            raise ValueError(f"unknown pred_mode {self.pred_mode!r}")
        if self.delta < 1:
            raise ValueError("delta must be >= 1")
        if self.n_shards is not None and self.n_shards < 1:
            raise ValueError("n_shards must be >= 1")


class SSSPResult(NamedTuple):
    """Solve result; ``solve_many`` returns the same tuple with a leading
    batch axis on every field."""

    dist: jax.Array          # int32[n], INF32 = unreachable
    pred: jax.Array          # int32[n], -1 = source/unreachable
    outer_iters: jax.Array   # int32: number of buckets processed
    inner_iters: jax.Array   # int32: total light-phase sweeps
    overflow: jax.Array      # bool: compacted frontier capacity exceeded


def _require_x64():
    if jnp.zeros((), jnp.int64).dtype != jnp.int64:
        raise RuntimeError(
            "pred_mode='packed' packs (dist, pred) into int64 and requires "
            "x64 (wrap the call in repro.compat.enable_x64())."
        )


# ---------------------------------------------------------------------------
# the unified loop driver — generic over RelaxBackend and vmap-batchable
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("n", "packed"))
def _run_one(backend: RelaxBackend, source, *, n: int, packed: bool):
    """Jitted single-source driver. Module-level so the compile cache is
    shared across ``DeltaSteppingSolver`` instances: the backend is a
    pytree *argument* whose static fields (strategy class, Δ, caps) are
    part of the cache key, so same-shaped solvers never recompile."""
    return _run_backend(backend, source, n=n, packed=packed)


@partial(jax.jit, static_argnames=("n", "packed"))
def _run_many_vmapped(backend: RelaxBackend, sources, *, n: int,
                      packed: bool):
    """Jitted batched multi-source driver (vmapped state)."""
    return jax.vmap(
        lambda s: _run_backend(backend, s, n=n, packed=packed))(sources)


@partial(jax.jit, static_argnames=("n", "packed"))
def _run_many_seq(backend: RelaxBackend, sources, *, n: int, packed: bool):
    """Batched driver for backends without a batching rule
    (``pallas_call`` with scalar-prefetch grids): in-program lax.map."""
    return lax.map(
        lambda s: _run_backend(backend, s, n=n, packed=packed), sources)


def _run_backend(backend: RelaxBackend, source, *, n: int, packed: bool):
    """Outer/inner Δ-stepping loop (paper Alg. 1) over one backend.
    Returns ``(tent, outer_iters, inner_iters, overflow)``."""
    tent0 = _init_tent(n, source, packed)
    explored0 = jnp.full((n,), INF32, jnp.int32)

    def scan(tent, explored, i):
        return backend.scan(_dist_of(tent, packed), explored, i)

    def light_phase(tent, explored, i, inner, over):
        in_s0 = jnp.zeros((n,), bool)
        f0, go0, _ = scan(tent, explored, i)

        def cond(c):
            return c[6]

        def body(c):
            tent, explored, in_s, inner, over, f, _ = c
            d = _dist_of(tent, packed)
            explored = jnp.where(f, d, explored)   # paper: move into S
            in_s = in_s | f
            tent, o = backend.sweep(tent, f, i, light=True, packed=packed)
            f, go, _ = scan(tent, explored, i)
            return (tent, explored, in_s, inner + 1, over | o, f, go)

        tent, explored, in_s, inner, over, _, _ = lax.while_loop(
            cond, body, (tent, explored, in_s0, inner, over, f0, go0))
        return tent, explored, in_s, inner, over

    def outer_body(c):
        tent, explored, i, outer, inner, over = c
        tent, explored, in_s, inner, over = light_phase(
            tent, explored, i, inner, over)
        # heavy pass from S (paper Alg. 1 lines 19-20)
        tent, o = backend.sweep(tent, in_s, i, light=False, packed=packed)
        _, _, nxt = scan(tent, explored, i)
        return (tent, explored, nxt, outer + 1, inner, over | o)

    def outer_cond(c):
        return c[2] < _IMAX

    i0 = jnp.zeros((), jnp.int32)  # relax(s, 0) puts the source in B_0
    tent, _, _, outer, inner, over = lax.while_loop(
        outer_cond, outer_body,
        (tent0, explored0, i0, jnp.zeros((), jnp.int32),
         jnp.zeros((), jnp.int32), jnp.zeros((), bool)))
    return tent, outer, inner, over


# ---------------------------------------------------------------------------
# predecessor recovery (two-pass argmin mode)
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("n",))
def pred_argmin(dist, src, dst, w, source, *, n: int):
    """Recover a shortest-path tree from converged distances: for every
    edge achieving dist[src] + w == dist[dst], scatter-min the source id.
    Deterministic (smallest-id parent wins), matching packed-mode ties."""
    d_src = jnp.take(dist, src, mode="fill", fill_value=INF32)
    d_dst = jnp.take(dist, dst, mode="fill", fill_value=INF32)
    cand = jnp.where(d_src < INF32, d_src, 0) + jnp.where(d_src < INF32, w, 0)
    ok = (d_src < INF32) & (d_dst < INF32) & (cand == d_dst)
    p = jnp.full((n,), _IMAX, jnp.int32).at[dst].min(
        jnp.where(ok, src, _IMAX), mode="drop")
    pred = jnp.where((p < _IMAX) & (dist < INF32), p, -1)
    return pred.at[source].set(-1)


def _finish_pred(tent, coo: COOGraph, source, cfg: DeltaConfig):
    packed = cfg.pred_mode == "packed"
    dist = _dist_of(tent, packed)
    if cfg.pred_mode == "none":
        pred = jnp.full((coo.n_nodes,), -1, jnp.int32)
    elif cfg.pred_mode == "packed":
        pred = packing.unpack_pred(tent)
        pred = jnp.where(dist < INF32, pred, -1).at[source].set(-1)
    else:
        pred = pred_argmin(dist, coo.src, coo.dst, coo.w, source,
                           n=coo.n_nodes)
    return dist, pred


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------

def _resolve_auto(graph, config, free_mask=None, tune_cache=None):
    """Map ``config="auto"`` to a concrete ``DeltaConfig`` via the tuning
    subsystem (lazy import: core must not depend on repro.tune at module
    load — tune builds solvers from this module)."""
    if not isinstance(config, str):
        return config
    if config != "auto":
        raise ValueError(f"unknown config string {config!r} (did you mean "
                         "'auto' or a DeltaConfig?)")
    from repro.tune import resolve_config
    # sources=None: the solver cannot know its future sources, so a
    # tuning-chosen frontier cap is dropped rather than trusted
    return resolve_config(graph, free_mask=free_mask, cache_path=tune_cache,
                          sources=None)


class DeltaSteppingSolver:
    """Preprocesses a graph once (paper's parallel preprocessing stage) and
    solves SSSP from arbitrary sources — singly (``solve``) or as a
    batched multi-source program (``solve_many``, the regime of the
    paper's betweenness-centrality citation) — with jitted programs
    shared across calls.

    ``free_mask`` (bool[H, W]) marks the game-map graph class: together
    with ``strategy='pallas'`` it routes relaxation to the grid-stencil
    kernel (DESIGN.md §3).

    ``config="auto"`` consults the tuning subsystem (DESIGN.md §7): a
    cached ``TuningRecord`` for this graph's fingerprint if one exists,
    the zero-measurement Δ estimator otherwise. ``tune_cache`` names the
    persistent cache file to consult."""

    def __init__(self, graph: COOGraph, config: DeltaConfig = DeltaConfig(),
                 *, free_mask=None, tune_cache: Optional[str] = None):
        config = _resolve_auto(graph, config, free_mask, tune_cache)
        self.config = config
        self.graph = graph
        if config.pred_mode == "packed":
            _require_x64()
        self.backend = make_backend(graph, config, free_mask=free_mask)
        packed = config.pred_mode == "packed"
        # module-level jitted drivers (the backend is a pytree argument):
        # every solver over a same-shaped graph + same static config hits
        # the same compile cache entry, across solver instances.
        self._run1 = partial(_run_one, n=graph.n_nodes, packed=packed)
        many = (_run_many_vmapped if self.backend.supports_vmap
                else _run_many_seq)
        self._run_many = partial(many, n=graph.n_nodes, packed=packed)

    def solve(self, source: int) -> SSSPResult:
        src_arr = jnp.asarray(source, jnp.int32)
        tent, outer, inner, over = self._run1(self.backend, src_arr)
        dist, pred = _finish_pred(tent, self.graph, src_arr, self.config)
        return SSSPResult(dist, pred, outer, inner, over)

    def solve_many(self, sources) -> SSSPResult:
        """Batched multi-source solve on one device. Returns an
        ``SSSPResult`` whose fields carry a leading batch axis; every
        lane is bitwise identical to the corresponding ``solve``."""
        srcs = jnp.asarray(sources, jnp.int32)
        if srcs.ndim != 1:
            raise ValueError("sources must be a 1-D array of vertex ids")
        cfg = self.config
        packed = cfg.pred_mode == "packed"
        tent, outer, inner, over = self._run_many(self.backend, srcs)
        dist = _dist_of(tent, packed)
        if cfg.pred_mode == "none":
            pred = jnp.full(dist.shape, -1, jnp.int32)
        elif packed:
            pred = packing.unpack_pred(tent)
            pred = jnp.where(dist < INF32, pred, -1)
            pred = pred.at[jnp.arange(srcs.shape[0]), srcs].set(-1)
        else:
            g = self.graph
            pred = jax.vmap(lambda d, s: pred_argmin(
                d, g.src, g.dst, g.w, s, n=g.n_nodes))(dist, srcs)
        return SSSPResult(dist, pred, outer, inner, over)


def delta_stepping(graph: COOGraph, source: int,
                   config: DeltaConfig = DeltaConfig()) -> SSSPResult:
    """One-shot convenience wrapper around :class:`DeltaSteppingSolver`.
    ``config="auto"`` picks Δ from graph statistics (DESIGN.md §7)."""
    return DeltaSteppingSolver(graph, config).solve(source)
