"""Single-device Δ-stepping SSSP engine (paper Alg. 1/2, DESIGN.md §2–3).

The paper's shared-memory mechanisms map onto JAX dataflow:

* dense bucket array (C1)   → ``bucket_id = tent // Δ`` recomputed by a
  full vector scan each inner iteration; the frontier is a boolean mask.
* CAS minimum loop (C2)     → ``tent.at[dst].min(candidates)`` scatter-min.
* 64-bit (cost, pred) packing (C3) → ``pred_mode='packed'`` scatter-mins
  one int64 word per vertex; ``pred_mode='argmin'`` is the 32-bit
  two-pass TPU-idiomatic equivalent (post-hoc tree recovery).
* private request sets + dup work (C4) → relaxations are emitted without
  dedup and filtered early with ``cand < tent[dst]`` before the scatter.
* read/write decoupling (C5) → gather phase and scatter phase are
  separate XLA ops by construction.

One generic outer/inner loop driver hosts every relaxation strategy via
the ``RelaxBackend`` protocol (core.backends): ``edge`` (edge-centric
|E| sweeps), ``ell`` (frontier-compacted ELL expansion) and ``pallas``
(the ELL expansion and bucket scan on the Pallas TPU kernels under
``kernels/``; game-map instances use the grid stencil kernel).

Batched multi-source solving (``_run_many_vmapped``) vmaps the driver
over a batch of sources: the carried state (tent / explored / frontier,
bucket index, iteration counters) gains a leading batch axis, the
while-loops run until every lane converges, and converged lanes are
frozen by the batching rule's select — so per-source counters and
results are bitwise identical to per-source single solves.

The public surface of this module is consumed through the Query/Plan
façade (``repro.api``, DESIGN.md §10): ``Plan`` partially applies the
module-level jitted drivers below, and the early-exit query kinds
(point-to-point, bounded radius) are the ``stop``-predicate variants of
the same loop. ``DeltaSteppingSolver`` survives as a deprecated shim.

Weights must be non-negative int32; ``pred_mode='argmin'`` additionally
assumes weights >= 1 (zero-weight ties could close a predecessor cycle;
``packed`` mode is safe for zero weights, see pack.py).
"""
from __future__ import annotations

import dataclasses
import warnings
from functools import partial
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import pack as packing
from repro.core.backends import (
    RelaxBackend,
    dist_of as _dist_of,
    init_tent as _init_tent,
)
from repro.core.policies import POLICIES
from repro.graphs.structures import COOGraph, INF32

_IMAX = jnp.int32(2**31 - 1)

# PointToPoint answer modes (DESIGN.md §14): the classic early exit plus
# the goal-directed landmark modes served by repro.landmarks.
P2P_MODES = ("early_exit", "alt", "bidirectional", "alt_bidirectional")

# Per-side clamp of the bidirectional meeting sums: tent values are
# clipped to 2^30 - 1 before the int32-safe f + b addition. Exact
# whenever finite point-to-point distances stay below 2^30 — a slightly
# tighter form of the engine's existing no-overflow assumption (every
# relaxation computes dist + w in int32).
_MEET_CLIP = jnp.int32(2**30 - 1)


@dataclasses.dataclass(frozen=True)
class DeltaConfig:
    """Configuration of the Δ-stepping engine.

    delta        — bucket width Δ (paper's tuning parameter, Fig. 1).
    strategy     — 'edge' | 'ell' | 'pallas' | 'fused' | 'sharded_edge'
                   | 'sharded_ell' | 'sharded_fused' relaxation backend
                   (see module doc / DESIGN.md §3, §9, §12).
    pred_mode    — 'none' | 'argmin' | 'packed' predecessor tracking.
    frontier_cap — ELL-family ('ell'/'pallas'/'fused') only: static
                   capacity of the compacted frontier (defaults to |V|;
                   smaller saves work if an upper bound on per-bucket
                   frontier size is known — the ``overflow`` result
                   flag reports violations). For 'sharded_ell' /
                   'sharded_fused' the cap is *per shard* (defaults to
                   the owned vertex range, which cannot overflow).
    interpret    — 'pallas'/'fused' only: run kernels in interpret mode
                   (CPU; for 'fused' this is also what opts the build
                   into the kernel path off-TPU — see backends
                   ``_kernel_viable``).
    grid_costs   — 'pallas' on game maps: (straight, diagonal) move
                   costs of the occupancy-grid stencil (paper §4).
    n_shards     — 'sharded_*' only: width of the 1-D device mesh the
                   relaxation is partitioned over (None = every local
                   device; DESIGN.md §9).
    p2p_mode     — default answer mode of ``PointToPoint`` queries:
                   'early_exit' (the classic settled-bucket exit),
                   'alt' (goal-directed landmark potentials),
                   'bidirectional' (forward+backward meeting rule) or
                   'alt_bidirectional' (both; repro.landmarks,
                   DESIGN.md §14). Queries can override per-call.
    policy       — frontier-selection policy (DESIGN.md §15): 'delta'
                   (the paper's bucket loop), 'rho' (ρ-stepping: pop
                   the ρ nearest pending vertices per round) or
                   'radius' (radius-stepping: per-vertex precomputed
                   step radii). Every policy runs over the same
                   relaxation backends and is bitwise-pinned to the
                   Dijkstra oracle; 'delta' keeps the classic loop
                   bit-for-bit unchanged. The grid-stencil game-map
                   path ('pallas' + free_mask) and the landmark p2p
                   modes are delta-only.
    rho          — 'rho' only: batch size ρ (None = heuristic
                   ``policies.default_rho``).
    radius_k     — 'radius' only: r(v) is the k-th smallest outgoing
                   edge weight (see policies.compute_radii).
    """

    delta: int = 10
    strategy: str = "edge"
    pred_mode: str = "argmin"
    frontier_cap: Optional[int] = None
    interpret: bool = False
    grid_costs: Tuple[int, int] = (10, 14)
    n_shards: Optional[int] = None
    p2p_mode: str = "early_exit"
    policy: str = "delta"
    rho: Optional[int] = None
    radius_k: int = 4

    def __post_init__(self):
        if self.p2p_mode not in P2P_MODES:
            raise ValueError(f"unknown p2p_mode {self.p2p_mode!r}")
        if self.strategy not in ("edge", "ell", "pallas", "fused",
                                 "sharded_edge", "sharded_ell",
                                 "sharded_fused"):
            raise ValueError(f"unknown strategy {self.strategy!r}")
        if self.pred_mode not in ("none", "argmin", "packed"):
            raise ValueError(f"unknown pred_mode {self.pred_mode!r}")
        if self.delta < 1:
            raise ValueError("delta must be >= 1")
        if self.n_shards is not None and self.n_shards < 1:
            raise ValueError("n_shards must be >= 1")
        if self.policy not in POLICIES:
            raise ValueError(f"unknown policy {self.policy!r}")
        if self.rho is not None and self.rho < 1:
            raise ValueError("rho must be >= 1")
        if self.radius_k < 1:
            raise ValueError("radius_k must be >= 1")


class SSSPResult(NamedTuple):
    """Solve result; ``solve_many`` returns the same tuple with a leading
    batch axis on every field."""

    dist: jax.Array          # int32[n], INF32 = unreachable
    pred: jax.Array          # int32[n], -1 = source/unreachable
    outer_iters: jax.Array   # int32: number of buckets processed
    inner_iters: jax.Array   # int32: total light-phase sweeps
    overflow: jax.Array      # bool: compacted frontier capacity exceeded


def _require_x64():
    if jnp.zeros((), jnp.int64).dtype != jnp.int64:
        raise RuntimeError(
            "pred_mode='packed' packs (dist, pred) into int64 and requires "
            "x64 (wrap the call in repro.compat.enable_x64())."
        )


# ---------------------------------------------------------------------------
# the unified loop driver — generic over RelaxBackend and vmap-batchable
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("n", "packed"))
def _run_one(backend: RelaxBackend, source, *, n: int, packed: bool):
    """Jitted single-source driver. Module-level so the compile cache is
    shared across ``DeltaSteppingSolver`` instances: the backend is a
    pytree *argument* whose static fields (strategy class, Δ, caps) are
    part of the cache key, so same-shaped solvers never recompile."""
    return _run_backend(backend, source, n=n, packed=packed)


@partial(jax.jit, static_argnames=("n", "packed"))
def _run_many_vmapped(backend: RelaxBackend, sources, *, n: int,
                      packed: bool):
    """Jitted batched multi-source driver (vmapped state)."""
    return jax.vmap(
        lambda s: _run_backend(backend, s, n=n, packed=packed))(sources)


@partial(jax.jit, static_argnames=("n", "packed"))
def _run_many_seq(backend: RelaxBackend, sources, *, n: int, packed: bool):
    """Batched driver for backends without a batching rule
    (``pallas_call`` with scalar-prefetch grids): in-program lax.map."""
    return lax.map(
        lambda s: _run_backend(backend, s, n=n, packed=packed), sources)


def _pending_min(d, explored):
    """Minimum tentative distance over *pending* vertices — those whose
    tent improved since their edges were last relaxed (``tent <
    explored``; an undiscovered vertex has tent == explored == INF and
    is excluded). On an all-light backend every future tent assignment
    derives from relaxing a pending vertex, so every future value is
    >= this bound — the Dijkstra priority-queue minimum, recovered from
    the Δ-stepping state."""
    return jnp.where(d < explored, d, INF32).min()


@partial(jax.jit, static_argnames=("n", "packed", "all_light"))
def _run_one_p2p(backend: RelaxBackend, source, target, *, n: int,
                 packed: bool, all_light: bool = False):
    """Jitted point-to-point driver with early exit (Kainer & Träff
    2019, DESIGN.md §10): when the outer loop advances past bucket i,
    every vertex whose tentative distance lies in a bucket <= i is
    settled — and the next-bucket scan is a global min over *all*
    finite tent values, so ``tent[target] // Δ < next_bucket`` proves
    the target's bucket was already processed and its distance is
    final. ``target`` is a traced argument (no recompile per target).

    ``all_light=True`` (the landmark ALT path, DESIGN.md §14) adds a
    mid-bucket exit: once ``tent[target] <= min pending tent``, no
    future relaxation can improve the target (weights >= 0, so every
    future value is >= the pending minimum) — essential under tight
    potentials, where the whole corridor collapses into bucket 0 and
    the between-buckets test above never gets a chance to fire. Sound
    only when every relaxed vertex has *all* its edges swept at once
    (no deferred heavy phase), hence the all-light gate."""
    delta = backend.delta

    def stop(tent, explored, nxt):
        d_t = _dist_of(tent, packed)[target]
        return (d_t < INF32) & ((d_t // delta) < nxt)

    inner_stop = None
    if all_light:
        def inner_stop(tent, explored):
            d = _dist_of(tent, packed)
            return (d[target] < INF32) & (d[target] <= _pending_min(
                d, explored))

    return _run_backend(backend, source, n=n, packed=packed, stop=stop,
                        inner_stop=inner_stop)


@partial(jax.jit, static_argnames=("n", "packed"))
def _run_one_warm(backend: RelaxBackend, tent0, explored0, *, n: int,
                  packed: bool):
    """Jitted warm-start driver (repro.dynamic, DESIGN.md §11): the same
    outer/inner bucket loop, entered with a *repaired* state instead of
    the all-INF cold one. ``tent0`` are upper-bound tent words (dist, or
    packed (dist, pred)); ``explored0`` marks the tent value each vertex
    last relaxed its edges at (its old settled distance), so exactly the
    vertices whose tent was improved or reset by the repair satisfy
    ``tent < explored`` and re-enter their buckets — Δ-stepping's own
    frontier rule bounds the re-relaxation to the repair cone, and the
    unsettled-only next-bucket scan skips every bucket the repair never
    touched."""
    return _run_backend(backend, None, n=n, packed=packed,
                        init=(tent0, explored0))


@partial(jax.jit, static_argnames=("n", "packed", "all_light"))
def _run_one_bidir(backend: RelaxBackend, tent0, explored0, *, n: int,
                   packed: bool, all_light: bool = False):
    """Jitted bidirectional point-to-point driver (repro.landmarks,
    DESIGN.md §14). ``backend`` relaxes the disjoint union of the graph
    with its reversed copy (``graphs.union_with_reverse``; ``n`` is the
    union size ``2 * half``); ``tent0`` seeds *two* searches through the
    warm-init hook — the source in the forward half and the target's
    twin in the reversed half — so one lockstep bucket loop advances
    both searches.

    Meeting rule, layered on the ``_run_backend`` stop hooks: let
    μ = min_v (tent[v] + tent[v + half]) over the half vertices — an
    upper bound on dist(s, t) that becomes exact once some vertex on a
    shortest path has exact tents on both sides.

    * Generic backends stop between buckets when 2·nxt·Δ >= μ: every
      unsettled vertex of either half has tent >= nxt·Δ, so a
      hypothetical shorter path would need a vertex settled forward
      whose successor y has forward distance >= nxt·Δ, forcing y's
      backward distance below nxt·Δ — i.e. y settled backward and μ
      already counts the shorter sum.
    * ``all_light=True`` (the landmark path) sharpens this to the
      classic bidirectional-Dijkstra rule μ <= L_f + L_b, where L_f/L_b
      are the per-half *pending* minimums, checked mid-bucket too —
      under tight ALT potentials both searches live entirely in bucket
      0 and the bucket-granular test above never fires before the full
      closure. Sound because all-light relaxation sweeps every edge of
      a vertex the moment it leaves the pending set (DESIGN.md §14 has
      the full argument).

    The returned tent carries both half solutions; the caller extracts
    μ = dist(s, t) and the meeting vertex host-side."""
    half = n // 2
    delta = backend.delta

    def _mu(d):
        f, b = d[:half], d[half:]
        fin = (f < INF32) & (b < INF32)
        sums = jnp.where(
            fin, jnp.minimum(f, _MEET_CLIP) + jnp.minimum(b, _MEET_CLIP),
            INF32)
        return sums.min()

    inner_stop = None
    if all_light:
        def meet(tent, explored):
            d = _dist_of(tent, packed)
            lf = _pending_min(d[:half], explored[:half])
            lb = _pending_min(d[half:], explored[half:])
            # clamping only lowers the threshold (never a premature
            # stop); an exhausted side (L = INF -> clip) is genuinely
            # final — its half of every sum is exact
            bound = (jnp.minimum(lf, _MEET_CLIP)
                     + jnp.minimum(lb, _MEET_CLIP))
            mu = _mu(d)
            return (mu < INF32) & (mu <= bound)

        stop = lambda tent, explored, nxt: meet(tent, explored)  # noqa: E731
        inner_stop = meet
    else:
        def stop(tent, explored, nxt):
            mu = _mu(_dist_of(tent, packed))
            # 2·nxt·Δ <= 2·max finite tent < 2^32: an int32 wrap can
            # only go negative, which keeps the loop running (still
            # exact, just no early exit) — never a premature stop
            return (mu < INF32) & (2 * nxt * delta >= mu)

    return _run_backend(backend, None, n=n, packed=packed, stop=stop,
                        init=(tent0, explored0), inner_stop=inner_stop)


@partial(jax.jit, static_argnames=("n", "packed"))
def _run_one_bounded(backend: RelaxBackend, source, radius, *, n: int,
                     packed: bool):
    """Jitted bounded-radius driver: stop at the first bucket past
    ``radius // Δ`` — every vertex with true distance <= radius lives
    in a bucket <= radius // Δ and is settled by then; tent values
    beyond are upper bounds, not answers (the caller filters them)."""
    delta = backend.delta

    def stop(tent, explored, nxt):
        return nxt > radius // delta

    return _run_backend(backend, source, n=n, packed=packed, stop=stop)


def _run_backend(backend: RelaxBackend, source, *, n: int, packed: bool,
                 stop=None, init=None, inner_stop=None):
    """Outer/inner Δ-stepping loop (paper Alg. 1) over one backend.
    Returns ``(tent, outer_iters, inner_iters, overflow)``. ``stop``
    (trace-time constant) is an optional early-exit predicate
    ``(tent, explored, next_bucket) -> bool`` checked between buckets —
    the hook the point-to-point and bounded-radius drivers hang off;
    ``inner_stop`` is a ``(tent, explored) -> bool`` predicate checked
    before every light sweep for exits that must fire *inside* a
    bucket's closure (the all-light landmark drivers; its soundness
    burden is the caller's). ``None`` for both keeps the full-solve
    loop bit-for-bit unchanged. ``init`` is an optional warm
    ``(tent0, explored0)`` state (the repro.dynamic repair path,
    DESIGN.md §11); ``None`` is the cold all-INF start."""
    if init is None:
        tent0 = _init_tent(n, source, packed)
        explored0 = jnp.full((n,), INF32, jnp.int32)
    else:
        tent0, explored0 = init

    def scan(tent, explored, i):
        return backend.scan(_dist_of(tent, packed), explored, i)

    def light_phase(tent, explored, i, inner, over):
        in_s0 = jnp.zeros((n,), bool)
        f0, go0, _ = scan(tent, explored, i)

        def cond(c):
            go = c[6]
            if inner_stop is not None:
                go = go & jnp.logical_not(inner_stop(c[0], c[1]))
            return go

        def body(c):
            tent, explored, in_s, inner, over, f, _ = c
            d = _dist_of(tent, packed)
            explored = jnp.where(f, d, explored)   # paper: move into S
            in_s = in_s | f
            tent, o = backend.sweep(tent, f, i, light=True, packed=packed)
            f, go, _ = scan(tent, explored, i)
            return (tent, explored, in_s, inner + 1, over | o, f, go)

        tent, explored, in_s, inner, over, _, _ = lax.while_loop(
            cond, body, (tent, explored, in_s0, inner, over, f0, go0))
        return tent, explored, in_s, inner, over

    # fused light phase (DESIGN.md §12): backends implementing the
    # fused protocol run scan + compaction + row gather as ONE step
    # (``fused_iter``), so the loop needs no full-width frontier mask in
    # its carry at all. The classic loop primes with a scan and re-scans
    # inside the body; here each iteration is scan-then-relax *atomic*,
    # which appends exactly one vacuous trailing iteration (the scan
    # that finds the bucket empty; all its updates are sentinel no-ops).
    # Counting ``inner += any`` instead of ``inner += 1`` makes the
    # counters — and the whole state trajectory — bitwise those of the
    # classic loop (same op sequence on the same states).
    fused = getattr(backend, "supports_fused_light", False)

    def light_phase_fused(tent, explored, i, inner, over):
        in_s0 = jnp.zeros((n,), bool)

        def cond(c):
            go = c[5]
            if inner_stop is not None:
                go = go & jnp.logical_not(inner_stop(c[0], c[1]))
            return go

        def body(c):
            tent, explored, in_s, inner, over, _ = c
            tent, explored, in_s, any_, o = backend.fused_iter(
                tent, explored, in_s, i, packed=packed)
            return (tent, explored, in_s, inner + any_.astype(jnp.int32),
                    over | o, any_)

        tent, explored, in_s, inner, over, _ = lax.while_loop(
            cond, body,
            (tent, explored, in_s0, inner, over, jnp.ones((), bool)))
        return tent, explored, in_s, inner, over

    def outer_body(c):
        tent, explored, i, outer, inner, over = c
        phase = light_phase_fused if fused else light_phase
        tent, explored, in_s, inner, over = phase(
            tent, explored, i, inner, over)
        # heavy pass from S (paper Alg. 1 lines 19-20)
        tent, o = backend.sweep(tent, in_s, i, light=False, packed=packed)
        if fused:
            nxt = backend.fused_next(_dist_of(tent, packed), explored, i)
        else:
            _, _, nxt = scan(tent, explored, i)
        return (tent, explored, nxt, outer + 1, inner, over | o)

    def outer_cond(c):
        go = c[2] < _IMAX
        if stop is not None:
            go = go & jnp.logical_not(stop(c[0], c[1], c[2]))
        return go

    i0 = jnp.zeros((), jnp.int32)  # relax(s, 0) puts the source in B_0
    tent, _, _, outer, inner, over = lax.while_loop(
        outer_cond, outer_body,
        (tent0, explored0, i0, jnp.zeros((), jnp.int32),
         jnp.zeros((), jnp.int32), jnp.zeros((), bool)))
    return tent, outer, inner, over


# ---------------------------------------------------------------------------
# the generic frontier-policy loop (DESIGN.md §15) — rho / radius stepping
# over the very same relaxation backends
# ---------------------------------------------------------------------------

def _run_policy(backend: RelaxBackend, policy, source, *, n: int,
                packed: bool, stop=None, init=None):
    """Round loop generic over a :mod:`repro.core.policies` policy.
    Each round: compute the policy threshold θ from the pending state,
    step the value-closed frontier ``pending & (tent <= θ)`` — mark it
    explored, then sweep its **full** edge set through the unchanged
    backend (light phase then heavy phase; the phase split is Δ-bucket
    machinery the policies reuse purely as an edge partition). Closure
    policies (radius) re-step under the same θ until nothing pending
    remains at or below it.

    Correctness is policy-independent: any round that relaxes all edges
    of a non-empty pending subset permanently settles at least the
    pending-minimum vertex (θ >= the pending minimum for every policy,
    and that vertex's tent is final by the Dijkstra argument), so the
    loop reaches the unique distance fixpoint in <= |V| rounds. Because
    no heavy work is ever deferred across rounds, every future tent
    assignment derives from a currently-pending vertex — which makes
    ``_pending_min`` a sound stop bound here for *every* policy (the
    p2p / bounded drivers below), where the bucket loop needs its
    all-light gate.

    Telemetry: ``outer`` counts policy rounds (the analogue of buckets
    processed), ``inner`` counts relaxation iterations (sweep pairs) —
    same counters, same meanings, comparable across policies.

    ``stop`` is an optional ``(tent, explored) -> bool`` early-exit
    predicate checked between rounds; ``init`` is the warm
    ``(tent0, explored0)`` state (repro.dynamic). The pending rule —
    not anything bucket- or policy-shaped — drives selection, which is
    exactly why the repair path is policy-agnostic (DESIGN.md §15)."""
    if init is None:
        tent0 = _init_tent(n, source, packed)
        explored0 = jnp.full((n,), INF32, jnp.int32)
    else:
        tent0, explored0 = init

    zero_i = jnp.zeros((), jnp.int32)  # dummy bucket id: only the grid
    # stencil backend reads it, and grid plans are delta-only (rejected
    # at Plan construction)

    def step(tent, explored, theta, inner, over):
        d = _dist_of(tent, packed)
        f = (d < explored) & (d <= theta)
        explored = jnp.where(f, d, explored)
        tent, o1 = backend.sweep(tent, f, zero_i, light=True, packed=packed)
        tent, o2 = backend.sweep(tent, f, zero_i, light=False, packed=packed)
        return tent, explored, inner + 1, over | o1 | o2

    if policy.closure:
        def round_body(c):
            tent, explored, outer, inner, over = c
            theta = policy.threshold(_dist_of(tent, packed), explored)

            def icond(ic):
                d = _dist_of(ic[0], packed)
                return ((d < ic[1]) & (d <= theta)).any()

            def ibody(ic):
                return step(ic[0], ic[1], theta, ic[2], ic[3])

            tent, explored, inner, over = lax.while_loop(
                icond, ibody, (tent, explored, inner, over))
            return (tent, explored, outer + 1, inner, over)
    else:
        def round_body(c):
            tent, explored, outer, inner, over = c
            theta = policy.threshold(_dist_of(tent, packed), explored)
            tent, explored, inner, over = step(
                tent, explored, theta, inner, over)
            return (tent, explored, outer + 1, inner, over)

    def round_cond(c):
        d = _dist_of(c[0], packed)
        go = (d < c[1]).any()
        if stop is not None:
            go = go & jnp.logical_not(stop(c[0], c[1]))
        return go

    tent, _, outer, inner, over = lax.while_loop(
        round_cond, round_body,
        (tent0, explored0, jnp.zeros((), jnp.int32),
         jnp.zeros((), jnp.int32), jnp.zeros((), bool)))
    return tent, outer, inner, over


@partial(jax.jit, static_argnames=("n", "packed"))
def _run_policy_one(backend: RelaxBackend, source, *, policy, n: int,
                    packed: bool):
    """Jitted single-source policy driver (the non-delta twin of
    ``_run_one``; the policy is a pytree argument, so its static shape
    — ρ, the policy class — keys the compile cache while radius leaves
    swap freely)."""
    return _run_policy(backend, policy, source, n=n, packed=packed)


@partial(jax.jit, static_argnames=("n", "packed"))
def _run_policy_many_vmapped(backend: RelaxBackend, sources, *, policy,
                             n: int, packed: bool):
    """Jitted batched policy driver (vmapped lanes, bitwise equal to
    per-source single solves — same argument as ``_run_many_vmapped``)."""
    return jax.vmap(lambda s: _run_policy(
        backend, policy, s, n=n, packed=packed))(sources)


@partial(jax.jit, static_argnames=("n", "packed"))
def _run_policy_many_seq(backend: RelaxBackend, sources, *, policy,
                         n: int, packed: bool):
    """Batched policy driver for backends without a batching rule."""
    return lax.map(lambda s: _run_policy(
        backend, policy, s, n=n, packed=packed), sources)


@partial(jax.jit, static_argnames=("n", "packed"))
def _run_policy_p2p(backend: RelaxBackend, source, target, *, policy,
                    n: int, packed: bool):
    """Point-to-point early exit under a policy loop: stop once
    ``tent[target] <= min pending tent`` — sound for every policy
    because each policy round sweeps the full edge set of what it
    relaxes (see ``_run_policy``), so all future values are >= the
    pending minimum. This is the policy-loop analogue of the bucket
    driver's ``all_light`` mid-bucket exit."""
    def stop(tent, explored):
        d = _dist_of(tent, packed)
        return (d[target] < INF32) & (d[target] <= _pending_min(d, explored))

    return _run_policy(backend, policy, source, n=n, packed=packed,
                       stop=stop)


@partial(jax.jit, static_argnames=("n", "packed"))
def _run_policy_bounded(backend: RelaxBackend, source, radius, *, policy,
                        n: int, packed: bool):
    """Bounded-radius policy driver: stop once the pending minimum
    exceeds ``radius`` — from then on every future assignment is
    > radius, so all tent values <= radius are final and anything
    beyond is an upper bound the caller filters (same contract as the
    bucket driver's past-the-bucket stop)."""
    def stop(tent, explored):
        return _pending_min(_dist_of(tent, packed), explored) > radius

    return _run_policy(backend, policy, source, n=n, packed=packed,
                       stop=stop)


@partial(jax.jit, static_argnames=("n", "packed"))
def _run_policy_warm(backend: RelaxBackend, tent0, explored0, *, policy,
                     n: int, packed: bool):
    """Warm-start policy driver (repro.dynamic, DESIGN.md §11/§15): the
    policy round loop entered with the repaired state. The repair
    machinery is untouched — it only manufactures ``tent < explored``
    on the repair cone, and the pending rule is what every policy
    selects from, so warm == cold holds per policy by the same unique
    -fixpoint argument as for Δ-stepping."""
    return _run_policy(backend, policy, None, n=n, packed=packed,
                       init=(tent0, explored0))


# ---------------------------------------------------------------------------
# predecessor recovery (two-pass argmin mode)
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("n",))
def pred_argmin(dist, src, dst, w, source, *, n: int):
    """Recover a shortest-path tree from converged distances: for every
    edge achieving dist[src] + w == dist[dst], scatter-min the source id.
    Deterministic (smallest-id parent wins), matching packed-mode ties."""
    d_src = jnp.take(dist, src, mode="fill", fill_value=INF32)
    d_dst = jnp.take(dist, dst, mode="fill", fill_value=INF32)
    cand = jnp.where(d_src < INF32, d_src, 0) + jnp.where(d_src < INF32, w, 0)
    ok = (d_src < INF32) & (d_dst < INF32) & (cand == d_dst)
    p = jnp.full((n,), _IMAX, jnp.int32).at[dst].min(
        jnp.where(ok, src, _IMAX), mode="drop")
    pred = jnp.where((p < _IMAX) & (dist < INF32), p, -1)
    return pred.at[source].set(-1)


def _finish_pred(tent, coo: COOGraph, source, cfg: DeltaConfig):
    packed = cfg.pred_mode == "packed"
    dist = _dist_of(tent, packed)
    if cfg.pred_mode == "none":
        pred = jnp.full((coo.n_nodes,), -1, jnp.int32)
    elif cfg.pred_mode == "packed":
        pred = packing.unpack_pred(tent)
        pred = jnp.where(dist < INF32, pred, -1).at[source].set(-1)
    else:
        pred = pred_argmin(dist, coo.src, coo.dst, coo.w, source,
                           n=coo.n_nodes)
    return dist, pred


def _finish_pred_many(tent, coo: COOGraph, srcs, cfg: DeltaConfig):
    """Batched twin of :func:`_finish_pred` (leading batch axis on
    ``tent``/``srcs``); shared by the façade's MultiSource dispatch and
    the deprecated ``solve_many`` shim so the two stay bitwise equal."""
    packed = cfg.pred_mode == "packed"
    dist = _dist_of(tent, packed)
    if cfg.pred_mode == "none":
        pred = jnp.full(dist.shape, -1, jnp.int32)
    elif packed:
        pred = packing.unpack_pred(tent)
        pred = jnp.where(dist < INF32, pred, -1)
        pred = pred.at[jnp.arange(srcs.shape[0]), srcs].set(-1)
    else:
        pred = jax.vmap(lambda d, s: pred_argmin(
            d, coo.src, coo.dst, coo.w, s, n=coo.n_nodes))(dist, srcs)
    return dist, pred


# ---------------------------------------------------------------------------
# public API — deprecated shims over the Query/Plan façade (repro.api)
# ---------------------------------------------------------------------------

class DeltaSteppingSolver:
    """**Deprecated** thin shim over the Query/Plan façade — prefer
    ``repro.api.Engine(graph, config).plan()`` (DESIGN.md §10).

    Kept with its original signature under a parity contract: ``solve``
    and ``solve_many`` delegate to ``Plan.solve(SingleSource(...))`` /
    ``Plan.solve(MultiSource(...))``, which run the very same module-
    level jitted drivers and finishers this class used to own, so dist
    and pred (including packed (cost, pred) words) are bitwise
    identical to the pre-façade solver on every backend
    (tests/test_api_queries.py pins this).

    ``free_mask`` (bool[H, W]) marks the game-map graph class: together
    with ``strategy='pallas'`` it routes relaxation to the grid-stencil
    kernel (DESIGN.md §3). ``config="auto"`` consults the tuning
    subsystem (DESIGN.md §7); ``tune_cache`` names the persistent cache
    file to consult."""

    def __init__(self, graph: COOGraph, config: DeltaConfig = DeltaConfig(),
                 *, free_mask=None, tune_cache: Optional[str] = None):
        warnings.warn(
            "DeltaSteppingSolver is deprecated: use repro.api.Engine("
            "graph, config).plan() and the query algebra (DESIGN.md §10)",
            DeprecationWarning, stacklevel=2)
        from repro.api import Engine, Tuning  # lazy: api builds on this
        # legacy semantics, preserved exactly: tune_cache is consulted
        # for config="auto" only — a concrete config a caller pinned is
        # never overwritten by a cached record (Engine would treat it as
        # a tuning base; the old _resolve_auto did not). sources=None:
        # the solver cannot know its future sources, so a tuning-chosen
        # frontier cap is dropped rather than trusted.
        if isinstance(config, str):
            if config != "auto":
                raise ValueError(f"config must be 'auto', got {config!r}")
            engine = Engine(graph, None, free_mask=free_mask,
                            tuning=Tuning(cache=tune_cache))
        else:
            engine = Engine(graph, config, free_mask=free_mask)
        self._plan = engine.plan(sources=None)
        self.config = self._plan.config
        self.graph = graph
        self.backend = self._plan.backend

    @property
    def plan(self):
        """The underlying ``repro.api.Plan`` (the migration path)."""
        return self._plan

    def solve(self, source: int) -> SSSPResult:
        from repro.api import SingleSource
        r = self._plan.solve(SingleSource(source))
        t = r.telemetry
        return SSSPResult(r.dist, r.pred, t.buckets, t.inner_iters,
                          t.overflow)

    def solve_many(self, sources) -> SSSPResult:
        """Batched multi-source solve on one device. Returns an
        ``SSSPResult`` whose fields carry a leading batch axis; every
        lane is bitwise identical to the corresponding ``solve``."""
        from repro.api import MultiSource
        r = self._plan.solve(MultiSource(sources))
        t = r.telemetry
        return SSSPResult(r.dist, r.pred, t.buckets, t.inner_iters,
                          t.overflow)


def delta_stepping(graph: COOGraph, source: int,
                   config: DeltaConfig = DeltaConfig()) -> SSSPResult:
    """**Deprecated** one-shot convenience wrapper (prefer
    ``repro.api.Engine(graph, config).plan().solve(SingleSource(s))``).
    ``config="auto"`` picks Δ from graph statistics (DESIGN.md §7)."""
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        solver = DeltaSteppingSolver(graph, config)
    warnings.warn(
        "delta_stepping is deprecated: use repro.api.Engine(graph, config)"
        ".plan().solve(SingleSource(source)) (DESIGN.md §10)",
        DeprecationWarning, stacklevel=2)
    return solver.solve(source)
