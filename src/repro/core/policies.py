"""Frontier-selection policies — "which pending vertices step this
round" as a pluggable axis (DESIGN.md §15).

Δ-stepping's bucket loop is one answer to a more general question: each
round, pick a non-empty subset of the *pending* vertices (``tent <
explored``), mark it explored, and relax **all** of its edges. Any such
policy converges to the exact SSSP fixpoint — the pending rule alone
carries correctness (weights >= 0, each round permanently settles at
least the pending-minimum vertex, so the loop terminates in <= |V|
rounds at the unique distance fixpoint). The policy only shapes the
*round structure*: how much parallel work each step exposes versus how
much of it is wasted on non-final relaxations.

Three members of the family (the Dong et al. 2021 stepping-framework
view, arXiv:2105.06145):

* ``delta``  — the paper's bucket loop, unchanged: handled by the
  classic ``_run_backend`` driver (light/heavy split, fused kernels,
  bucket telemetry all bit-for-bit identical to before this module
  existed). :class:`DeltaPolicy` is a routing marker, never stepped.
* ``rho``    — ρ-stepping (Dong/Gu/Sun/Zhang): step = pop the ρ nearest
  pending vertices. The round threshold is the ρ-th smallest pending
  tent; the batch is *value-closed* (every pending vertex at or below
  the threshold steps, so ties never split and results stay independent
  of vertex order). ρ=1 degenerates to Dijkstra-by-distance-class, ρ=n
  to Bellman-Ford over the pending set.
* ``radius`` — radius-stepping (Blelloch et al., arXiv:1602.03881):
  each vertex carries a precomputed step radius r(v); a round picks
  threshold θ = min over pending of (tent(v) + r(v)) and runs the inner
  closure until no pending vertex remains at or below θ. Correctness
  never depends on r — any r >= 0 yields exact distances (θ >= the
  pending minimum, so the round-settles-the-min-class argument above
  still holds); r only trades round count against wasted relaxations.

The policies share every relaxation backend unchanged: a policy round
sweeps its frontier through ``backend.sweep`` twice (light then heavy
phase), which together cover the vertex's full edge set — the no
-deferred-heavy discipline that makes the pending-minimum stop bounds
of the point-to-point and bounded-radius drivers sound for every
policy (see ``delta_stepping._run_policy``).

``compute_radii`` is a deliberate deviation from Blelloch et al.: the
paper computes r(v) from exact k-nearest-ball distances (a Dijkstra per
vertex); here r(v) is the k-th smallest *outgoing edge weight* — an
O(m log m) host-side surrogate honoring the same intent (vertices with
many cheap edges step further) with the identical correctness story
(any r >= 0 is exact). Radii are persisted beside the tuner cache via
:class:`RadiiStore` (same npz idiom as ``landmarks.store``: content
-hashed key, atomic replace, corrupt file == miss).
"""
from __future__ import annotations

import dataclasses
import hashlib
import os
import tempfile
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.graphs.structures import COOGraph, INF32

POLICIES = ("delta", "rho", "radius")


# ---------------------------------------------------------------------------
# the policy pytrees
# ---------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class DeltaPolicy:
    """Marker for the classic Δ-stepping bucket loop. Plans with this
    policy bind the original ``_run_backend`` drivers (bitwise unchanged
    — including the fused light-phase protocol and bucket telemetry);
    the generic policy loop never sees it."""

    name = "delta"
    closure = False

    def threshold(self, d, explored):  # pragma: no cover - never stepped
        raise NotImplementedError("DeltaPolicy routes to the bucket loop")


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class RhoPolicy:
    """ρ-stepping: the round threshold is the ρ-th smallest pending
    tent (INF when fewer than ρ vertices are pending — then the whole
    pending set steps). ρ is static: it shapes the compiled program's
    cache key, exactly like Δ does for the bucket loop."""

    rho: int = dataclasses.field(metadata=dict(static=True))

    name = "rho"
    closure = False

    def threshold(self, d, explored):
        pend = jnp.where(d < explored, d, INF32)
        k = min(int(self.rho), int(d.shape[0]))
        return jnp.sort(pend)[k - 1]


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class RadiusPolicy:
    """Radius-stepping: θ = min over pending of (tent(v) + r(v)), inner
    closure drains everything at or below θ. ``r`` is a *leaf* (not
    static): weight updates on a dynamic plan swap in fresh radii
    without retracing the driver."""

    r: jax.Array  # int32[n] per-vertex step radii, >= 0

    name = "radius"
    closure = True

    def threshold(self, d, explored):
        pend = d < explored
        # non-pending lanes may wrap (INF + r) — masked out before the min
        reach = jnp.where(pend, d + self.r, INF32)
        return reach.min()


# ---------------------------------------------------------------------------
# radius preprocessing + persistence
# ---------------------------------------------------------------------------

def compute_radii(graph: COOGraph, k: int) -> np.ndarray:
    """Per-vertex step radii for radius-stepping: r(v) = k-th smallest
    outgoing edge weight (largest outgoing weight when deg(v) < k; 0 for
    isolated vertices). Host-side numpy, O(m log m). Any r >= 0 keeps
    the policy exact (see module doc), so the surrogate is free to be
    cheap."""
    if k < 1:
        raise ValueError("radius_k must be >= 1")
    n = graph.n_nodes
    src = np.asarray(graph.src)
    w = np.asarray(graph.w)
    r = np.zeros((n,), np.int32)
    if src.size == 0:
        return r
    order = np.lexsort((w, src))
    ws = w[order]
    deg = np.bincount(src, minlength=n)
    starts = np.zeros((n,), np.int64)
    starts[1:] = np.cumsum(deg)[:-1]
    has = deg > 0
    idx = starts + np.minimum(k - 1, np.maximum(deg - 1, 0))
    r[has] = ws[idx[has]]
    return r.astype(np.int32)


def graph_weight_hash(graph: COOGraph) -> str:
    """Content hash of (src, dst, w, n) — the exact identity radii
    depend on (unlike the tuner's structural fingerprint, which is
    deliberately coarse)."""
    h = hashlib.sha1()
    for a in (graph.src, graph.dst, graph.w):
        h.update(np.ascontiguousarray(np.asarray(a, np.int64)).tobytes())
    h.update(str(int(graph.n_nodes)).encode())
    return h.hexdigest()


class RadiiStore:
    """Persistent per-graph radii, stored beside the tuner cache (the
    façade derives the directory from ``Tuning.cache``). One ``.npz``
    per (graph content hash, k), atomically replaced; unreadable or
    mismatched files are misses, never errors. ``path=None`` keeps an
    in-memory store (tests, ephemeral graphs)."""

    def __init__(self, path: Optional[str] = None):
        self.path = path
        self._mem: dict = {}
        if path is not None:
            os.makedirs(path, exist_ok=True)

    def _key(self, whash: str, k: int) -> str:
        return hashlib.sha1(f"{whash}|k={int(k)}".encode()).hexdigest()

    def _file(self, key: str) -> str:
        return os.path.join(self.path, f"radii_{key}.npz")

    def get(self, graph: COOGraph, k: int) -> Optional[np.ndarray]:
        whash = graph_weight_hash(graph)
        key = self._key(whash, k)
        if key in self._mem:
            return self._mem[key]
        if self.path is None:
            return None
        try:
            with np.load(self._file(key), allow_pickle=False) as z:
                if (str(z["whash"]) != whash or int(z["k"]) != int(k)
                        or int(z["n"]) != int(graph.n_nodes)):
                    return None
                r = np.asarray(z["r"], np.int32)
        except (OSError, KeyError, ValueError):
            return None
        if r.shape != (graph.n_nodes,):
            return None
        self._mem[key] = r
        return r

    def put(self, graph: COOGraph, k: int, r: np.ndarray) -> None:
        whash = graph_weight_hash(graph)
        key = self._key(whash, k)
        r = np.asarray(r, np.int32)
        self._mem[key] = r
        if self.path is None:
            return
        fd, tmp = tempfile.mkstemp(dir=self.path, suffix=".tmp")
        try:
            with os.fdopen(fd, "wb") as f:
                np.savez(f, r=r, whash=np.str_(whash),
                         k=np.int64(k), n=np.int64(graph.n_nodes))
            os.replace(tmp, self._file(key))
        except OSError:
            try:
                os.unlink(tmp)
            except OSError:
                pass


# ---------------------------------------------------------------------------
# construction
# ---------------------------------------------------------------------------

def default_rho(n: int) -> int:
    """Batch-size heuristic when ``DeltaConfig.rho`` is unset: large
    batches (Dong et al. run ρ in the 2^15..2^21 range on million-vertex
    graphs); clipped so tiny graphs still form multi-vertex rounds."""
    return max(32, n // 8)


def make_policy(graph: COOGraph, cfg, store: Optional[RadiiStore] = None):
    """Build the frontier policy named by ``cfg.policy`` for ``graph``.
    ``store`` (optional) persists/reuses radius preprocessing."""
    if cfg.policy == "delta":
        return DeltaPolicy()
    if cfg.policy == "rho":
        rho = cfg.rho if cfg.rho is not None else default_rho(graph.n_nodes)
        return RhoPolicy(rho=int(rho))
    if cfg.policy == "radius":
        r = store.get(graph, cfg.radius_k) if store is not None else None
        if r is None:
            r = compute_radii(graph, cfg.radius_k)
            if store is not None:
                store.put(graph, cfg.radius_k, r)
        return RadiusPolicy(r=jnp.asarray(r, jnp.int32))
    raise ValueError(f"unknown policy {cfg.policy!r}")
