"""Distributed Δ-stepping over a device mesh (DESIGN.md §4).

The paper's OpenMP parallelization maps to SPMD:

* OpenMP static scheduling of bucket entries  → static 1-D vertex
  partition over the ``model`` mesh axis (``graphs.partition``); each
  device owns a vertex range and all outgoing edges of that range.
* shared ``tent`` array + CAS               → per-device relaxation into
  a local candidate buffer + a cross-device **min-combine** collective.
* the paper's removal of the omp barrier in the light phase (trading
  synchronization for redundant relaxations) → ``local_steps > 1``:
  devices run k local light sweeps between collectives.

The per-shard compute is built from the same primitives as the
single-device backends (``core.backends``): ``edge_candidates`` for
request generation and ``scan_bucket`` for the fused frontier /
any-frontier / next-bucket scan, with collectives layered on top
(pmax of the any-flag, pmin of the next bucket).

Two combine schedules (the §Perf hillclimb axis):

* ``allreduce``      — tent replicated on every device; one
  ``all-reduce(min)`` of |V| words per sweep. Simple; collective volume
  2·(P-1)/P·|V| words per device.
* ``reduce_scatter`` — tent sharded; relaxations go into a full-size
  candidate buffer which is min-combined with an ``all_to_all``
  (reduce-scatter-min has no primitive, so we transpose-and-reduce),
  volume (P-1)/P·|V| words — half the bytes, and tent memory drops to
  |V|/P per device.

Independent SSSP sources are batched over the ``data`` (× ``pod``) axes —
the multi-source regime of the paper's betweenness-centrality citation
(single-device batching without a mesh is
``DeltaSteppingSolver.solve_many``).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map
from repro.core.backends import edge_candidates, scan_bucket
from repro.graphs.partition import VertexPartition
from repro.graphs.structures import INF32

_IMAX = jnp.int32(2**31 - 1)


@dataclasses.dataclass(frozen=True)
class DistDeltaConfig:
    """delta        — bucket width Δ.
    combine      — 'allreduce' | 'reduce_scatter' min-combine schedule.
    local_steps  — local light sweeps between collectives (>=1); the
                   paper's barrier-removal trade (§4 'Delta').
    model_axis   — mesh axis name sharding the vertex set.
    batch_axes   — mesh axes sharding independent SSSP sources.
    """

    delta: int = 10
    combine: str = "allreduce"
    local_steps: int = 1
    model_axis: str = "model"
    batch_axes: tuple = ("data",)

    def __post_init__(self):
        if self.combine not in ("allreduce", "reduce_scatter"):
            raise ValueError(f"unknown combine {self.combine!r}")
        if self.local_steps < 1:
            raise ValueError("local_steps must be >= 1")


def _local_sweep(tent_full_dist, frontier_flags_of_src, src_off, dst, w,
                 buf, *, delta: int, light: bool):
    """Relax this device's edge shard into ``buf`` (full-size candidate
    buffer, int32[N_pad]). ``tent_full_dist`` provides gather distances for
    edge sources via *local* indices ``src_off`` (padding rows gather INF
    through fill)."""
    d_src = jnp.take(tent_full_dist, src_off, mode="fill", fill_value=INF32)
    cand, ok = edge_candidates(d_src, frontier_flags_of_src, w,
                               delta=delta, light=light)
    return buf.at[dst].min(jnp.where(ok, cand, INF32), mode="drop")


def build_solver_from_meta(*, n_nodes: int, shard_nodes: int, mesh: Mesh,
                           cfg: DistDeltaConfig = DistDeltaConfig()):
    """Builds the jitted SPMD solve function from *static* metadata only —
    the partition arrays are runtime arguments, so the multi-pod dry-run
    can lower it against ShapeDtypeStructs without materializing a graph.

    Returns ``solve(sources, src, dst, w, vstart) ->
    (dist int32[B, n], outer, inner)``.
    """
    P_model = mesh.shape[cfg.model_axis]
    n = n_nodes
    s_nodes = shard_nodes
    n_pad = P_model * s_nodes
    delta = cfg.delta
    batch_spec = P(cfg.batch_axes)
    ax = cfg.model_axis

    def combine_min(buf, tent_loc):
        """buf int32[n_pad] of local candidates → merge into tent_loc."""
        if cfg.combine == "allreduce":
            merged = lax.pmin(buf, ax)
            my = lax.axis_index(ax)
            piece = lax.dynamic_slice_in_dim(merged, my * s_nodes, s_nodes)
            return jnp.minimum(tent_loc, piece)
        # reduce-scatter-min via all_to_all + local reduce
        stacked = buf.reshape(P_model, s_nodes)
        swapped = lax.all_to_all(stacked, ax, split_axis=0, concat_axis=0,
                                 tiled=False)
        piece = swapped.min(axis=0)
        return jnp.minimum(tent_loc, piece)

    def solve_one(source, src_e, dst_e, w_e, vstart):
        """Single-source solve; runs inside shard_map, collectives over
        the model axis. All per-vertex state is the local shard slice."""
        my = lax.axis_index(ax)
        base = my * s_nodes
        lidx = jnp.arange(s_nodes, dtype=jnp.int32) + base
        tent = jnp.where(lidx == source, 0, INF32).astype(jnp.int32)
        tent = jnp.where(lidx < n, tent, INF32)
        explored = jnp.full((s_nodes,), INF32, jnp.int32)
        src_off = jnp.where(src_e < n, src_e - vstart, s_nodes).astype(jnp.int32)

        def sweep_combine(tent, frontier, light):
            buf = jnp.full((n_pad,), INF32, jnp.int32)
            f_src = jnp.take(frontier, src_off, mode="fill", fill_value=False)
            buf = _local_sweep(tent, f_src, src_off, dst_e, w_e, buf,
                               delta=delta, light=light)
            return combine_min(buf, tent)

        def local_light_steps(tent, explored, i):
            """cfg.local_steps local sweeps without combining — redundant
            work instead of synchronization (paper §4 'Delta')."""
            def one(k, carry):
                tent, explored = carry
                f, _, _ = scan_bucket(tent, explored, i, delta=delta)
                explored = jnp.where(f, tent, explored)
                buf = jnp.full((n_pad,), INF32, jnp.int32)
                f_src = jnp.take(f, src_off, mode="fill", fill_value=False)
                buf = _local_sweep(tent, f_src, src_off, dst_e, w_e, buf,
                                   delta=delta, light=True)
                # merge only the local slice (no collective)
                piece = lax.dynamic_slice_in_dim(buf, base, s_nodes)
                return jnp.minimum(tent, piece), explored
            if cfg.local_steps > 1:
                tent, explored = lax.fori_loop(
                    0, cfg.local_steps - 1, one, (tent, explored))
            return tent, explored

        def light_phase(tent, explored, i, in_s, inner):
            def flag(t, e):
                f, any_loc, _ = scan_bucket(t, e, i, delta=delta)
                return f, lax.pmax(any_loc.astype(jnp.int32), ax) > 0

            f0, go0 = flag(tent, explored)

            def cond(c):
                return c[5]

            def body(c):
                tent, explored, in_s, inner, f, _ = c
                explored = jnp.where(f, tent, explored)
                in_s = in_s | f
                tent, explored = local_light_steps(tent, explored, i)
                f2, _, _ = scan_bucket(tent, explored, i, delta=delta)
                tent = sweep_combine(tent, f2 | f, light=True)
                f3, go = flag(tent, explored)
                return (tent, explored, in_s, inner + 1, f3, go)

            tent, explored, in_s, inner, _, _ = lax.while_loop(
                cond, body, (tent, explored, in_s, inner, f0, go0))
            return tent, explored, in_s, inner

        def outer_body(c):
            tent, explored, i, outer, inner = c
            in_s = jnp.zeros((s_nodes,), bool)
            tent, explored, in_s, inner = light_phase(
                tent, explored, i, in_s, inner)
            tent = sweep_combine(tent, in_s, light=False)
            _, _, nxt = scan_bucket(tent, explored, i, delta=delta)
            i = lax.pmin(nxt, ax)
            return (tent, explored, i, outer + 1, inner)

        def outer_cond(c):
            return c[2] < _IMAX

        i0 = jnp.zeros((), jnp.int32)
        tent, _, _, outer, inner = lax.while_loop(
            outer_cond, outer_body,
            (tent, explored, i0, jnp.zeros((), jnp.int32),
             jnp.zeros((), jnp.int32)))
        return tent, outer, inner

    def shard_body(sources, src_e, dst_e, w_e, vstart):
        # squeeze the leading shard dim added by in_specs
        src_e, dst_e, w_e = src_e[0], dst_e[0], w_e[0]
        vstart = vstart[0]
        solve = jax.vmap(solve_one, in_axes=(0, None, None, None, None))
        tent, outer, inner = solve(sources, src_e, dst_e, w_e, vstart)
        return tent, outer.max(keepdims=True), inner.max(keepdims=True)

    mapped = shard_map(
        shard_body,
        mesh=mesh,
        in_specs=(batch_spec, P(ax), P(ax), P(ax), P(ax)),
        out_specs=(P(cfg.batch_axes, ax), batch_spec, batch_spec),
        check_vma=False,
    )

    @jax.jit
    def solve(sources, src, dst, w, vstart):
        tent, outer, inner = mapped(sources, src, dst, w, vstart)
        return tent[:, :n], outer.max(), inner.max()

    return solve


def build_distributed_solver(partition: VertexPartition, mesh: Mesh,
                             cfg: DistDeltaConfig = DistDeltaConfig()):
    """Convenience wrapper closing over a concrete partition. Returns
    ``solve(sources int32[B]) -> (dist int32[B, n], outer, inner)``."""
    P_model = mesh.shape[cfg.model_axis]
    if partition.n_shards != P_model:
        raise ValueError(
            f"partition has {partition.n_shards} shards, mesh axis "
            f"{cfg.model_axis!r} has {P_model} devices")
    inner = build_solver_from_meta(
        n_nodes=partition.n_nodes, shard_nodes=partition.shard_nodes,
        mesh=mesh, cfg=cfg)

    def solve(sources):
        return inner(sources, partition.src, partition.dst, partition.w,
                     partition.vstart)

    return solve


def input_specs_sssp(n_sources: int, n_nodes: int):
    """ShapeDtypeStruct stand-ins for the SSSP dry-run."""
    return dict(sources=jax.ShapeDtypeStruct((n_sources,), jnp.int32))
