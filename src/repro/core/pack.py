"""(distance, predecessor) word packing — paper §3 'Data packing'.

The paper packs a 32-bit cost into the high half and a 32-bit vertex id
into the low half of one 64-bit word so a single x86 CAS updates both
consistently. Here the same layout makes a single XLA **scatter-min**
update both consistently: for non-negative costs, integer order on the
packed int64 equals lexicographic order on (cost, pred), so the min
combiner picks the smallest cost and, on ties, the smallest predecessor
id — which also makes the parallel run bitwise deterministic (stronger
than the paper's CAS, which is timing-dependent on ties).

Requires x64 (``jax.experimental.enable_x64`` in tests); the engine's
default ``pred_mode='argmin'`` avoids 64-bit traffic entirely (DESIGN.md).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.graphs.structures import INF32

MASK32 = (1 << 32) - 1
# "infinity" word: INF32 cost, all-ones pred (decodes to pred sentinel).
INF_PACKED = (int(INF32) << 32) | MASK32


def pack(dist, pred):
    """dist int32 (>=0), pred int32 (>=0) → packed int64."""
    d = dist.astype(jnp.int64)
    p = pred.astype(jnp.int64) & MASK32
    return (d << 32) | p


def unpack_dist(packed):
    return (packed >> 32).astype(jnp.int32)


def unpack_pred(packed):
    p = (packed & MASK32).astype(jnp.uint32).astype(jnp.int32)
    return p
