"""Reference SSSP oracles (host-side, numpy/heapq).

``dijkstra`` mirrors the Boost Graph Library baseline the paper compares
against (binary-heap Dijkstra, O(|V| log |V| + |E|)); ``bellman_ford`` is
a second independent oracle used by the property-based tests so that a
bug in one reference cannot mask an engine bug.
"""
from __future__ import annotations

import heapq
from typing import Tuple

import numpy as np

from repro.graphs.structures import COOGraph, INF32

__all__ = ["dijkstra", "bellman_ford", "validate_pred_tree",
           "walk_pred_tree"]


def _to_adj(g: COOGraph):
    src = np.asarray(g.src)
    dst = np.asarray(g.dst)
    w = np.asarray(g.w)
    order = np.argsort(src, kind="stable")
    src, dst, w = src[order], dst[order], w[order]
    row_ptr = np.zeros(g.n_nodes + 1, dtype=np.int64)
    np.cumsum(np.bincount(src, minlength=g.n_nodes), out=row_ptr[1:])
    return row_ptr, dst, w


def dijkstra(g: COOGraph, source: int) -> Tuple[np.ndarray, np.ndarray]:
    """Binary-heap Dijkstra. Returns (dist int64[n] with INF32 sentinel,
    pred int32[n] with -1 for unreachable/source)."""
    row_ptr, dst, w = _to_adj(g)
    n = g.n_nodes
    dist = np.full(n, int(INF32), dtype=np.int64)
    pred = np.full(n, -1, dtype=np.int32)
    dist[source] = 0
    heap = [(0, source)]
    done = np.zeros(n, dtype=bool)
    while heap:
        d, u = heapq.heappop(heap)
        if done[u]:
            continue
        done[u] = True
        for e in range(row_ptr[u], row_ptr[u + 1]):
            v = dst[e]
            nd = d + int(w[e])
            if nd < dist[v]:
                dist[v] = nd
                pred[v] = u
                heapq.heappush(heap, (nd, v))
    return dist, pred


def bellman_ford(g: COOGraph, source: int) -> np.ndarray:
    """Vectorized Bellman-Ford over the edge list. O(V·E) worst case but
    each round is a single numpy sweep; fine at test sizes."""
    src = np.asarray(g.src).astype(np.int64)
    dst = np.asarray(g.dst).astype(np.int64)
    w = np.asarray(g.w).astype(np.int64)
    n = g.n_nodes
    dist = np.full(n, int(INF32), dtype=np.int64)
    dist[source] = 0
    for _ in range(n):
        cand = dist[src] + w
        nxt = dist.copy()
        np.minimum.at(nxt, dst, cand)
        if np.array_equal(nxt, dist):
            break
        dist = nxt
    return dist


def validate_pred_tree(g: COOGraph, source: int, dist: np.ndarray,
                       pred: np.ndarray) -> bool:
    """Check that ``pred`` encodes a valid shortest-path tree for ``dist``:
    every reachable non-source v has an edge (pred[v], v) with
    dist[pred[v]] + w == dist[v]. (Multiple valid trees exist; we check
    validity, not equality with the oracle's tree.)"""
    src = np.asarray(g.src)
    dst = np.asarray(g.dst)
    w = np.asarray(g.w).astype(np.int64)
    edge_w: dict[tuple[int, int], int] = {}
    for s, d, ww in zip(src, dst, w):
        key = (int(s), int(d))
        edge_w[key] = min(edge_w.get(key, 1 << 62), int(ww))
    for v in range(g.n_nodes):
        if v == source or dist[v] >= int(INF32):
            continue
        p = int(pred[v])
        if p < 0:
            return False
        key = (p, v)
        if key not in edge_w:
            return False
        if dist[p] + edge_w[key] != dist[v]:
            return False
    return True


def walk_pred_tree(g: COOGraph, source: int, dist: np.ndarray,
                   pred: np.ndarray) -> bool:
    """Stronger check than :func:`validate_pred_tree`: *walk* the pred
    chain of every reachable vertex all the way to the source — the
    chains must be acyclic (a tree rooted at the source, <= n hops) and
    the accumulated edge weights along each chain must reproduce
    ``dist`` exactly. This is the global invariant a torn (cost, pred)
    write (the paper's C3 worry) or a stale-parent race (C4) would
    break while leaving every *individual* edge locally consistent."""
    src = np.asarray(g.src)
    dst = np.asarray(g.dst)
    w = np.asarray(g.w).astype(np.int64)
    edge_w: dict[tuple[int, int], int] = {}
    for s, d, ww in zip(src, dst, w):
        key = (int(s), int(d))
        edge_w[key] = min(edge_w.get(key, 1 << 62), int(ww))
    n = g.n_nodes
    for v in range(n):
        if v == source or dist[v] >= int(INF32):
            continue
        acc = 0
        u = v
        for _ in range(n):                      # > n hops = a cycle
            p = int(pred[u])
            if p < 0:
                return False                    # chain broke off-tree
            key = (p, u)
            if key not in edge_w:
                return False                    # pred edge not in graph
            acc += edge_w[key]
            u = p
            if u == source:
                break
        else:
            return False                        # never reached the source
        if acc != int(dist[v]):
            return False                        # weights don't reproduce dist
    return True
