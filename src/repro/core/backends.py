"""Pluggable relaxation backends for the unified Δ-stepping driver.

DESIGN.md §3: the paper's three inner mechanisms — request generation
(light/heavy split), relaxation, and the dense bucket scan (C1) — are
isolated behind the ``RelaxBackend`` protocol so a single generic
outer/inner loop driver (``core.delta_stepping``) hosts every strategy:

* ``edge``   — edge-centric |E| sweep (jnp scatter-min), zero
  preprocessing; the light mask is evaluated on the fly.
* ``ell``    — frontier-compacted expansion of light/heavy ELL blocks
  (jnp); work scales with |frontier|·max_deg.
* ``pallas`` — the same ELL expansion through the ``kernels/ell_relax``
  Pallas kernel with bucket bookkeeping fused by ``kernels/bucket_scan``;
  on game-map (occupancy-grid) instances the relaxation is instead the
  ``kernels/grid_relax`` min-plus stencil.

A backend provides two traced operations over solver state:

  ``sweep(tent, mask, bucket_i, light=, packed=)`` → ``(tent', overflow)``
      one relaxation sweep from the masked vertex set (the current
      frontier for light passes, the settled set S for the heavy pass);
  ``scan(dist, explored, bucket_i)`` → ``(frontier, any, next_bucket)``
      the fused dense-bucket scan.

plus host-side preprocessing in its ``build`` classmethod (CSR
conversion, light/heavy split, ELL padding). Backends are registered
pytrees: their operand arrays are jit *arguments*, not baked constants,
so solvers over same-shaped graphs share compile cache entries.

Sweeps are pure scatter-min dataflow, so the driver can ``vmap`` them
over a batch of sources (``supports_vmap``); the Pallas-backed ones run
the batch under ``lax.map`` instead (``pallas_call`` with scalar-prefetch
grids has no batching rule).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import pack as packing
from repro.graphs.structures import (
    COOGraph,
    ELLGraph,
    INF32,
    coo_to_csr,
    csr_to_ell,
    light_heavy_split,
)
from repro.kernels.bucket_scan import bucket_scan
from repro.kernels.ell_relax import ell_relax
from repro.kernels.grid_relax import grid_relax

_IMAX = jnp.int32(2**31 - 1)


# ---------------------------------------------------------------------------
# value-word helpers: every backend is generic over 'plain int32 distance'
# vs 'packed int64 (distance, predecessor)' words (paper C3, pack.py).
# ---------------------------------------------------------------------------

def init_tent(n: int, source, packed: bool):
    if packed:
        tent = jnp.full((n,), packing.INF_PACKED, dtype=jnp.int64)
        src_word = packing.pack(jnp.zeros((), jnp.int32),
                                jnp.asarray(source, jnp.int32))
        return tent.at[source].set(src_word)
    return jnp.full((n,), INF32, jnp.int32).at[source].set(0)


def dist_of(tent, packed: bool):
    return packing.unpack_dist(tent) if packed else tent


def candidate_words(cand_d, src_ids, ok, packed: bool):
    if packed:
        word = packing.pack(cand_d, src_ids)
        return jnp.where(ok, word, packing.INF_PACKED)
    return jnp.where(ok, cand_d, INF32)


# ---------------------------------------------------------------------------
# shared primitive ops (also consumed by core.distributed)
# ---------------------------------------------------------------------------

def scan_bucket(dist, explored, bucket_i, *, delta: int):
    """Fused dense-bucket scan (paper C1): the frontier mask of bucket
    ``bucket_i``, its any-reduce, and the next non-empty bucket index —
    pure-jnp twin of the ``kernels/bucket_scan`` Pallas kernel."""
    fin = dist < INF32
    b = jnp.where(fin, dist // delta, _IMAX)
    frontier = fin & (b == bucket_i) & (dist < explored)
    nxt = jnp.where(b > bucket_i, b, _IMAX).min()
    return frontier, frontier.any(), nxt


def edge_candidates(d_src, f_src, w, *, delta: int, light: bool):
    """Candidate distances of one edge-array relaxation and the C4 early
    mask (frontier membership + phase; the ``cand < tent[dst]`` filter is
    the caller's, since only it holds the destination gather)."""
    active = f_src & (d_src < INF32)
    cand = jnp.where(active, d_src, 0) + jnp.where(active, w, 0)
    phase = (w <= delta) if light else (w > delta)
    return cand, active & phase


def edge_sweep(tent, frontier, src, dst, w, *, delta: int, light: bool,
               packed: bool):
    """One relaxation sweep over an edge array, masked by frontier[src]
    and the light/heavy phase. Padding edges may carry src == n (sentinel):
    out-of-range gathers are filled inactive, out-of-range scatters drop —
    the TPU version of the paper's 'benign garbage writes' argument."""
    d = dist_of(tent, packed)
    f = jnp.take(frontier, src, mode="fill", fill_value=False)
    d_src = jnp.take(d, src, mode="fill", fill_value=INF32)
    cand, ok = edge_candidates(d_src, f, w, delta=delta, light=light)
    d_dst = jnp.take(d, dst, mode="fill", fill_value=INF32)
    ok = ok & (cand < d_dst)              # C4: early filter before scatter
    words = candidate_words(cand, src, ok, packed)
    return tent.at[dst].min(words, mode="drop")


def ell_sweep(tent, fidx, nbr, w_ell, *, n: int, packed: bool):
    """Expand compacted frontier rows of an ELL adjacency block.
    ``fidx`` int32[cap] with sentinel value n for padding slots."""
    d = dist_of(tent, packed)
    rows_n = nbr[fidx]                      # (cap, D); row n is all-sentinel
    rows_w = w_ell[fidx]
    d_f = jnp.take(d, fidx, mode="fill", fill_value=INF32)
    valid = (rows_n < n) & (rows_w < INF32) & (d_f[:, None] < INF32)
    cand = (jnp.where(valid, d_f[:, None], 0)
            + jnp.where(valid, rows_w, 0))
    d_dst = jnp.take(d, rows_n, mode="fill", fill_value=INF32)
    ok = valid & (cand < d_dst)
    src_ids = jnp.broadcast_to(fidx[:, None], rows_n.shape)
    words = candidate_words(cand, src_ids, ok, packed)
    return tent.at[rows_n.ravel()].min(words.ravel(), mode="drop")


# ---------------------------------------------------------------------------
# backends
# ---------------------------------------------------------------------------

class RelaxBackend:
    """Strategy protocol consumed by the unified driver (methods only;
    concrete backends are frozen pytree dataclasses)."""

    supports_vmap = True
    delta: int

    def sweep(self, tent, mask, bucket_i, *, light: bool, packed: bool):
        raise NotImplementedError

    def scan(self, dist, explored, bucket_i):
        return scan_bucket(dist, explored, bucket_i, delta=self.delta)


def _static():
    return dataclasses.field(metadata=dict(static=True))


class _FrontierCompactMixin:
    """Shared ELL-strategy frontier compaction: masked vertex set → a
    fixed-capacity index buffer (sentinel ``n``) plus the overflow flag.
    Consumers declare static fields ``n`` and ``cap``."""

    def compact(self, mask):
        idx = jnp.nonzero(mask, size=self.cap,
                          fill_value=self.n)[0].astype(jnp.int32)
        return idx, mask.sum() > self.cap


class _PallasScanMixin:
    """Bucket bookkeeping on the fused ``kernels/bucket_scan`` Pallas
    kernel. Consumers declare static fields ``delta`` and ``interpret``."""

    def scan(self, dist, explored, bucket_i):
        return bucket_scan(dist, explored, bucket_i, delta=self.delta,
                           backend="pallas", interpret=self.interpret)


def _ell_blocks(graph: COOGraph, delta: int):
    """Host-side preprocessing shared by the ELL strategies: CSR convert,
    light/heavy split (paper Alg. 1 lines 3–5), ELL pad."""
    csr = coo_to_csr(graph)
    light, heavy = light_heavy_split(csr, delta)
    return csr_to_ell(light), csr_to_ell(heavy)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class EdgeBackend(RelaxBackend):
    """Edge-centric strategy: every sweep touches all |E| edges, masked
    by frontier membership of their source (fixed shapes, no compaction)."""

    src: jax.Array
    dst: jax.Array
    w: jax.Array
    delta: int = _static()

    @classmethod
    def build(cls, graph: COOGraph, cfg) -> "EdgeBackend":
        return cls(graph.src, graph.dst, graph.w, cfg.delta)

    def sweep(self, tent, mask, bucket_i, *, light: bool, packed: bool):
        tent = edge_sweep(tent, mask, self.src, self.dst, self.w,
                          delta=self.delta, light=light, packed=packed)
        return tent, jnp.zeros((), bool)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class EllBackend(_FrontierCompactMixin, RelaxBackend):
    """Frontier-centric strategy: compacts the masked set into a
    fixed-capacity index buffer and expands light/heavy ELL rows
    (preprocessed split, paper Alg. 1 lines 3–5)."""

    light: ELLGraph
    heavy: ELLGraph
    delta: int = _static()
    n: int = _static()
    cap: int = _static()

    @classmethod
    def build(cls, graph: COOGraph, cfg) -> "EllBackend":
        light, heavy = _ell_blocks(graph, cfg.delta)
        return cls(light, heavy, cfg.delta, graph.n_nodes,
                   cfg.frontier_cap or graph.n_nodes)

    def sweep(self, tent, mask, bucket_i, *, light: bool, packed: bool):
        fidx, over = self.compact(mask)
        ell = self.light if light else self.heavy
        tent = ell_sweep(tent, fidx, ell.nbr, ell.w, n=self.n, packed=packed)
        return tent, over


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PallasEllBackend(_FrontierCompactMixin, _PallasScanMixin,
                       RelaxBackend):
    """ELL strategy with the hot loops on Pallas TPU kernels: candidate
    generation by ``kernels/ell_relax`` (scalar-prefetch row gather) and
    the three bucket scans fused by ``kernels/bucket_scan``. The
    scatter-min merge stays in XLA (C2), so packed (dist, pred) words
    still work — the kernel only ever sees int32 distances."""

    supports_vmap = False

    light: ELLGraph
    heavy: ELLGraph
    delta: int = _static()
    n: int = _static()
    cap: int = _static()
    interpret: bool = _static()

    @classmethod
    def build(cls, graph: COOGraph, cfg) -> "PallasEllBackend":
        light, heavy = _ell_blocks(graph, cfg.delta)
        return cls(light, heavy, cfg.delta, graph.n_nodes,
                   cfg.frontier_cap or graph.n_nodes, cfg.interpret)

    def sweep(self, tent, mask, bucket_i, *, light: bool, packed: bool):
        fidx, over = self.compact(mask)
        ell = self.light if light else self.heavy
        d = dist_of(tent, packed)
        cand = ell_relax(fidx, d, ell.w, backend="pallas",
                         interpret=self.interpret)          # (cap, D)
        rows_n = ell.nbr[fidx]
        d_dst = jnp.take(d, rows_n, mode="fill", fill_value=INF32)
        ok = cand < d_dst                 # C4 filter on kernel candidates
        src_ids = jnp.broadcast_to(fidx[:, None], rows_n.shape)
        words = candidate_words(cand, src_ids, ok, packed)
        tent = tent.at[rows_n.ravel()].min(words.ravel(), mode="drop")
        return tent, over


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class GridPallasBackend(_PallasScanMixin, RelaxBackend):
    """Game-map strategy (paper §4 'Game Maps'): the graph is an
    occupancy grid, so relaxation is the ``kernels/grid_relax`` masked
    min-plus stencil — no adjacency materialization at all. The stencil
    recomputes bucket membership from ``tent`` in-kernel, so the driver's
    mask argument is advisory; re-relaxing settled cells is idempotent
    (the paper's redundant-work trade). int32 distances only
    (``pred_mode='packed'`` is rejected at build time)."""

    supports_vmap = False

    free: jax.Array                       # bool[H, W] occupancy mask
    delta: int = _static()
    shape: Tuple[int, int] = _static()
    costs: Tuple[int, int] = _static()    # (straight, diagonal)
    interpret: bool = _static()

    @classmethod
    def build(cls, graph: COOGraph, cfg, free_mask) -> "GridPallasBackend":
        free = jnp.asarray(free_mask, bool)
        if free.ndim != 2 or free.size != graph.n_nodes:
            raise ValueError(
                f"free_mask shape {free.shape} does not cover the "
                f"{graph.n_nodes}-vertex graph")
        return cls(free, cfg.delta, tuple(free.shape),
                   tuple(cfg.grid_costs), cfg.interpret)

    def sweep(self, tent, mask, bucket_i, *, light: bool, packed: bool):
        h, w = self.shape
        out = grid_relax(tent.reshape(h, w), self.free, bucket_i,
                         delta=self.delta, cost_straight=self.costs[0],
                         cost_diag=self.costs[1], light=light,
                         backend="pallas", interpret=self.interpret)
        return out.reshape(-1), jnp.zeros((), bool)


def make_backend(graph: COOGraph, cfg, free_mask=None) -> RelaxBackend:
    """Route a (graph, config) pair to its backend. ``free_mask`` marks
    the game-map graph class: under ``strategy='pallas'`` it selects the
    grid-stencil kernel instead of the ELL kernels. ``cfg="auto"``
    consults the tuning subsystem (estimator + cache, DESIGN.md §7)."""
    if isinstance(cfg, str):
        from repro.tune import resolve_config   # lazy: tune imports core
        if cfg != "auto":
            raise ValueError(f"unknown config string {cfg!r}")
        cfg = resolve_config(graph, free_mask=free_mask, sources=None)
    if cfg.strategy == "edge":
        return EdgeBackend.build(graph, cfg)
    if cfg.strategy == "ell":
        return EllBackend.build(graph, cfg)
    assert cfg.strategy == "pallas", cfg.strategy
    if free_mask is not None:
        if cfg.pred_mode == "packed":
            raise ValueError(
                "grid-stencil pallas backend carries int32 distances only; "
                "use pred_mode='argmin' (post-hoc tree recovery)")
        return GridPallasBackend.build(graph, cfg, free_mask)
    return PallasEllBackend.build(graph, cfg)
