"""Pluggable relaxation backends for the unified Δ-stepping driver.

DESIGN.md §3: the paper's three inner mechanisms — request generation
(light/heavy split), relaxation, and the dense bucket scan (C1) — are
isolated behind the ``RelaxBackend`` protocol so a single generic
outer/inner loop driver (``core.delta_stepping``) hosts every strategy:

* ``edge``   — edge-centric |E| sweep (jnp scatter-min), zero
  preprocessing; the light mask is evaluated on the fly.
* ``ell``    — frontier-compacted expansion of light/heavy ELL blocks
  (jnp); work scales with |frontier|·max_deg.
* ``pallas`` — the same ELL expansion through the ``kernels/ell_relax``
  Pallas kernel with bucket bookkeeping fused by ``kernels/bucket_scan``;
  on game-map (occupancy-grid) instances the relaxation is instead the
  ``kernels/grid_relax`` min-plus stencil.
* ``fused``  — the frontier-compacted ELL expansion with scan,
  compaction and row gather fused into one ``kernels/frontier_relax``
  Pallas call (DESIGN.md §12); the backend additionally implements the
  driver's *fused light phase* protocol (``supports_fused_light`` /
  ``fused_iter`` / ``fused_next``), so each inner iteration is one
  kernel step plus O(cap·deg) XLA scatters instead of three full-width
  passes. On hosts where the kernel cannot run compiled (plain CPU
  without interpret mode) the build drops to the bitwise-identical jnp
  twin — the strategy is then a loop-structure optimization only.
* ``sharded_edge`` / ``sharded_ell`` — SPMD variants of the first two:
  edges (or ELL row blocks) are partitioned across a 1-D device mesh
  (``graphs.partition``), each sweep runs per-shard under ``shard_map``
  (through ``compat``) and merges candidates with an all-reduce min.
  The merge reduces whole tent *words* — in ``packed`` mode the int64
  (cost, pred) word — so the sharded run is bitwise identical to the
  single-device engine, not merely distance-equal (DESIGN.md §9).
* ``sharded_fused`` — the fused step per shard (each device scans and
  compacts its owned vertex slice and gathers its local ELL rows),
  composed with exactly the same all-reduce min-over-words merge, so
  both contracts hold at once: bitwise ≡ single-device ``fused`` for
  any shard count, and ``fused`` bitwise ≡ ``edge``/``ell``.

A backend provides two traced operations over solver state:

  ``sweep(tent, mask, bucket_i, light=, packed=)`` → ``(tent', overflow)``
      one relaxation sweep from the masked vertex set (the current
      frontier for light passes, the settled set S for the heavy pass);
  ``scan(dist, explored, bucket_i)`` → ``(frontier, any, next_bucket)``
      the fused dense-bucket scan.

plus host-side preprocessing in its ``build`` classmethod (CSR
conversion, light/heavy split, ELL padding). Backends are registered
pytrees: their operand arrays are jit *arguments*, not baked constants,
so solvers over same-shaped graphs share compile cache entries.

Sweeps are pure scatter-min dataflow, so the driver can ``vmap`` them
over a batch of sources (``supports_vmap``); the Pallas-backed ones run
the batch under ``lax.map`` instead (``pallas_call`` with scalar-prefetch
grids has no batching rule).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec

from repro import compat
from repro.core import pack as packing
from repro.graphs.partition import ELLPartition, partition_edges, partition_ell
from repro.graphs.structures import (
    COOGraph,
    ELLGraph,
    INF32,
    coo_to_csr,
    csr_to_ell,
    light_heavy_split,
)
from repro.kernels.bucket_scan import bucket_scan
from repro.kernels.ell_relax import ell_relax
from repro.kernels.frontier_relax import frontier_relax
from repro.kernels.grid_relax import grid_relax

_IMAX = jnp.int32(2**31 - 1)


# ---------------------------------------------------------------------------
# value-word helpers: every backend is generic over 'plain int32 distance'
# vs 'packed int64 (distance, predecessor)' words (paper C3, pack.py).
# ---------------------------------------------------------------------------

def init_tent(n: int, source, packed: bool):
    if packed:
        tent = jnp.full((n,), packing.INF_PACKED, dtype=jnp.int64)
        src_word = packing.pack(jnp.zeros((), jnp.int32),
                                jnp.asarray(source, jnp.int32))
        return tent.at[source].set(src_word)
    return jnp.full((n,), INF32, jnp.int32).at[source].set(0)


def dist_of(tent, packed: bool):
    return packing.unpack_dist(tent) if packed else tent


def candidate_words(cand_d, src_ids, ok, packed: bool):
    if packed:
        word = packing.pack(cand_d, src_ids)
        return jnp.where(ok, word, packing.INF_PACKED)
    return jnp.where(ok, cand_d, INF32)


def graph_is_canonical(graph: COOGraph) -> bool:
    """True when every edge weight is >= 1 — the *canonical-ties* graph
    class (DESIGN.md §11). On it, packed (cost, pred) relaxations use
    the word-order C4 filter, whose fixed point is a pure function of
    (graph, source): word[v] = (dist[v], smallest-id tight parent). That
    trajectory independence is what lets a warm-started repair solve
    (repro.dynamic) be bitwise identical to a cold solve. Zero-weight
    graphs keep the historical strict-distance filter instead: the
    canonical rule could close a predecessor cycle inside a zero-weight
    tie group (exactly the hazard documented for pred_mode='argmin'),
    while the temporal first-settled tie-break cannot."""
    w = np.asarray(graph.w)
    return bool(w.size == 0 or int(w.min()) >= 1)


# ---------------------------------------------------------------------------
# shared primitive ops (also consumed by core.distributed)
# ---------------------------------------------------------------------------

def scan_bucket(dist, explored, bucket_i, *, delta: int):
    """Fused dense-bucket scan (paper C1): the frontier mask of bucket
    ``bucket_i``, its any-reduce, and the next bucket index holding
    *unexplored* work — pure-jnp twin of the ``kernels/bucket_scan``
    Pallas kernel.

    The next-bucket minimum is restricted to unsettled vertices
    (``dist < explored``). On a cold solve this changes nothing, bit
    for bit: while the outer loop sits at bucket i, every finite vertex
    in a bucket > i is still unexplored (``explored`` is only ever set
    to a vertex's tent value while it sits in the *current* bucket's
    frontier, and tent never increases — so a future-bucket tent value
    is always strictly below its explored mark; proof in DESIGN.md
    §11). On a *warm* re-solve (repro.dynamic) the restriction is
    load-bearing: buckets whose vertices are all pre-settled from the
    previous solve are skipped outright, which bounds the outer loop to
    the buckets the repair actually touched."""
    fin = dist < INF32
    b = jnp.where(fin, dist // delta, _IMAX)
    frontier = fin & (b == bucket_i) & (dist < explored)
    nxt = jnp.where((b > bucket_i) & (dist < explored), b, _IMAX).min()
    return frontier, frontier.any(), nxt


def edge_candidates(d_src, f_src, w, *, delta: int, light: bool):
    """Candidate distances of one edge-array relaxation and the C4 early
    mask (frontier membership + phase; the ``cand < tent[dst]`` filter is
    the caller's, since only it holds the destination gather)."""
    active = f_src & (d_src < INF32)
    cand = jnp.where(active, d_src, 0) + jnp.where(active, w, 0)
    phase = (w <= delta) if light else (w > delta)
    return cand, active & phase


def edge_relax_words(tent, frontier, src, dst, w, *, delta: int, light: bool,
                     packed: bool, canonical: bool = False):
    """Candidate words of one edge-array relaxation: frontier/phase mask,
    C4 early filter against the destination gather, word packing. The
    single shared generation path of the single-device ``edge_sweep``
    and the per-shard ``ShardedEdgeBackend`` sweep — callers differ only
    in the scatter target (tent vs a per-shard merge buffer), which is
    what keeps them bitwise interchangeable (DESIGN.md §9). Padding
    edges may carry src == n (sentinel): out-of-range gathers are filled
    inactive — the TPU version of the paper's 'benign garbage writes'
    argument.

    The C4 filter has two regimes (DESIGN.md §11): with
    ``canonical=True`` (packed mode on a w >= 1 graph) a candidate word
    passes when it beats the destination's current *word* — so an
    equal-cost candidate with a smaller predecessor id still lands, and
    the converged word is the schedule-independent (dist, smallest-id
    tight parent). Otherwise the historical strict distance comparison
    applies (first-settled tie winner keeps its slot)."""
    d = dist_of(tent, packed)
    f = jnp.take(frontier, src, mode="fill", fill_value=False)
    d_src = jnp.take(d, src, mode="fill", fill_value=INF32)
    cand, ok = edge_candidates(d_src, f, w, delta=delta, light=light)
    if packed and canonical:
        word = packing.pack(cand, src)
        word_dst = jnp.take(tent, dst, mode="fill",
                            fill_value=packing.INF_PACKED)
        ok = ok & (word < word_dst)       # C4 on (cost, pred) word order
        return jnp.where(ok, word, packing.INF_PACKED)
    d_dst = jnp.take(d, dst, mode="fill", fill_value=INF32)
    ok = ok & (cand < d_dst)              # C4: early filter before scatter
    return candidate_words(cand, src, ok, packed)


def edge_sweep(tent, frontier, src, dst, w, *, delta: int, light: bool,
               packed: bool, canonical: bool = False):
    """One relaxation sweep over an edge array; out-of-range scatters
    (padding edges) drop."""
    words = edge_relax_words(tent, frontier, src, dst, w,
                             delta=delta, light=light, packed=packed,
                             canonical=canonical)
    return tent.at[dst].min(words, mode="drop")


def ell_relax_words(tent, fidx, rows_n, rows_w, *, n: int, packed: bool,
                    canonical: bool = False):
    """Candidate words of gathered ELL rows (``rows_n``/``rows_w``
    (cap, D), global neighbor ids). ``fidx`` int32[cap] holds the
    *global* vertex ids of the compacted rows with a >= n sentinel for
    padding slots (gathers INF). Shared by the single-device
    ``ell_sweep`` and the per-shard ``ShardedEllBackend`` sweep — same
    bitwise-interchangeability contract (and the same two C4 filter
    regimes) as ``edge_relax_words``."""
    d = dist_of(tent, packed)
    d_f = jnp.take(d, fidx, mode="fill", fill_value=INF32)
    valid = (rows_n < n) & (rows_w < INF32) & (d_f[:, None] < INF32)
    cand = (jnp.where(valid, d_f[:, None], 0)
            + jnp.where(valid, rows_w, 0))
    src_ids = jnp.broadcast_to(fidx[:, None], rows_n.shape)
    if packed and canonical:
        word = packing.pack(cand, src_ids)
        word_dst = jnp.take(tent, rows_n, mode="fill",
                            fill_value=packing.INF_PACKED)
        ok = valid & (word < word_dst)
        return jnp.where(ok, word, packing.INF_PACKED)
    d_dst = jnp.take(d, rows_n, mode="fill", fill_value=INF32)
    ok = valid & (cand < d_dst)
    return candidate_words(cand, src_ids, ok, packed)


def ell_sweep(tent, fidx, nbr, w_ell, *, n: int, packed: bool,
              canonical: bool = False):
    """Expand compacted frontier rows of an ELL adjacency block.
    ``fidx`` int32[cap] with sentinel value n for padding slots."""
    rows_n = nbr[fidx]                      # (cap, D); row n is all-sentinel
    rows_w = w_ell[fidx]
    words = ell_relax_words(tent, fidx, rows_n, rows_w, n=n, packed=packed,
                            canonical=canonical)
    return tent.at[rows_n.ravel()].min(words.ravel(), mode="drop")


# ---------------------------------------------------------------------------
# backends
# ---------------------------------------------------------------------------

class RelaxBackend:
    """Strategy protocol consumed by the unified driver (methods only;
    concrete backends are frozen pytree dataclasses)."""

    supports_vmap = True
    delta: int

    def sweep(self, tent, mask, bucket_i, *, light: bool, packed: bool):
        raise NotImplementedError

    def scan(self, dist, explored, bucket_i):
        return scan_bucket(dist, explored, bucket_i, delta=self.delta)


def _static():
    return dataclasses.field(metadata=dict(static=True))


class _FrontierCompactMixin:
    """Shared ELL-strategy frontier compaction: masked vertex set → a
    fixed-capacity index buffer (sentinel ``n``) plus the overflow flag.
    Consumers declare static fields ``n`` and ``cap``."""

    def compact(self, mask):
        idx = jnp.nonzero(mask, size=self.cap,
                          fill_value=self.n)[0].astype(jnp.int32)
        return idx, mask.sum() > self.cap


class _PallasScanMixin:
    """Bucket bookkeeping on the fused ``kernels/bucket_scan`` Pallas
    kernel. Consumers declare static fields ``delta`` and ``interpret``."""

    def scan(self, dist, explored, bucket_i):
        return bucket_scan(dist, explored, bucket_i, delta=self.delta,
                           backend="pallas", interpret=self.interpret)


def _ell_blocks(graph: COOGraph, delta: int, max_deg=None):
    """Host-side preprocessing shared by the ELL strategies: CSR convert,
    light/heavy split (paper Alg. 1 lines 3–5), ELL pad. ``max_deg``
    pins both blocks' pad width; the default (tightest per-block width)
    is weight-*dependent* — dynamic-update consumers pin the weight-
    independent full adjacency degree instead, so rebuilding after a
    cost change keeps the compiled shapes (repro.dynamic)."""
    csr = coo_to_csr(graph)
    light, heavy = light_heavy_split(csr, delta)
    return csr_to_ell(light, max_deg), csr_to_ell(heavy, max_deg)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class EdgeBackend(RelaxBackend):
    """Edge-centric strategy: every sweep touches all |E| edges, masked
    by frontier membership of their source (fixed shapes, no compaction)."""

    src: jax.Array
    dst: jax.Array
    w: jax.Array
    delta: int = _static()
    canonical: bool = _static()

    @classmethod
    def build(cls, graph: COOGraph, cfg) -> "EdgeBackend":
        return cls(graph.src, graph.dst, graph.w, cfg.delta,
                   graph_is_canonical(graph))

    def sweep(self, tent, mask, bucket_i, *, light: bool, packed: bool):
        tent = edge_sweep(tent, mask, self.src, self.dst, self.w,
                          delta=self.delta, light=light, packed=packed,
                          canonical=self.canonical)
        return tent, jnp.zeros((), bool)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class EllBackend(_FrontierCompactMixin, RelaxBackend):
    """Frontier-centric strategy: compacts the masked set into a
    fixed-capacity index buffer and expands light/heavy ELL rows
    (preprocessed split, paper Alg. 1 lines 3–5)."""

    light: ELLGraph
    heavy: ELLGraph
    delta: int = _static()
    n: int = _static()
    cap: int = _static()
    canonical: bool = _static()

    @classmethod
    def build(cls, graph: COOGraph, cfg, max_deg=None) -> "EllBackend":
        light, heavy = _ell_blocks(graph, cfg.delta, max_deg)
        return cls(light, heavy, cfg.delta, graph.n_nodes,
                   cfg.frontier_cap or graph.n_nodes,
                   graph_is_canonical(graph))

    def sweep(self, tent, mask, bucket_i, *, light: bool, packed: bool):
        fidx, over = self.compact(mask)
        ell = self.light if light else self.heavy
        tent = ell_sweep(tent, fidx, ell.nbr, ell.w, n=self.n, packed=packed,
                         canonical=self.canonical)
        return tent, over


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PallasEllBackend(_FrontierCompactMixin, _PallasScanMixin,
                       RelaxBackend):
    """ELL strategy with the hot loops on Pallas TPU kernels: candidate
    generation by ``kernels/ell_relax`` (scalar-prefetch row gather) and
    the three bucket scans fused by ``kernels/bucket_scan``. The
    scatter-min merge stays in XLA (C2), so packed (dist, pred) words
    still work — the kernel only ever sees int32 distances."""

    supports_vmap = False

    light: ELLGraph
    heavy: ELLGraph
    delta: int = _static()
    n: int = _static()
    cap: int = _static()
    interpret: bool = _static()
    canonical: bool = _static()

    @classmethod
    def build(cls, graph: COOGraph, cfg, max_deg=None) -> "PallasEllBackend":
        light, heavy = _ell_blocks(graph, cfg.delta, max_deg)
        return cls(light, heavy, cfg.delta, graph.n_nodes,
                   cfg.frontier_cap or graph.n_nodes, cfg.interpret,
                   graph_is_canonical(graph))

    def sweep(self, tent, mask, bucket_i, *, light: bool, packed: bool):
        fidx, over = self.compact(mask)
        ell = self.light if light else self.heavy
        d = dist_of(tent, packed)
        cand = ell_relax(fidx, d, ell.w, backend="pallas",
                         interpret=self.interpret)          # (cap, D)
        rows_n = ell.nbr[fidx]
        src_ids = jnp.broadcast_to(fidx[:, None], rows_n.shape)
        if packed and self.canonical:
            # C4 on word order (the kernel only ever sees distances, so
            # INF candidates from padded slots are masked explicitly)
            word = packing.pack(cand, src_ids)
            word_dst = jnp.take(tent, rows_n, mode="fill",
                                fill_value=packing.INF_PACKED)
            ok = (cand < INF32) & (word < word_dst)
            words = jnp.where(ok, word, packing.INF_PACKED)
        else:
            d_dst = jnp.take(d, rows_n, mode="fill", fill_value=INF32)
            ok = cand < d_dst             # C4 filter on kernel candidates
            words = candidate_words(cand, src_ids, ok, packed)
        tent = tent.at[rows_n.ravel()].min(words.ravel(), mode="drop")
        return tent, over


def _kernel_viable(cfg) -> bool:
    """Whether the fused ``pallas_call`` can actually execute here:
    interpret mode anywhere (the CI configuration — CPU executes the
    kernel *body* through the Pallas interpreter), compiled only on a
    real TPU. Everywhere else the fused strategies run their jnp twin,
    which is bitwise identical by construction (kernels/frontier_relax)
    — the strategy selection never changes answers, only which engine
    executes the same dataflow."""
    return bool(cfg.interpret) or jax.default_backend() == "tpu"


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class FusedBackend(_FrontierCompactMixin, RelaxBackend):
    """Fused frontier strategy (DESIGN.md §12): the light phase runs the
    driver's *fused light phase* protocol — one
    ``kernels/frontier_relax`` step per inner iteration produces the
    compacted frontier, its gathered ELL rows, the any-reduce and the
    next-bucket min together, and the candidate words then flow through
    the shared ``ell_relax_words`` path into an XLA scatter-min (C2
    stays in XLA, so packed (cost, pred) words work unchanged). The
    heavy pass and any generic ``sweep`` call fall back to the plain
    compact-and-expand of ``EllBackend`` (the settled-set mask is not a
    bucket-membership scan, so there is nothing for the kernel to
    fuse)."""

    supports_fused_light = True

    light: ELLGraph
    heavy: ELLGraph
    delta: int = _static()
    n: int = _static()
    cap: int = _static()
    kernel: bool = _static()
    interpret: bool = _static()
    canonical: bool = _static()

    @property
    def supports_vmap(self):
        # the kernel path has no batching rule; the jnp twin vmaps fine
        return not self.kernel

    @classmethod
    def build(cls, graph: COOGraph, cfg, max_deg=None) -> "FusedBackend":
        light, heavy = _ell_blocks(graph, cfg.delta, max_deg)
        return cls(light, heavy, cfg.delta, graph.n_nodes,
                   cfg.frontier_cap or graph.n_nodes, _kernel_viable(cfg),
                   cfg.interpret, graph_is_canonical(graph))

    def _fused_step(self, dist, explored, bucket_i):
        ell = self.light
        return frontier_relax(
            dist, explored, bucket_i, ell.nbr, ell.w, delta=self.delta,
            cap=self.cap, base=0, sent=self.n,
            backend="pallas" if self.kernel else "ref",
            interpret=self.interpret)

    def fused_iter(self, tent, explored, in_s, bucket_i, *, packed: bool):
        """One whole light inner iteration: kernel step (scan + compact
        + gather), settled-set bookkeeping, shared-path relaxation.
        Replays the classic loop's op sequence on the same states —
        explored/S updates read the *pre*-relaxation distances — so
        state trajectories are bitwise those of ``edge``/``ell``
        (DESIGN.md §12). An empty frontier makes every phase a sentinel
        no-op, which is what lets the driver run this unconditionally."""
        d = dist_of(tent, packed)
        fidx, rows_n, rows_w, count, any_, _ = self._fused_step(
            d, explored, bucket_i)
        d_f = jnp.take(d, fidx, mode="fill", fill_value=INF32)
        explored = explored.at[fidx].set(d_f, mode="drop")
        in_s = in_s.at[fidx].set(True, mode="drop")
        words = ell_relax_words(tent, fidx, rows_n, rows_w, n=self.n,
                                packed=packed, canonical=self.canonical)
        tent = tent.at[rows_n.ravel()].min(words.ravel(), mode="drop")
        return tent, explored, in_s, any_, count > self.cap

    def fused_next(self, dist, explored, bucket_i):
        """Next-bucket min for the driver's bucket advance — the
        kernel's scalar output (the fused replacement of the post-heavy
        ``scan_bucket`` call; bitwise equal, same formulas)."""
        if self.kernel:
            return self._fused_step(dist, explored, bucket_i)[5]
        return scan_bucket(dist, explored, bucket_i, delta=self.delta)[2]

    def sweep(self, tent, mask, bucket_i, *, light: bool, packed: bool):
        fidx, over = self.compact(mask)
        ell = self.light if light else self.heavy
        tent = ell_sweep(tent, fidx, ell.nbr, ell.w, n=self.n, packed=packed,
                         canonical=self.canonical)
        return tent, over


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class GridPallasBackend(_PallasScanMixin, RelaxBackend):
    """Game-map strategy (paper §4 'Game Maps'): the graph is an
    occupancy grid, so relaxation is the ``kernels/grid_relax`` masked
    min-plus stencil — no adjacency materialization at all. The stencil
    recomputes bucket membership from ``tent`` in-kernel, so the driver's
    mask argument is advisory; re-relaxing settled cells is idempotent
    (the paper's redundant-work trade). int32 distances only
    (``pred_mode='packed'`` is rejected at build time)."""

    supports_vmap = False

    free: jax.Array                       # bool[H, W] occupancy mask
    delta: int = _static()
    shape: Tuple[int, int] = _static()
    costs: Tuple[int, int] = _static()    # (straight, diagonal)
    interpret: bool = _static()

    @classmethod
    def build(cls, graph: COOGraph, cfg, free_mask) -> "GridPallasBackend":
        free = jnp.asarray(free_mask, bool)
        if free.ndim != 2 or free.size != graph.n_nodes:
            raise ValueError(
                f"free_mask shape {free.shape} does not cover the "
                f"{graph.n_nodes}-vertex graph")
        return cls(free, cfg.delta, tuple(free.shape),
                   tuple(cfg.grid_costs), cfg.interpret)

    def sweep(self, tent, mask, bucket_i, *, light: bool, packed: bool):
        h, w = self.shape
        out = grid_relax(tent.reshape(h, w), self.free, bucket_i,
                         delta=self.delta, cost_straight=self.costs[0],
                         cost_diag=self.costs[1], light=light,
                         backend="pallas", interpret=self.interpret)
        return out.reshape(-1), jnp.zeros((), bool)


# ---------------------------------------------------------------------------
# mesh-sharded backends (DESIGN.md §9)
# ---------------------------------------------------------------------------

_SHARD_AXIS = "shard"


def resolve_n_shards(n_shards) -> int:
    """Concrete shard count for a config's ``n_shards`` (None = every
    local device). Bounded by the device count: ``shard_map`` needs one
    device per mesh slot."""
    ndev = jax.device_count()
    if n_shards is None:
        return ndev
    if not 1 <= n_shards <= ndev:
        raise ValueError(
            f"n_shards={n_shards} needs 1..{ndev} devices (run under "
            "XLA_FLAGS=--xla_force_host_platform_device_count=K to fake "
            "a K-device host mesh)")
    return int(n_shards)


def _inf_word(packed: bool):
    return jnp.asarray(packing.INF_PACKED, jnp.int64) if packed \
        else jnp.asarray(INF32, jnp.int32)


class _ShardedMixin:
    """Shared shard_map plumbing: a 1-D mesh over ``n_shards`` devices,
    built at trace time (meshes are host objects, not pytree leaves).
    Consumers declare the static field ``n_shards``."""

    def _mesh(self):
        return compat.make_mesh((self.n_shards,), (_SHARD_AXIS,))

    def _shard_map(self, body, n_sharded_args, n_outs):
        spec = PartitionSpec(_SHARD_AXIS)
        rep = PartitionSpec()
        return compat.shard_map(
            body, mesh=self._mesh(),
            in_specs=(rep, rep) + (spec,) * n_sharded_args,
            out_specs=(rep,) * n_outs if n_outs > 1 else rep,
            check_vma=False)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ShardedEdgeBackend(_ShardedMixin, RelaxBackend):
    """Edge-centric strategy over an SPMD mesh: every device sweeps its
    own edge shard (``graphs.partition.partition_edges`` row ownership)
    into a full-width candidate buffer, then the buffers are merged with
    an all-reduce min and folded into the replicated tent. Min is
    associative and commutative on the tent words — int32 distances or
    packed int64 (cost, pred) — so the result is bitwise identical to
    the single-device ``edge`` backend for any shard count: the paper's
    CAS loop (C2) becomes a deterministic collective (DESIGN.md §9)."""

    src: jax.Array                        # int32[n_shards, E_pad]
    dst: jax.Array
    w: jax.Array
    delta: int = _static()
    n: int = _static()
    n_shards: int = _static()
    canonical: bool = _static()

    @classmethod
    def build(cls, graph: COOGraph, cfg) -> "ShardedEdgeBackend":
        shards = resolve_n_shards(cfg.n_shards)
        part = partition_edges(graph, shards)
        return cls(part.src, part.dst, part.w, cfg.delta, graph.n_nodes,
                   shards, graph_is_canonical(graph))

    def sweep(self, tent, mask, bucket_i, *, light: bool, packed: bool):
        delta, n, canonical = self.delta, self.n, self.canonical

        def body(tent_r, mask_r, src, dst, w):
            src, dst, w = src[0], dst[0], w[0]    # shed the shard dim
            words = edge_relax_words(tent_r, mask_r,
                                     src, dst, w, delta=delta, light=light,
                                     packed=packed, canonical=canonical)
            buf = jnp.full((n,), _inf_word(packed)).at[dst].min(
                words, mode="drop")
            return jnp.minimum(tent_r, lax.pmin(buf, _SHARD_AXIS))

        tent = self._shard_map(body, 3, 1)(tent, mask, self.src, self.dst,
                                           self.w)
        return tent, jnp.zeros((), bool)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ShardedEllBackend(_ShardedMixin, RelaxBackend):
    """Frontier-centric strategy over an SPMD mesh: each device compacts
    the frontier slice of its owned vertex range and expands its local
    light/heavy ELL row block (``graphs.partition.partition_ell``), then
    candidates merge with the same all-reduce min word schedule as
    ``sharded_edge``. ``cap`` is the *per-shard* compaction capacity
    (default: the full owned range, which cannot overflow); the overflow
    flag is any-reduced across shards."""

    part: ELLPartition
    delta: int = _static()
    n: int = _static()
    n_shards: int = _static()
    cap: int = _static()
    canonical: bool = _static()

    @classmethod
    def build(cls, graph: COOGraph, cfg) -> "ShardedEllBackend":
        shards = resolve_n_shards(cfg.n_shards)
        part = partition_ell(graph, shards, cfg.delta)
        cap = min(cfg.frontier_cap or part.shard_nodes, part.shard_nodes)
        return cls(part, cfg.delta, graph.n_nodes, shards, cap,
                   graph_is_canonical(graph))

    def sweep(self, tent, mask, bucket_i, *, light: bool, packed: bool):
        part = self.part
        nbr = part.light_nbr if light else part.heavy_nbr
        w_ell = part.light_w if light else part.heavy_w
        n, s_nodes, cap = self.n, part.shard_nodes, self.cap
        canonical = self.canonical
        n_pad = self.n_shards * s_nodes

        def body(tent_r, mask_r, nbr_s, w_s):
            nbr_s, w_s = nbr_s[0], w_s[0]         # (S + 1, D)
            base = lax.axis_index(_SHARD_AXIS) * s_nodes
            maskp = jnp.pad(mask_r, (0, n_pad - n))
            local = lax.dynamic_slice_in_dim(maskp, base, s_nodes)
            lidx = jnp.nonzero(local, size=cap,
                               fill_value=s_nodes)[0].astype(jnp.int32)
            over = local.sum() > cap
            # global ids of the compacted rows; sentinel slots gather INF
            gidx = jnp.where(lidx < s_nodes, lidx + base, n).astype(jnp.int32)
            rows_n = nbr_s[lidx]                  # (cap, D), global ids
            rows_w = w_s[lidx]
            words = ell_relax_words(tent_r, gidx,
                                    rows_n, rows_w, n=n, packed=packed,
                                    canonical=canonical)
            buf = jnp.full((n,), _inf_word(packed)).at[rows_n.ravel()].min(
                words.ravel(), mode="drop")
            tent_out = jnp.minimum(tent_r, lax.pmin(buf, _SHARD_AXIS))
            over_all = lax.pmax(over.astype(jnp.int32), _SHARD_AXIS) > 0
            return tent_out, over_all

        return self._shard_map(body, 2, 2)(tent, mask, nbr, w_ell)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ShardedFusedBackend(ShardedEllBackend):
    """Fused frontier strategy over an SPMD mesh: each device runs the
    ``kernels/frontier_relax`` step on its *owned* vertex slice (scan +
    compact + local ELL row gather with global neighbor ids), then the
    iteration's three state updates merge with deterministic collectives
    — tent words through the same all-reduce min as every sharded
    backend (DESIGN.md §9), ``explored`` through ``pmin`` (each shard
    lowers only its owned frontier entries, and a frontier member's
    tent is strictly below its explored mark, so the element-wise min
    over per-shard copies IS the sequential update), and the settled
    mask / any / overflow flags through ``pmax``. Ownership is disjoint
    and min/max are associative-commutative, so the result is bitwise
    the single-device ``fused`` iteration for any shard count."""

    supports_fused_light = True

    kernel: bool = _static()
    interpret: bool = _static()

    @property
    def supports_vmap(self):
        return not self.kernel

    @classmethod
    def build(cls, graph: COOGraph, cfg) -> "ShardedFusedBackend":
        shards = resolve_n_shards(cfg.n_shards)
        part = partition_ell(graph, shards, cfg.delta)
        cap = min(cfg.frontier_cap or part.shard_nodes, part.shard_nodes)
        return cls(part, cfg.delta, graph.n_nodes, shards, cap,
                   graph_is_canonical(graph), _kernel_viable(cfg),
                   cfg.interpret)

    def fused_iter(self, tent, explored, in_s, bucket_i, *, packed: bool):
        part = self.part
        n, s_nodes, cap = self.n, part.shard_nodes, self.cap
        delta, canonical = self.delta, self.canonical
        kernel, interpret = self.kernel, self.interpret
        n_pad = self.n_shards * s_nodes

        def body(tent_r, explored_r, in_s_r, i_r, nbr_s, w_s):
            nbr_s, w_s = nbr_s[0], w_s[0]         # (S + 1, D)
            base = lax.axis_index(_SHARD_AXIS) * s_nodes
            d = dist_of(tent_r, packed)
            dp = jnp.pad(d, (0, n_pad - n), constant_values=INF32)
            ep = jnp.pad(explored_r, (0, n_pad - n), constant_values=INF32)
            d_loc = lax.dynamic_slice_in_dim(dp, base, s_nodes)
            e_loc = lax.dynamic_slice_in_dim(ep, base, s_nodes)
            fidx, rows_n, rows_w, count, any_l, _ = frontier_relax(
                d_loc, e_loc, i_r, nbr_s, w_s, delta=delta, cap=cap,
                base=base, sent=n, backend="pallas" if kernel else "ref",
                interpret=interpret)
            d_f = jnp.take(d, fidx, mode="fill", fill_value=INF32)
            explored_out = lax.pmin(
                explored_r.at[fidx].set(d_f, mode="drop"), _SHARD_AXIS)
            in_s_out = lax.pmax(
                in_s_r.at[fidx].set(True, mode="drop").astype(jnp.int32),
                _SHARD_AXIS) > 0
            words = ell_relax_words(tent_r, fidx, rows_n, rows_w, n=n,
                                    packed=packed, canonical=canonical)
            buf = jnp.full((n,), _inf_word(packed)).at[rows_n.ravel()].min(
                words.ravel(), mode="drop")
            tent_out = jnp.minimum(tent_r, lax.pmin(buf, _SHARD_AXIS))
            any_all = lax.pmax(any_l.astype(jnp.int32), _SHARD_AXIS) > 0
            over = (count > cap).astype(jnp.int32)
            over_all = lax.pmax(over, _SHARD_AXIS) > 0
            return tent_out, explored_out, in_s_out, any_all, over_all

        rep, spec = PartitionSpec(), PartitionSpec(_SHARD_AXIS)
        fn = compat.shard_map(
            body, mesh=self._mesh(),
            in_specs=(rep, rep, rep, rep, spec, spec),
            out_specs=(rep, rep, rep, rep, rep),
            check_vma=False)       # no replication rule for pallas_call
        return fn(tent, explored, in_s, jnp.asarray(bucket_i, jnp.int32),
                  part.light_nbr, part.light_w)

    def fused_next(self, dist, explored, bucket_i):
        """Replicated next-bucket min: the post-heavy scan runs on the
        already-merged tent, so the plain jnp scan is both cheapest and
        trivially bitwise (same formulas as the kernel output)."""
        return scan_bucket(dist, explored, bucket_i, delta=self.delta)[2]


def make_backend(graph: COOGraph, cfg, free_mask=None) -> RelaxBackend:
    """Route a (graph, config) pair to its backend. ``free_mask`` marks
    the game-map graph class: under ``strategy='pallas'`` it selects the
    grid-stencil kernel instead of the ELL kernels. ``cfg="auto"``
    consults the tuning subsystem (estimator + cache, DESIGN.md §7)."""
    if isinstance(cfg, str):
        from repro.tune import resolve_config   # lazy: tune imports core
        if cfg != "auto":
            raise ValueError(f"unknown config string {cfg!r}")
        cfg = resolve_config(graph, free_mask=free_mask, sources=None)
    if cfg.strategy == "edge":
        return EdgeBackend.build(graph, cfg)
    if cfg.strategy == "ell":
        return EllBackend.build(graph, cfg)
    if cfg.strategy == "fused":
        return FusedBackend.build(graph, cfg)
    if cfg.strategy == "sharded_edge":
        return ShardedEdgeBackend.build(graph, cfg)
    if cfg.strategy == "sharded_ell":
        return ShardedEllBackend.build(graph, cfg)
    if cfg.strategy == "sharded_fused":
        return ShardedFusedBackend.build(graph, cfg)
    assert cfg.strategy == "pallas", cfg.strategy
    if free_mask is not None:
        if cfg.pred_mode == "packed":
            raise ValueError(
                "grid-stencil pallas backend carries int32 distances only; "
                "use pred_mode='argmin' (post-hoc tree recovery)")
        return GridPallasBackend.build(graph, cfg, free_mask)
    return PallasEllBackend.build(graph, cfg)
