"""Core: the paper's contribution — parallel Δ-stepping SSSP in JAX."""
from repro.core.backends import (
    EdgeBackend,
    EllBackend,
    FusedBackend,
    GridPallasBackend,
    PallasEllBackend,
    RelaxBackend,
    ShardedEdgeBackend,
    ShardedEllBackend,
    ShardedFusedBackend,
    edge_sweep,
    make_backend,
    resolve_n_shards,
    scan_bucket,
)
from repro.core.delta_stepping import (
    DeltaConfig,
    DeltaSteppingSolver,
    SSSPResult,
    delta_stepping,
    pred_argmin,
)
from repro.core.ref import (
    bellman_ford,
    dijkstra,
    validate_pred_tree,
    walk_pred_tree,
)

__all__ = [
    "DeltaConfig",
    "DeltaSteppingSolver",
    "SSSPResult",
    "delta_stepping",
    "edge_sweep",
    "pred_argmin",
    "RelaxBackend",
    "EdgeBackend",
    "EllBackend",
    "FusedBackend",
    "PallasEllBackend",
    "GridPallasBackend",
    "ShardedEdgeBackend",
    "ShardedEllBackend",
    "ShardedFusedBackend",
    "make_backend",
    "resolve_n_shards",
    "scan_bucket",
    "dijkstra",
    "bellman_ford",
    "validate_pred_tree",
    "walk_pred_tree",
]
