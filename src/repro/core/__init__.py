"""Core: the paper's contribution — parallel Δ-stepping SSSP in JAX."""
from repro.core.delta_stepping import (
    DeltaConfig,
    DeltaSteppingSolver,
    SSSPResult,
    delta_stepping,
    edge_sweep,
    pred_argmin,
)
from repro.core.ref import bellman_ford, dijkstra, validate_pred_tree

__all__ = [
    "DeltaConfig",
    "DeltaSteppingSolver",
    "SSSPResult",
    "delta_stepping",
    "edge_sweep",
    "pred_argmin",
    "dijkstra",
    "bellman_ford",
    "validate_pred_tree",
]
