"""Game-map Δ-stepping on the occupancy grid itself (paper §4 'Game
Maps'). The regular 8-neighbour structure means no preprocessing (the
light/heavy classification of a move is known statically from its cost),
and the relaxation is a masked min-plus stencil executed by the
``grid_relax`` Pallas kernel (or its jnp oracle).

Bucket semantics match the generic engine: with straight cost 10 and
diagonal cost 14 under the paper's Δ = 13, the light phase sweeps
straight moves to a fixpoint and one heavy pass relaxes diagonals.
Unlike the sparse engines there is no explored/S bookkeeping: re-relaxing
an unchanged cell is idempotent and free inside a dense tile, so the
fixpoint test is simply 'did the sweep change anything' — the same
O(|V|)-scan-per-iteration trade the paper defends for its bucket array.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.graphs.structures import INF32
from repro.kernels.grid_relax import grid_relax

_IMAX = jnp.int32(2**31 - 1)


@dataclasses.dataclass(frozen=True)
class GridDeltaConfig:
    delta: int = 13
    cost_straight: int = 10
    cost_diag: int = 14
    backend: str = "ref"        # 'pallas' | 'ref' (pure jnp)
    block_rows: int = 64
    interpret: bool = False     # pallas interpret mode (CPU validation)


class GridSSSPResult(NamedTuple):
    dist: jax.Array          # int32[H, W]; INF32 = unreachable/blocked
    outer_iters: jax.Array
    inner_iters: jax.Array


@partial(jax.jit, static_argnames=("cfg",))
def _solve_grid(free, source_rc, cfg: GridDeltaConfig):
    h, w = free.shape
    delta = cfg.delta
    sweep = partial(grid_relax, delta=delta, cost_straight=cfg.cost_straight,
                    cost_diag=cfg.cost_diag, backend=cfg.backend,
                    block_rows=cfg.block_rows, interpret=cfg.interpret)
    r0, c0 = source_rc
    tent0 = jnp.full((h, w), INF32, jnp.int32).at[r0, c0].set(0)
    tent0 = jnp.where(free, tent0, INF32)

    def light_phase(tent, i, inner):
        def cond(c):
            return c[1]

        def body(c):
            tent, _, inner = c
            new = sweep(tent, free, i, light=True)
            changed = (new != tent).any()
            return (new, changed, inner + 1)

        tent, _, inner = lax.while_loop(
            cond, body, (tent, jnp.asarray(True), inner))
        return tent, inner

    def outer_body(c):
        tent, i, outer, inner = c
        tent, inner = light_phase(tent, i, inner)
        tent = sweep(tent, free, i, light=False)   # heavy pass from B_i
        b = jnp.where(tent < INF32, tent // delta, _IMAX)
        b = jnp.where(b > i, b, _IMAX)
        return (tent, b.min(), outer + 1, inner)

    def outer_cond(c):
        return c[1] < _IMAX

    tent, _, outer, inner = lax.while_loop(
        outer_cond, outer_body,
        (tent0, jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32),
         jnp.zeros((), jnp.int32)))
    return tent, outer, inner


class GridDeltaSolver:
    def __init__(self, free_mask, cfg: GridDeltaConfig = GridDeltaConfig()):
        self.free = jnp.asarray(free_mask, bool)
        self.cfg = cfg

    def solve(self, source_rc: Tuple[int, int]) -> GridSSSPResult:
        tent, outer, inner = _solve_grid(
            self.free, jnp.asarray(source_rc, jnp.int32), self.cfg)
        return GridSSSPResult(tent, outer, inner)
