"""Arch/shape registry: the (architecture × input-shape) matrix.

``--arch <id>`` everywhere resolves through here. Shapes are per-family
(the assignment pairs each arch with its own shape set); LM decode
shapes lower ``serve_step``, everything else lowers ``train_step`` or
the family's serving entry point.
"""
from __future__ import annotations

import importlib
from typing import List

from repro.configs.base import ShapeSpec

ARCH_IDS = [
    "kimi-k2-1t-a32b", "moonshot-v1-16b-a3b", "granite-20b", "gemma2-9b",
    "yi-34b",
    "gin-tu", "gatedgcn", "gat-cora", "schnet",
    "dlrm-mlperf",
    # the paper's own workloads (extra, not part of the 40 assigned cells)
    "sssp-smallworld", "sssp-rmat", "sssp-gamemap",
]

LM_SHAPES = [
    ShapeSpec("train_4k", "train", seq_len=4096, global_batch=256),
    ShapeSpec("prefill_32k", "prefill", seq_len=32768, global_batch=32),
    ShapeSpec("decode_32k", "decode", seq_len=32768, global_batch=128),
    ShapeSpec("long_500k", "decode", seq_len=524288, global_batch=1),
]

GNN_SHAPES = [
    ShapeSpec("full_graph_sm", "train",
              extras=(("n_nodes", 2708), ("n_edges", 10556))),
    ShapeSpec("minibatch_lg", "train",
              extras=(("n_nodes", 232965), ("n_edges", 114615892),
                      ("batch_nodes", 1024), ("fanout", (15, 10)))),
    ShapeSpec("ogb_products", "train",
              extras=(("n_nodes", 2449029), ("n_edges", 61859140))),
    ShapeSpec("molecule", "train",
              extras=(("n_nodes", 30), ("n_edges", 64), ("batch", 128))),
]

RECSYS_SHAPES = [
    ShapeSpec("train_batch", "train", global_batch=65536),
    ShapeSpec("serve_p99", "serve", global_batch=512),
    ShapeSpec("serve_bulk", "serve", global_batch=262144),
    ShapeSpec("retrieval_cand", "serve", global_batch=1,
              extras=(("n_candidates", 1_000_000),)),
]

SSSP_SHAPES = [
    ShapeSpec("multi_source", "solve"),
]

_FAMILY_SHAPES = {"lm": LM_SHAPES, "gnn": GNN_SHAPES,
                  "recsys": RECSYS_SHAPES, "sssp": SSSP_SHAPES}


def _module(arch_id: str):
    return importlib.import_module(
        "repro.configs." + arch_id.replace("-", "_"))


def family_of(arch_id: str) -> str:
    return _module(arch_id).FAMILY


def get_config(arch_id: str, smoke: bool = False):
    mod = _module(arch_id)
    return mod.smoke() if smoke else mod.CONFIG


def shapes_for(arch_id: str) -> List[ShapeSpec]:
    return list(_FAMILY_SHAPES[family_of(arch_id)])


def get_shape(arch_id: str, shape_name: str) -> ShapeSpec:
    for s in shapes_for(arch_id):
        if s.name == shape_name:
            return s
    raise KeyError(f"{arch_id} has no shape {shape_name!r}; "
                   f"available: {[s.name for s in shapes_for(arch_id)]}")


def assigned_cells() -> List[tuple]:
    """The 40 assigned (arch, shape) cells (SSSP extras excluded)."""
    out = []
    for a in ARCH_IDS:
        if a.startswith("sssp-"):
            continue
        for s in shapes_for(a):
            out.append((a, s.name))
    return out


def all_cells() -> List[tuple]:
    out = []
    for a in ARCH_IDS:
        for s in shapes_for(a):
            out.append((a, s.name))
    return out
