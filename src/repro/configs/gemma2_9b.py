"""Arch config: gemma2-9b (family: lm). Exact spec in lm_archs.py."""
from repro.configs.lm_archs import GEMMA2_9B as CONFIG, smoke as _smoke

FAMILY = "lm"


def smoke():
    return _smoke(CONFIG)
