"""The four assigned GNN architectures (exact hyper-parameters from the
cited papers) and their four shape cells. Feature/class dimensions are a
property of the *shape* (dataset), applied via ``with_shape_dims``.
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import GNNConfig

# GIN [arXiv:1810.00826]: 5 layers, hidden 64, sum aggregator, learnable ε
GIN_TU = GNNConfig(name="gin-tu", arch="gin", n_layers=5, d_hidden=64,
                   eps_learnable=True)

# GatedGCN [arXiv:2003.00982]: 16 layers, hidden 70, gated aggregator
GATEDGCN = GNNConfig(name="gatedgcn", arch="gatedgcn", n_layers=16,
                     d_hidden=70)

# GAT on Cora [arXiv:1710.10903]: 2 layers, 8 hidden units, 8 heads
GAT_CORA = GNNConfig(name="gat-cora", arch="gat", n_layers=2, d_hidden=8,
                     n_heads=8)

# SchNet [arXiv:1706.08566]: 3 interactions, hidden 64, 300 RBF, cutoff 10
SCHNET = GNNConfig(name="schnet", arch="schnet", n_layers=3, d_hidden=64,
                   n_rbf=300, cutoff=10.0)

# (d_feat, n_classes) per shape cell — Cora / Reddit-like / ogbn-products /
# TU-molecule conventions.
SHAPE_DIMS = {
    "full_graph_sm": (1433, 7),
    "minibatch_lg": (602, 41),
    "ogb_products": (100, 47),
    "molecule": (28, 2),
}


def with_shape_dims(cfg: GNNConfig, shape_name: str) -> GNNConfig:
    d_in, n_classes = SHAPE_DIMS[shape_name]
    return dataclasses.replace(cfg, d_in=d_in, n_classes=n_classes)


def smoke(cfg: GNNConfig) -> GNNConfig:
    return dataclasses.replace(
        cfg, name=cfg.name + "-smoke",
        n_layers=min(cfg.n_layers, 2), d_hidden=16,
        d_in=12, n_classes=5, n_rbf=16, cutoff=5.0)
