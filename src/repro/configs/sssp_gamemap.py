"""Arch config: sssp-gamemap (family: sssp). Exact spec in sssp_archs.py."""
from repro.configs.sssp_archs import SSSP_GAMEMAP as CONFIG, smoke as _smoke

FAMILY = "sssp"


def smoke():
    return _smoke(CONFIG)
