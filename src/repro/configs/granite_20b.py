"""Arch config: granite-20b (family: lm). Exact spec in lm_archs.py."""
from repro.configs.lm_archs import GRANITE_20B as CONFIG, smoke as _smoke

FAMILY = "lm"


def smoke():
    return _smoke(CONFIG)
