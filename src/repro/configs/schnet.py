"""Arch config: schnet (family: gnn). Exact spec in gnn_archs.py."""
from repro.configs.gnn_archs import SCHNET as CONFIG, smoke as _smoke

FAMILY = "gnn"


def smoke():
    return _smoke(CONFIG)
