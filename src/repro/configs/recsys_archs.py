"""DLRM MLPerf benchmark config (Criteo 1TB) [arXiv:1906.00091].

Table cardinalities are the canonical MLPerf/Criteo-Terabyte day-feature
counts (26 categorical features; the three ~40M tables dominate — these
are the row-sharded ones).
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import DLRMConfig

CRITEO_1TB_VOCABS = (
    39884406, 39043, 17289, 7420, 20263, 3, 7120, 1543, 63, 38532951,
    2953546, 403346, 10, 2208, 11938, 155, 4, 976, 14, 39979771, 25641295,
    39664984, 585935, 12972, 108, 36,
)

DLRM_MLPERF = DLRMConfig(
    name="dlrm-mlperf",
    n_dense=13,
    embed_dim=128,
    vocab_sizes=CRITEO_1TB_VOCABS,
    bot_mlp=(512, 256, 128),
    top_mlp=(1024, 1024, 512, 256, 1),
    interaction="dot",
)


def smoke(cfg: DLRMConfig) -> DLRMConfig:
    return dataclasses.replace(
        cfg, name=cfg.name + "-smoke",
        embed_dim=16,
        vocab_sizes=tuple([97, 13, 211, 5, 53] + [11] * 21),
        bot_mlp=(32, 16), top_mlp=(64, 32, 1))
