"""Arch config: gat-cora (family: gnn). Exact spec in gnn_archs.py."""
from repro.configs.gnn_archs import GAT_CORA as CONFIG, smoke as _smoke

FAMILY = "gnn"


def smoke():
    return _smoke(CONFIG)
