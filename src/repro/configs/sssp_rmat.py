"""Arch config: sssp-rmat (family: sssp). Exact spec in sssp_archs.py."""
from repro.configs.sssp_archs import SSSP_RMAT as CONFIG, smoke as _smoke

FAMILY = "sssp"


def smoke():
    return _smoke(CONFIG)
