"""Arch config: gin-tu (family: gnn). Exact spec in gnn_archs.py."""
from repro.configs.gnn_archs import GIN_TU as CONFIG, smoke as _smoke

FAMILY = "gnn"


def smoke():
    return _smoke(CONFIG)
