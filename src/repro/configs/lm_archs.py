"""The five assigned LM-family architectures, exact configs from the
public sources cited in the assignment, plus reduced smoke variants.

Memory-feasibility choices for the production shapes (DESIGN.md §6):
bf16 params + remat + microbatching for the two MoE configs; chunked
attention everywhere (the 4k×4k score tile would otherwise dominate).
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import LMConfig, MoEConfig

# ---------------------------------------------------------------------------
# kimi-k2-1t-a32b — trillion-param MoE [arXiv:2501.kimi2]
# 61L d=7168 64H (GQA kv=8) expert d_ff=2048, vocab 163840, MoE 384e top-8.
# Spec lists routed experts only (no shared-expert term), so n_shared = 0.
# Optimizer for this config must be factored (adafactor): unfactored Adam
# would need ~16 bytes/param = 16 TB of state.
KIMI_K2 = LMConfig(
    name="kimi-k2-1t-a32b",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=2048, vocab=163840,
    moe=MoEConfig(n_experts=384, top_k=8, d_ff_expert=2048,
                  capacity_factor=1.25, impl="ep"),
    mlp="swiglu", rope_theta=50000.0,
    dtype="bfloat16", param_dtype="bfloat16",
    remat=True, attention_chunk=512, max_seq_len=131072,
)

# moonshot-v1-16b-a3b — Moonlight 16B (64e top-6) [hf:moonshotai]
MOONSHOT_16B = LMConfig(
    name="moonshot-v1-16b-a3b",
    n_layers=48, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=1408, vocab=163840,
    moe=MoEConfig(n_experts=64, top_k=6, d_ff_expert=1408,
                  capacity_factor=1.25, impl="ep"),
    mlp="swiglu", dtype="bfloat16", param_dtype="bfloat16",
    remat=True, attention_chunk=512, max_seq_len=131072,
)

# granite-20b — code model, MQA (kv=1), GELU MLP [arXiv:2405.04324]
GRANITE_20B = LMConfig(
    name="granite-20b",
    n_layers=52, d_model=6144, n_heads=48, n_kv_heads=1, head_dim=128,
    d_ff=24576, vocab=49152, mlp="gelu",
    dtype="bfloat16", param_dtype="bfloat16",
    remat=True, attention_chunk=512, max_seq_len=131072,
)

# gemma2-9b — alternating local(4096)/global attention, GeGLU,
# attn softcap 50 / final logit softcap 30, tied embeddings, head_dim 256
# [arXiv:2408.00118]
GEMMA2_9B = LMConfig(
    name="gemma2-9b",
    n_layers=42, d_model=3584, n_heads=16, n_kv_heads=8, head_dim=256,
    d_ff=14336, vocab=256000, mlp="geglu",
    window=4096, window_pattern=2,
    attn_softcap=50.0, logit_softcap=30.0, tie_embeddings=True,
    dtype="bfloat16", param_dtype="bfloat16",
    remat=True, attention_chunk=512, max_seq_len=131072,
)

# yi-34b — llama-arch GQA [arXiv:2403.04652]
YI_34B = LMConfig(
    name="yi-34b",
    n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8, head_dim=128,
    d_ff=20480, vocab=64000, mlp="swiglu", rope_theta=5000000.0,
    dtype="bfloat16", param_dtype="bfloat16",
    remat=True, attention_chunk=512, max_seq_len=131072,
)


def smoke(cfg: LMConfig) -> LMConfig:
    """Reduced same-family config: thin layers, few experts, small vocab —
    runs a CPU train/serve step in seconds."""
    moe = None
    if cfg.moe is not None:
        moe = dataclasses.replace(cfg.moe, n_experts=8,
                                  top_k=min(cfg.moe.top_k, 2),
                                  d_ff_expert=64)
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=max(1, min(cfg.n_kv_heads, 2)),
        head_dim=16, d_ff=128, vocab=512, moe=moe,
        window=8 if cfg.window else None,
        dtype="float32", param_dtype="float32",
        remat=False, attention_chunk=16, max_seq_len=256,
    )
