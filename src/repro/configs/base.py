"""Config schema for every architecture family the framework supports.

Configs are frozen dataclasses (hashable → usable as jit static args).
Exact assigned-architecture instances live in sibling modules
(one file per arch id); reduced smoke variants are derived with
``dataclasses.replace`` by each arch module's ``smoke()``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    impl: str = "dense"          # 'dense' (auto-sharded einsum) | 'ep'
    router_aux_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None       # default d_model // n_heads
    mlp: str = "swiglu"                  # 'swiglu' | 'geglu' | 'gelu'
    moe: Optional[MoEConfig] = None
    window: Optional[int] = None         # sliding-attention window size
    window_pattern: int = 1              # 1 = every layer local; 2 = alternate
    attn_softcap: Optional[float] = None
    logit_softcap: Optional[float] = None
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    dtype: str = "float32"               # activation/compute dtype
    param_dtype: str = "float32"
    remat: bool = False                  # per-layer activation checkpointing
    attention_chunk: Optional[int] = None  # query-block size (flash-style)
    max_seq_len: int = 8192              # serving cache length

    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def adtype(self):
        return jnp.dtype(self.dtype)

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    def layer_is_local(self, layer: int) -> bool:
        """gemma2-style alternating local/global attention."""
        if self.window is None:
            return False
        return layer % self.window_pattern == 0

    def n_params(self) -> int:
        """Total parameter count (used by roofline MODEL_FLOPS)."""
        d, f, v, hd = self.d_model, self.d_ff, self.vocab, self.head_dim_
        attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) \
            + self.n_heads * hd * d
        if self.moe:
            m = self.moe
            gate_mats = 3 if self.mlp in ("swiglu", "geglu") else 2
            ffn = (m.n_experts + m.n_shared_experts) * gate_mats * d \
                * m.d_ff_expert + d * m.n_experts  # + router
        else:
            gate_mats = 3 if self.mlp in ("swiglu", "geglu") else 2
            ffn = gate_mats * d * f
        per_layer = attn + ffn + 2 * d
        embed = v * d * (1 if self.tie_embeddings else 2)
        return self.n_layers * per_layer + embed + d

    def n_active_params(self) -> int:
        """Active params per token (MoE: only routed experts count)."""
        if not self.moe:
            return self.n_params()
        m = self.moe
        d = self.d_model
        gate_mats = 3 if self.mlp in ("swiglu", "geglu") else 2
        dense_ffn = gate_mats * d * m.d_ff_expert
        active_ffn = (m.top_k + m.n_shared_experts) * dense_ffn \
            + d * m.n_experts
        full_ffn = (m.n_experts + m.n_shared_experts) * dense_ffn \
            + d * m.n_experts
        return self.n_params() - self.n_layers * (full_ffn - active_ffn)


@dataclasses.dataclass(frozen=True)
class GNNConfig:
    name: str
    arch: str                   # 'gin' | 'gatedgcn' | 'gat' | 'schnet'
    n_layers: int
    d_hidden: int
    d_in: int = 128
    n_classes: int = 40
    n_heads: int = 1            # gat
    eps_learnable: bool = True  # gin
    n_rbf: int = 300            # schnet radial basis size
    cutoff: float = 10.0        # schnet
    dtype: str = "float32"

    def head_hidden(self) -> int:
        return self.d_hidden * self.n_heads if self.arch == "gat" \
            else self.d_hidden


@dataclasses.dataclass(frozen=True)
class DLRMConfig:
    name: str
    n_dense: int = 13
    embed_dim: int = 128
    vocab_sizes: Tuple[int, ...] = ()
    bot_mlp: Tuple[int, ...] = (512, 256, 128)
    top_mlp: Tuple[int, ...] = (1024, 1024, 512, 256, 1)
    interaction: str = "dot"
    dtype: str = "float32"

    @property
    def n_sparse(self) -> int:
        return len(self.vocab_sizes)


@dataclasses.dataclass(frozen=True)
class SSSPConfig:
    """The paper's own workload as a launchable 'architecture'."""
    name: str
    graph: str                   # 'smallworld' | 'rmat' | 'gamemap' | 'lattice'
    n_nodes: int
    avg_degree: int
    delta: int = 10
    n_sources: int = 16          # batched multi-source
    combine: str = "reduce_scatter"
    local_steps: int = 1


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One input-shape cell of the (arch × shape) matrix."""
    name: str
    kind: str                    # 'train' | 'prefill' | 'decode' | ...
    seq_len: int = 0
    global_batch: int = 0
    extras: tuple = ()           # family-specific (sorted key/value pairs)

    def extra(self, key, default=None):
        return dict(self.extras).get(key, default)
