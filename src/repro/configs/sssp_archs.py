"""The paper's own workloads as launchable configs — the Δ-stepping
engine is a first-class 'architecture' of the framework, with the same
dry-run/roofline treatment as the assigned model zoo.

Production sizes follow the paper's largest experiments (small-world
|V| = 6M k=60; RMat |V| = 2M |E| = 40M; 3000×3000 game map), batched
over 16 sources on the data axis (the multi-source regime of the
paper's betweenness-centrality citation [4]).
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import SSSPConfig

SSSP_SMALLWORLD = SSSPConfig(
    name="sssp-smallworld", graph="smallworld",
    n_nodes=6_000_000, avg_degree=60, delta=10, n_sources=16,
    combine="reduce_scatter")

SSSP_RMAT = SSSPConfig(
    name="sssp-rmat", graph="rmat",
    n_nodes=2_000_000, avg_degree=20, delta=10, n_sources=16,
    combine="reduce_scatter")

SSSP_GAMEMAP = SSSPConfig(
    name="sssp-gamemap", graph="gamemap",
    n_nodes=9_000_000, avg_degree=8, delta=13, n_sources=16,
    combine="reduce_scatter")


def smoke(cfg: SSSPConfig) -> SSSPConfig:
    return dataclasses.replace(cfg, name=cfg.name + "-smoke",
                               n_nodes=512, avg_degree=6, n_sources=2)
