"""Arch config: dlrm-mlperf (family: recsys). Exact spec in recsys_archs.py."""
from repro.configs.recsys_archs import DLRM_MLPERF as CONFIG, smoke as _smoke

FAMILY = "recsys"


def smoke():
    return _smoke(CONFIG)
