"""Arch config: kimi-k2-1t-a32b (family: lm). Exact spec in lm_archs.py."""
from repro.configs.lm_archs import KIMI_K2 as CONFIG, smoke as _smoke

FAMILY = "lm"


def smoke():
    return _smoke(CONFIG)
