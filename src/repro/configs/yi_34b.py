"""Arch config: yi-34b (family: lm). Exact spec in lm_archs.py."""
from repro.configs.lm_archs import YI_34B as CONFIG, smoke as _smoke

FAMILY = "lm"


def smoke():
    return _smoke(CONFIG)
