"""Arch config: moonshot-v1-16b-a3b (family: lm). Exact spec in lm_archs.py."""
from repro.configs.lm_archs import MOONSHOT_16B as CONFIG, smoke as _smoke

FAMILY = "lm"


def smoke():
    return _smoke(CONFIG)
