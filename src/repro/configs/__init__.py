from repro.configs.base import (
    DLRMConfig,
    GNNConfig,
    LMConfig,
    MoEConfig,
    ShapeSpec,
    SSSPConfig,
)
from repro.configs.registry import (
    ARCH_IDS,
    all_cells,
    assigned_cells,
    family_of,
    get_config,
    get_shape,
    shapes_for,
)

__all__ = ["LMConfig", "MoEConfig", "GNNConfig", "DLRMConfig", "SSSPConfig",
           "ShapeSpec", "ARCH_IDS", "family_of", "get_config", "get_shape",
           "shapes_for", "assigned_cells", "all_cells"]
