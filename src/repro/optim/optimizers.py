"""Optimizers as pure (init, update) pairs over parameter pytrees.

AdamW (ZeRO-1 friendly: states inherit parameter sharding), Adafactor
(factored second moment — the only feasible choice for the 1T MoE config,
DESIGN.md §6), SGD-momentum, global-norm clipping, warmup-cosine
schedule. No optax dependency — the container is offline.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable
    update: Callable  # (grads, state, params) -> (new_params, new_state)


def global_norm(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


def warmup_cosine(base_lr: float, warmup: int, total: int,
                  final_frac: float = 0.1):
    def lr(step):
        step = step.astype(jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        t = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup, warm, base_lr * cos)
    return lr


def adamw(lr, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.0,
          clip_norm: float | None = 1.0):
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
        return {"m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params),
                "step": jnp.zeros((), jnp.int32),
                "grad_norm": jnp.zeros((), jnp.float32)}

    def update(grads, state, params):
        if clip_norm is not None:
            grads, gnorm = clip_by_global_norm(grads, clip_norm)
        else:
            gnorm = global_norm(grads)
        step = state["step"] + 1
        lr_t = lr_fn(step)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(p, g, m, v):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            u = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            new_p = p.astype(jnp.float32) - lr_t * (u + weight_decay
                                                    * p.astype(jnp.float32))
            return new_p.astype(p.dtype), m, v

        out = jax.tree.map(upd, params, grads, state["m"], state["v"])
        new_p = jax.tree.map(lambda t: t[0], out,
                             is_leaf=lambda t: isinstance(t, tuple))
        new_m = jax.tree.map(lambda t: t[1], out,
                             is_leaf=lambda t: isinstance(t, tuple))
        new_v = jax.tree.map(lambda t: t[2], out,
                             is_leaf=lambda t: isinstance(t, tuple))
        return new_p, {"m": new_m, "v": new_v, "step": step,
                       "grad_norm": gnorm}

    return Optimizer(init, update)


def adafactor(lr, decay=0.8, eps=1e-30, clip_norm: float | None = 1.0,
              min_dim_size_to_factor: int = 128):
    """Factored second-moment (Shazeer & Stern): matrices keep only row
    and column RMS statistics — O(n+m) state instead of O(n·m)."""
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def _factored(shape):
        return (len(shape) >= 2 and shape[-1] >= min_dim_size_to_factor
                and shape[-2] >= min_dim_size_to_factor)

    def init(params):
        def st(p):
            if _factored(p.shape):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                        jnp.float32)}
            return {"v": jnp.zeros_like(p, dtype=jnp.float32)}
        return {"f": jax.tree.map(st, params),
                "step": jnp.zeros((), jnp.int32),
                "grad_norm": jnp.zeros((), jnp.float32)}

    def update(grads, state, params):
        if clip_norm is not None:
            grads, gnorm = clip_by_global_norm(grads, clip_norm)
        else:
            gnorm = global_norm(grads)
        step = state["step"] + 1
        beta = 1.0 - step.astype(jnp.float32) ** -decay
        lr_t = lr_fn(step)

        def upd(p, g, s):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if "vr" in s:
                vr = beta * s["vr"] + (1 - beta) * g2.mean(-1)
                vc = beta * s["vc"] + (1 - beta) * g2.mean(-2)
                denom = (vr[..., None] * vc[..., None, :]
                         / jnp.maximum(vr.mean(-1, keepdims=True)[..., None],
                                       eps))
                u = g * jax.lax.rsqrt(denom + eps)
                ns = {"vr": vr, "vc": vc}
            else:
                v = beta * s["v"] + (1 - beta) * g2
                u = g * jax.lax.rsqrt(v + eps)
                ns = {"v": v}
            # relative step clipping (RMS-1 trunc) as in the paper
            u = u / jnp.maximum(1.0, jnp.sqrt(jnp.mean(u * u)))
            new_p = (p.astype(jnp.float32) - lr_t * u).astype(p.dtype)
            return new_p, ns

        def upd_maybe_chunked(p, g, s):
            # For stacked-layer weights (ndim >= 3), run the update one
            # layer slice at a time: the elementwise f32 temps (g², u,
            # denom, p32) of a (61, 24, 7168, 64) stack are ~2.5 GiB
            # EACH — chunking turns ~15 GiB of optimizer temps into
            # ~50 MiB. Factoring is over the last two dims, so slicing
            # the leading dim is exact.
            if p.ndim >= 3 and p.size * 4 > (1 << 28):
                return jax.lax.map(lambda t: upd(*t), (p, g, s))
            return upd(p, g, s)

        flat_p, treedef = jax.tree.flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_s = treedef.flatten_up_to(state["f"])
        outs = [upd_maybe_chunked(p, g, s)
                for p, g, s in zip(flat_p, flat_g, flat_s)]
        new_p = treedef.unflatten([o[0] for o in outs])
        new_f = treedef.unflatten([o[1] for o in outs])
        return new_p, {"f": new_f, "step": step, "grad_norm": gnorm}

    return Optimizer(init, update)


def sgdm(lr, momentum=0.9, clip_norm: float | None = None):
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        return {"m": jax.tree.map(
            lambda p: jnp.zeros_like(p, dtype=jnp.float32), params),
            "step": jnp.zeros((), jnp.int32),
            "grad_norm": jnp.zeros((), jnp.float32)}

    def update(grads, state, params):
        if clip_norm is not None:
            grads, gnorm = clip_by_global_norm(grads, clip_norm)
        else:
            gnorm = global_norm(grads)
        step = state["step"] + 1
        lr_t = lr_fn(step)
        new_m = jax.tree.map(lambda m, g: momentum * m
                             + g.astype(jnp.float32), state["m"], grads)
        new_p = jax.tree.map(lambda p, m: (p.astype(jnp.float32)
                                           - lr_t * m).astype(p.dtype),
                             params, new_m)
        return new_p, {"m": new_m, "step": step, "grad_norm": gnorm}

    return Optimizer(init, update)


BY_NAME = {"adamw": adamw, "adafactor": adafactor, "sgdm": sgdm}
