from repro.optim.optimizers import (
    BY_NAME,
    Optimizer,
    adafactor,
    adamw,
    clip_by_global_norm,
    global_norm,
    sgdm,
    warmup_cosine,
)

__all__ = ["Optimizer", "adamw", "adafactor", "sgdm", "warmup_cosine",
           "clip_by_global_norm", "global_norm", "BY_NAME"]
