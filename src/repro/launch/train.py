"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch gemma2-9b --smoke \\
      --steps 50 --ckpt-dir /tmp/ckpt

Runs the end-to-end trainer (checkpoint/restart, straggler watchdog,
optional gradient compression) on whatever devices exist. Production
meshes come from ``--mesh single|multi`` (requires the 512-device
environment of the dry-run); the default uses the host devices.
"""
from __future__ import annotations

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-interval", type=int, default=25)
    ap.add_argument("--compression", default="none",
                    choices=["none", "int8", "topk"])
    ap.add_argument("--microbatch", type=int, default=None)
    args = ap.parse_args()

    import jax

    from repro.configs import family_of, get_config
    from repro.data import SyntheticClicks, SyntheticTokens
    from repro.optim import adamw, warmup_cosine
    from repro.train import Trainer, TrainerConfig

    family = family_of(args.arch)
    cfg = get_config(args.arch, smoke=args.smoke)
    opt = adamw(warmup_cosine(args.lr, 10, args.steps))
    key = jax.random.key(0)

    if family == "lm":
        from repro.models.transformer import init_lm, lm_loss
        params = init_lm(cfg, key)
        data = SyntheticTokens(vocab=cfg.vocab, batch=args.batch,
                               seq_len=args.seq)
        loss_fn = lambda p, b: lm_loss(p, cfg, b["tokens"], b["labels"],
                                       loss_chunk=min(args.seq, 512))
    elif family == "recsys":
        from repro.models.dlrm import dlrm_loss, init_dlrm
        params = init_dlrm(cfg, key)
        data = SyntheticClicks(cfg.vocab_sizes, cfg.n_dense,
                               batch=args.batch)
        loss_fn = lambda p, b: dlrm_loss(p, cfg, b["dense"], b["sparse"],
                                         b["labels"])
    elif family == "gnn":
        from repro.data import gnn_full_batch
        from repro.graphs import random_graph
        from repro.models.gnn import gnn_loss, init_gnn

        g = random_graph(512, 4096, seed=0)
        fb = gnn_full_batch(512, cfg.d_in, cfg.n_classes, seed=0)

        class _GraphData:
            def batch_at(self, step):
                return dict(fb, src=g.src, dst=g.dst)

        data = _GraphData()
        params = init_gnn(cfg, key)
        loss_fn = lambda p, b: gnn_loss(
            p, cfg, dict(x=b["x"], src=b["src"], dst=b["dst"]),
            b["labels"], b["label_mask"])
    else:
        raise SystemExit(f"use launch.sssp for family {family!r}")

    trainer = Trainer(
        loss_fn, opt, params, data,
        TrainerConfig(total_steps=args.steps, ckpt_dir=args.ckpt_dir,
                      ckpt_interval=args.ckpt_interval,
                      compression=args.compression,
                      microbatch=args.microbatch))
    trainer.run()
    if trainer.history:
        first, last = trainer.history[0][1], trainer.history[-1][1]
        print(f"[train] loss {first:.4f} -> {last:.4f} over "
              f"{args.steps} steps")
    if trainer.watchdog.events:
        print(f"[train] straggler events: {trainer.watchdog.events}")


if __name__ == "__main__":
    main()
