"""SSSP launcher — the paper's workload end-to-end on real arrays.

  PYTHONPATH=src python -m repro.launch.sssp --graph smallworld \\
      --nodes 100000 --degree 20 --delta 10 --sources 4 --verify

Uses the single-device engine by default; ``--sources K`` with
``--devices 0`` solves the K sources as one batched multi-source
program (``DeltaSteppingSolver.solve_many``). ``--devices N`` (with
XLA_FLAGS=--xla_force_host_platform_device_count=N) runs the
distributed shard_map engine on an (sources × N_model) mesh.
``--strategy pallas`` routes relaxation through the Pallas kernels
(add ``--interpret`` off-TPU); on ``--graph gamemap`` that selects the
grid-stencil kernel. ``--strategy sharded_edge`` / ``sharded_ell``
selects the mesh-sharded first-class backends (DESIGN.md §9) —
relaxation partitioned over ``--shards`` devices (default: all) inside
the unified engine, composing with ``--sources`` batching and
``--tune``:

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
      PYTHONPATH=src python -m repro.launch.sssp --graph rmat \\
      --nodes 100000 --strategy sharded_edge --shards 8 --verify

The single-host path routes through the Query/Plan façade
(``repro.api``, DESIGN.md §10): one ``Engine.plan`` resolves tuning /
strategy / caps, then queries dispatch on the plan — ``--target T``
issues an early-exit ``PointToPoint`` query instead of the full solve,
and ``--p2p-mode alt|bidirectional|alt_bidirectional`` upgrades it to
goal-directed / bidirectional search over ``--landmarks K`` ALT tables
(repro.landmarks, DESIGN.md §14).

``--tune`` replaces the hand-picked ``--delta``/``--strategy`` with the
measured (Δ, backend, packing) search (repro.tune, DESIGN.md §7);
``--tune-cache PATH`` persists/reuses tuned records across runs — with
``--tune-cache`` alone, a cache hit (or the zero-measurement estimator)
picks the config without any search.
"""
from __future__ import annotations

import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--graph", default="smallworld",
                    choices=["smallworld", "rmat", "gamemap", "lattice"])
    ap.add_argument("--nodes", type=int, default=100_000)
    ap.add_argument("--degree", type=int, default=20)
    ap.add_argument("--p", type=float, default=1e-2)
    ap.add_argument("--delta", type=int, default=10)
    ap.add_argument("--strategy", default="edge",
                    choices=["edge", "ell", "pallas", "fused",
                             "sharded_edge", "sharded_ell",
                             "sharded_fused"])
    ap.add_argument("--shards", type=int, default=None,
                    help="sharded_* strategies: 1-D mesh width "
                         "(default: every local device)")
    ap.add_argument("--policy", default="delta",
                    choices=["delta", "rho", "radius"],
                    help="frontier-selection policy (DESIGN.md §15): "
                         "the paper's bucket loop, ρ-stepping or "
                         "radius-stepping over the same backend")
    ap.add_argument("--rho", type=int, default=None,
                    help="--policy rho: batch size ρ (default: "
                         "heuristic max(32, |V|/8))")
    ap.add_argument("--radius-k", type=int, default=4,
                    help="--policy radius: r(v) = k-th smallest "
                         "outgoing edge weight")
    ap.add_argument("--interpret", action="store_true",
                    help="run pallas kernels in interpret mode (CPU)")
    ap.add_argument("--sources", type=int, default=1)
    ap.add_argument("--target", type=int, default=None,
                    help="point-to-point query: early-exit solve from "
                         "source 0 to this vertex (repro.api facade)")
    ap.add_argument("--p2p-mode", default="early_exit",
                    choices=["early_exit", "alt", "bidirectional",
                             "alt_bidirectional"],
                    help="--target search mode: goal-directed (alt*) "
                         "and/or bidirectional Δ-stepping "
                         "(repro.landmarks, DESIGN.md §14)")
    ap.add_argument("--landmarks", type=int, default=None, metavar="K",
                    help="ALT landmark count for --p2p-mode alt* "
                         "(default 4)")
    ap.add_argument("--devices", type=int, default=0,
                    help="model-parallel width (0 = single-device engine)")
    ap.add_argument("--combine", default="reduce_scatter",
                    choices=["allreduce", "reduce_scatter"])
    ap.add_argument("--local-steps", type=int, default=1)
    ap.add_argument("--tune", action="store_true",
                    help="auto-tune (Δ, backend, packing) by measured "
                         "search instead of --delta/--strategy "
                         "(single-device engine only)")
    ap.add_argument("--tune-cache", default=None, metavar="PATH",
                    help="persistent JSON tuning cache; implies auto "
                         "config (cache hit or heuristic estimator)")
    ap.add_argument("--verify", action="store_true")
    args = ap.parse_args()

    import numpy as np

    from repro.graphs import (
        grid_map, partition_edges, rmat, square_lattice, watts_strogatz)

    t0 = time.perf_counter()
    free = None
    if args.graph == "smallworld":
        k = args.degree - args.degree % 2
        g = watts_strogatz(args.nodes, k, args.p, seed=0)
    elif args.graph == "rmat":
        g = rmat(args.nodes, args.nodes * args.degree, seed=0)
    elif args.graph == "gamemap":
        side = int(np.sqrt(args.nodes))
        g, free = grid_map(side, side, 0.1, seed=0)
        args.delta = 13
    else:
        g = square_lattice(int(np.sqrt(args.nodes)), weighted=True)
    print(f"[sssp] graph {args.graph}: |V|={g.n_nodes} |E|={g.n_edges} "
          f"({time.perf_counter() - t0:.1f}s to generate)")

    sources = list(range(args.sources))
    if args.devices:
        import jax
        from repro.compat import make_mesh
        from repro.core.distributed import (
            DistDeltaConfig, build_distributed_solver)
        n_dev = len(jax.devices())
        model = args.devices
        data = max(1, n_dev // model)
        mesh = make_mesh((data, model), ("data", "model"))
        part = partition_edges(g, model)
        solve = build_distributed_solver(
            part, mesh, DistDeltaConfig(delta=args.delta,
                                        combine=args.combine,
                                        local_steps=args.local_steps))
        t0 = time.perf_counter()
        dist, outer, inner = solve(np.asarray(sources, np.int32))
        dist = np.asarray(dist)
        dt = time.perf_counter() - t0
        print(f"[sssp] distributed ({data}x{model}, {args.combine}): "
              f"{dt * 1e3:.1f} ms, buckets={int(outer)}, "
              f"light sweeps={int(inner)}")
    else:
        # single-host path: the Query/Plan façade (DESIGN.md §10) —
        # resolution (tuning, caps) happens once in Engine.plan, solves
        # dispatch through the query algebra
        from repro.api import Engine, MultiSource, SingleSource, Tuning
        from repro.core import DeltaConfig
        cfg = DeltaConfig(delta=args.delta, strategy=args.strategy,
                          pred_mode="argmin", interpret=args.interpret,
                          n_shards=args.shards, policy=args.policy,
                          rho=args.rho, radius_k=args.radius_k)
        t0 = time.perf_counter()
        tuning = (Tuning(measure=args.tune, cache=args.tune_cache)
                  if (args.tune or args.tune_cache) else None)
        # sources= the ones actually being solved: a tuning-chosen
        # frontier cap is validated against exactly these
        plan = Engine(g, cfg, free_mask=free,
                      tuning=tuning).plan(sources=sources)
        cfg = plan.config
        if args.tune or args.tune_cache:
            print(f"[sssp] tuned config: Δ={cfg.delta} "
                  f"strategy={cfg.strategy} policy={cfg.policy} "
                  f"cap={cfg.frontier_cap} "
                  f"({time.perf_counter() - t0:.1f}s to tune)")
        elif cfg.policy != "delta":
            print(f"[sssp] frontier policy: {cfg.policy}")
        if cfg.strategy.startswith("sharded"):
            from repro.core import resolve_n_shards
            print(f"[sssp] mesh-sharded relaxation over "
                  f"{resolve_n_shards(cfg.n_shards)} device(s)")
        if args.target is not None:
            from repro.api import PointToPoint
            mode = args.p2p_mode
            if mode != "early_exit":
                t0 = time.perf_counter()
                plan.prepare_landmarks(k=args.landmarks or 4)
                print(f"[sssp] landmarks: k={plan.landmark_tables.k} "
                      f"({time.perf_counter() - t0:.1f}s to preprocess)")
            q = PointToPoint(sources[0], args.target, mode=mode)
            plan.solve(q)                       # warm up / compile
            t0 = time.perf_counter()
            r = plan.solve(q)
            dt = time.perf_counter() - t0
            hops = 0 if r.path is None else len(r.path) - 1
            print(f"[sssp] p2p {sources[0]}->{args.target}: "
                  f"dist={r.distance} hops={hops} "
                  f"buckets={int(r.telemetry.buckets)} ({mode}), "
                  f"{dt * 1e3:.1f} ms")
            if args.verify:
                from repro.core import dijkstra
                ref, _ = dijkstra(g, sources[0])
                ok = int(ref[args.target]) == r.distance
                print(f"[sssp] verify vs Dijkstra: "
                      f"{'OK' if ok else 'MISMATCH'}")
                if not ok:
                    raise SystemExit(1)
            return
        if len(sources) > 1:
            # batched multi-source path: one program for all sources
            plan.solve(MultiSource(sources))    # warm up / compile
            t0 = time.perf_counter()
            res = plan.solve(MultiSource(sources))
            dist = np.asarray(res.dist)
            dt = time.perf_counter() - t0
            tel = res.telemetry
            print(f"[sssp] Δ={cfg.delta} ({cfg.strategy}, batched x"
                  f"{len(sources)}): {dt * 1e3 / len(sources):.1f} "
                  f"ms/source, buckets={int(tel.buckets.max())}, "
                  f"light sweeps={int(tel.inner_iters.max())}")
        else:
            plan.solve(SingleSource(0))         # warm up / compile
            t0 = time.perf_counter()
            r = plan.solve(SingleSource(sources[0]))
            dist = np.asarray(r.dist)[None]
            dt = time.perf_counter() - t0
            print(f"[sssp] Δ={cfg.delta} ({cfg.strategy}): "
                  f"{dt * 1e3:.1f} ms/source, "
                  f"buckets={int(r.telemetry.buckets)}, "
                  f"light sweeps={int(r.telemetry.inner_iters)}")

    if args.verify:
        from repro.core import dijkstra
        ref, _ = dijkstra(g, sources[0])
        ok = np.array_equal(dist[0].astype(np.int64), ref)
        print(f"[sssp] verify vs Dijkstra: {'OK' if ok else 'MISMATCH'}")
        if not ok:
            raise SystemExit(1)


if __name__ == "__main__":
    main()
