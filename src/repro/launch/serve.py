"""Serving launcher: batched-request demos over the serve layer.

LM decode over the slot server:

  PYTHONPATH=src python -m repro.launch.serve --arch gemma2-9b --smoke \\
      --requests 8 --max-new 16

Batched SSSP queries over the microbatching graph server (the unified
engine's multi-source program):

  PYTHONPATH=src python -m repro.launch.serve --sssp --nodes 20000 \\
      --requests 32 --batch 8
"""
from __future__ import annotations

import argparse
import time


def _serve_sssp(args):
    import numpy as np

    from repro.api import PointToPoint, SingleSource, Tuning
    from repro.core import DeltaConfig
    from repro.graphs import watts_strogatz
    from repro.serve import Server

    g = watts_strogatz(args.nodes, args.degree, 1e-2, seed=0)
    t0 = time.perf_counter()
    # --tune = measured search; --tune-cache alone = cache hit or the
    # zero-measurement estimator (same semantics as launch.sssp). The
    # concrete config is always the tuning *base*, so --strategy /
    # --shards survive tuning as non-searched fields, and the winning
    # TuningRecord attaches to the tenant's plan.
    auto = args.tune or args.tune_cache is not None
    config = DeltaConfig(delta=args.delta, strategy=args.strategy,
                         n_shards=args.shards, policy=args.policy,
                         rho=args.rho)
    if not auto and args.policy != "delta":
        print(f"[serve] frontier policy: {args.policy}")
    if not auto and args.strategy.startswith("sharded"):
        from repro.core import resolve_n_shards
        print(f"[serve] mesh-sharded relaxation over "
              f"{resolve_n_shards(args.shards)} device(s)")
    tuning = Tuning(measure=args.tune, cache=args.tune_cache) if auto \
        else None
    srv = Server(g, config=config, tuning=tuning, lane_width=args.batch)
    if auto:
        cfg = srv.plan().config
        rec = srv.plan().record
        provenance = "none" if rec is None else rec.source
        print(f"[serve] tuned at graph load: Δ={cfg.delta} "
              f"strategy={cfg.strategy} policy={cfg.policy} "
              f"cap={cfg.frontier_cap} "
              f"record={provenance} "
              f"({time.perf_counter() - t0:.1f}s)")
    srv.submit(SingleSource(0))
    srv.drain()                                 # warm up / compile
    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    tickets = []
    with srv:                                   # continuous batch loop
        for i in range(args.requests):
            # mix of full distance-vector and point-to-point queries
            src = int(rng.integers(g.n_nodes))
            q = (PointToPoint(src, int(rng.integers(g.n_nodes)))
                 if i % 2 else SingleSource(src))
            tickets.append(srv.submit(q))
        results = [t.result() for t in tickets]
    dt = time.perf_counter() - t0
    n_paths = sum(1 for r in results if getattr(r, "path", None) is not None)
    stats = srv.stats()
    print(f"[serve] answered {len(results)} SSSP queries ({n_paths} with "
          f"paths) in {dt:.2f}s "
          f"({len(results) / dt:.1f} qps, lanes={args.batch}, "
          f"|V|={g.n_nodes})")
    print(f"[serve] p50={stats['latency_p50_ms']:.1f} ms "
          f"p99={stats['latency_p99_ms']:.1f} ms "
          f"occupancy={stats['mean_occupancy']:.2f} "
          f"batches={stats['batches']} shed={stats['shed'] or 0}")


def _serve_lm(args):
    import jax
    import numpy as np

    from repro.configs import family_of, get_config
    from repro.models.transformer import init_lm
    from repro.serve import BatchServer, Request

    assert family_of(args.arch) == "lm", "LM serving needs an lm arch"
    cfg = get_config(args.arch, smoke=args.smoke)
    params = init_lm(cfg, jax.random.key(0))
    srv = BatchServer(params, cfg, n_slots=args.slots, max_len=args.max_len,
                      eos_id=-1)
    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    for i in range(args.requests):
        srv.submit(Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab, args.prompt_len,
                                dtype=np.int32),
            max_new=args.max_new))
    done = srv.run_to_completion()
    dt = time.perf_counter() - t0
    tokens = sum(len(r.generated) for r in done)
    print(f"[serve] completed {len(done)} requests, {tokens} tokens in "
          f"{dt:.2f}s ({tokens / dt:.1f} tok/s with {args.slots} slots)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=64)
    # SSSP serving mode
    ap.add_argument("--sssp", action="store_true",
                    help="serve batched SSSP queries instead of LM decode")
    ap.add_argument("--nodes", type=int, default=20_000)
    ap.add_argument("--degree", type=int, default=12)
    ap.add_argument("--delta", type=int, default=10)
    ap.add_argument("--strategy", default="edge",
                    choices=["edge", "ell", "fused", "sharded_edge",
                             "sharded_ell", "sharded_fused"],
                    help="SSSP mode: relaxation backend (sharded_* = "
                         "mesh-sharded engine, DESIGN.md §9)")
    ap.add_argument("--shards", type=int, default=None,
                    help="SSSP mode, sharded_* strategies: mesh width "
                         "(default: every local device)")
    ap.add_argument("--policy", default="delta",
                    choices=["delta", "rho", "radius"],
                    help="SSSP mode: frontier-selection policy "
                         "(Δ-stepping / ρ-stepping / radius-stepping, "
                         "DESIGN.md §15)")
    ap.add_argument("--rho", type=int, default=None,
                    help="SSSP mode, --policy rho: batch size ρ")
    ap.add_argument("--batch", type=int, default=8,
                    help="SSSP microbatch size (solve_many lanes)")
    ap.add_argument("--tune", action="store_true",
                    help="SSSP mode: measured auto-tune at graph load")
    ap.add_argument("--tune-cache", default=None, metavar="PATH",
                    help="SSSP mode: persistent tuning cache")
    args = ap.parse_args()

    if args.sssp:
        _serve_sssp(args)
    else:
        if not args.arch:
            ap.error("--arch is required for LM serving")
        _serve_lm(args)


if __name__ == "__main__":
    main()
