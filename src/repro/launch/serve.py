"""Serving launcher: batched-request demo over the slot server.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma2-9b --smoke \\
      --requests 8 --max-new 16
"""
from __future__ import annotations

import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=64)
    args = ap.parse_args()

    import jax
    import numpy as np

    from repro.configs import family_of, get_config
    from repro.models.transformer import init_lm
    from repro.serve import BatchServer, Request

    assert family_of(args.arch) == "lm", "serve launcher is for LM archs"
    cfg = get_config(args.arch, smoke=args.smoke)
    params = init_lm(cfg, jax.random.key(0))
    srv = BatchServer(params, cfg, n_slots=args.slots, max_len=args.max_len,
                      eos_id=-1)
    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    for i in range(args.requests):
        srv.submit(Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab, args.prompt_len,
                                dtype=np.int32),
            max_new=args.max_new))
    done = srv.run_to_completion()
    dt = time.perf_counter() - t0
    tokens = sum(len(r.generated) for r in done)
    print(f"[serve] completed {len(done)} requests, {tokens} tokens in "
          f"{dt:.2f}s ({tokens / dt:.1f} tok/s with {args.slots} slots)")


if __name__ == "__main__":
    main()
