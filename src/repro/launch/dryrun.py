import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))
# The two lines above MUST run before any other import: jax locks the
# device count at first initialization. 512 placeholder host devices back
# the 16x16 single-pod and 2x16x16 multi-pod production meshes.

"""Multi-pod dry-run driver (deliverable e).

For every (architecture × input-shape) cell, on the single-pod 16×16
mesh and the multi-pod 2×16×16 mesh:

    lowered  = jit(step, in_shardings=..., out_shardings=...).lower(*specs)
    compiled = lowered.compile()
    print(compiled.memory_analysis())   # proves it fits 16 GB/chip
    print(compiled.cost_analysis())     # FLOPs/bytes for §Roofline

and derives the §Roofline terms (compute/memory/collective) from the
compiled artifact. Results append to a JSON report consumed by
EXPERIMENTS.md. Failures (sharding mismatch, compile OOM, unsupported
collective) are bugs — the run exits nonzero listing them.

Usage:
  python -m repro.launch.dryrun                      # all cells, both meshes
  python -m repro.launch.dryrun --arch yi-34b --shape train_4k --mesh multi
  python -m repro.launch.dryrun --family lm --mesh single
  python -m repro.launch.dryrun --out reports.json --append
"""
import argparse
import json
import sys
import time
import traceback


def run_cell(arch: str, shape_name: str, multi_pod: bool, hbm_limit_gb=16.0):
    from repro.analysis.roofline import analyze
    from repro.launch.cells import build_cell
    from repro.launch.mesh import make_production_mesh, mesh_name

    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    cell = build_cell(arch, shape_name, mesh)
    lowered = cell.lower()
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    report = analyze(
        compiled, arch=arch, shape=shape_name, mesh_name=mesh_name(mesh),
        n_devices=mesh.size, model_flops=cell.model_flops,
        note=cell.note)
    d = report.to_json()
    d["lower_s"] = round(t_lower, 1)
    d["compile_s"] = round(t_compile, 1)
    ma = d["memory_analysis"]
    per_dev = (ma.get("argument_size_in_bytes", 0)
               + ma.get("temp_size_in_bytes", 0)
               + ma.get("output_size_in_bytes", 0)
               - ma.get("alias_size_in_bytes", 0))
    d["hbm_bytes_per_dev"] = int(per_dev)
    d["fits_hbm"] = bool(per_dev <= hbm_limit_gb * 2**30)
    return d


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--family", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--out", default="dryrun_reports.json")
    ap.add_argument("--append", action="store_true")
    ap.add_argument("--include-sssp", action="store_true",
                    help="also run the paper's SSSP configs")
    args = ap.parse_args()

    from repro.configs import all_cells, family_of

    cells = all_cells()
    if not args.include_sssp and args.arch is None and args.family is None:
        cells = [(a, s) for a, s in cells if not a.startswith("sssp-")]
    if args.arch:
        cells = [(a, s) for a, s in all_cells() if a == args.arch]
    if args.family:
        cells = [(a, s) for a, s in cells if family_of(a) == args.family]
    if args.shape:
        cells = [(a, s) for a, s in cells if s == args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    reports = []
    if args.append and os.path.exists(args.out):
        with open(args.out) as f:
            reports = json.load(f)
    done = {(r["arch"], r["shape"], r["mesh"]) for r in reports}

    failures = []
    for arch, shape in cells:
        for multi in meshes:
            mesh_label = "2x16x16" if multi else "16x16"
            if (arch, shape, mesh_label) in done:
                print(f"[skip] {arch} {shape} {mesh_label} (cached)")
                continue
            tag = f"{arch} × {shape} × {mesh_label}"
            print(f"[dryrun] {tag} ...", flush=True)
            try:
                d = run_cell(arch, shape, multi)
                reports.append(d)
                print(f"  ok: compile {d['compile_s']}s, "
                      f"hbm/dev {d['hbm_bytes_per_dev'] / 2**30:.2f} GiB "
                      f"(fits={d['fits_hbm']}), dominant={d['dominant']}, "
                      f"roofline={d['peak_fraction']:.1%}", flush=True)
                print(f"  memory_analysis: {d['memory_analysis']}")
                print(f"  cost: flops/dev={d['flops_per_dev']:.3e} "
                      f"bytes/dev={d['bytes_per_dev']:.3e} "
                      f"wire/dev={d['wire_bytes_per_dev']:.3e}")
            except Exception as e:
                failures.append((tag, repr(e)))
                traceback.print_exc()
            with open(args.out, "w") as f:
                json.dump(reports, f, indent=1)

    print(f"\n{len(reports)} cells compiled -> {args.out}")
    if failures:
        print(f"{len(failures)} FAILURES:")
        for tag, err in failures:
            print(f"  {tag}: {err[:200]}")
        sys.exit(1)


if __name__ == "__main__":
    main()
