"""Production mesh construction.

Single pod: 16×16 = 256 chips, axes ("data", "model").
Multi-pod:  2×16×16 = 512 chips, axes ("pod", "data", "model") — the
"pod" axis carries only data parallelism (plus FSDP weight sharding),
so its collectives are the low-frequency gradient/weight reductions
that tolerate the slower inter-pod links.

A FUNCTION, not a module constant: importing this module never touches
jax device state (the dry-run sets XLA_FLAGS before any jax init).
"""
from __future__ import annotations

from repro.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (host) devices exist — smoke tests."""
    return make_mesh((data, model), ("data", "model"))


def mesh_name(mesh) -> str:
    return "x".join(str(s) for s in mesh.devices.shape)
