"""(architecture × input-shape × mesh) cell builders for the dry-run.

A *cell* packages everything needed to ``jit(...).lower(*args)`` one
step program: the step callable, ShapeDtypeStruct stand-ins for every
input (weak-type-correct, shardable, never allocated), the input/output
shardings, and the MODEL_FLOPS estimate for §Roofline.

Family → lowered program:
  lm / train_*        train_step  (loss + grads + optimizer update)
  lm / prefill_*      prefill     (prompt pass filling the KV cache)
  lm / decode_*, long serve_step  (one token against the cache)
  gnn / *             train_step  (full-graph / sampled / batched-mol)
  recsys / train      train_step; serve_* forward; retrieval top-k
  sssp / *            batched multi-source Δ-stepping solve
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import family_of, get_config, get_shape
from repro.configs.base import ShapeSpec
from repro.analysis.roofline import estimate_model_flops
from repro.core.distributed import DistDeltaConfig, build_solver_from_meta
from repro.graphs.sampler import sample_khop
from repro.models import dlrm as dlrm_lib
from repro.models import gnn as gnn_lib
from repro.models import transformer as tf_lib
from repro.models.param_sharding import (
    cache_specs,
    dlrm_param_specs,
    gnn_param_specs,
    lm_param_specs,
    state_specs_like,
    tree_to_shardings,
)
from repro.models.sharding import sharding_rules
from repro.optim import adafactor, adamw
from repro.train.steps import make_train_step

F32 = jnp.float32
I32 = jnp.int32


@dataclasses.dataclass
class Cell:
    arch: str
    shape: str
    fn: Any                      # jitted (with shardings) step
    args: tuple                  # ShapeDtypeStructs for .lower(*args)
    model_flops: float
    mapping: dict                # logical→mesh mapping used
    note: str = ""

    def lower(self):
        return self.fn.lower(*self.args)


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(int(s) for s in shape), dtype)


def _fit(sds_tree, shardings_tree):
    """Drop sharded axes that do not divide the argument dimension (jit
    in_shardings requires exact divisibility; e.g. 8 KV heads on a
    16-wide axis, or a batch of 1). Trims axes right-to-left per dim."""
    def fix(sds, sh):
        mesh, spec = sh.mesh, sh.spec
        new = []
        for dim, entry in zip(sds.shape,
                              tuple(spec) + (None,) * (len(sds.shape)
                                                       - len(spec))):
            if entry is None:
                new.append(None)
                continue
            axes = list(entry) if isinstance(entry, tuple) else [entry]
            while axes:
                prod = 1
                for a in axes:
                    prod *= mesh.shape[a]
                if dim % prod == 0:
                    break
                axes.pop()
            new.append(tuple(axes) if len(axes) > 1
                       else (axes[0] if axes else None))
        return NamedSharding(mesh, P(*new))

    return jax.tree.map(fix, sds_tree, shardings_tree)


def _tree_sds(tree_shape):
    return jax.tree.map(lambda x: _sds(x.shape, x.dtype), tree_shape)


def _dp(mesh: Mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _all_axes(mesh: Mesh):
    return tuple(mesh.axis_names)


def _mapping_for(mesh: Mesh, overrides: Optional[dict] = None):
    m = {"dp": _dp(mesh), "fsdp": _dp(mesh), "tp": "model", "sp": "model",
         "ep_cap": _dp(mesh), "nodes": _all_axes(mesh), "act_seq": None}
    m.update(overrides or {})
    return m


def _opt_for(cfg):
    # factored state is mandatory above ~100B params (DESIGN.md §6)
    if cfg.n_params() > 100e9:
        return adafactor(1e-3)
    return adamw(3e-4)


# ------------------------------------------------------------------ LM cells

def _lm_cell(arch: str, cfg, shape: ShapeSpec, mesh: Mesh,
             smoke: bool) -> Cell:
    # training: sequence-parallel residual stream (act_seq -> model axis)
    mapping = _mapping_for(
        mesh, {"act_seq": "model"} if shape.kind == "train" else None)
    b, s = shape.global_batch, shape.seq_len
    dp_size = 1
    for a in _dp(mesh):
        dp_size *= mesh.shape[a]
    if smoke:
        b, s = max(2 * dp_size, 4), 64

    params_shape = jax.eval_shape(partial(tf_lib.init_lm, cfg),
                                  jax.random.key(0))
    pspecs = lm_param_specs(params_shape)
    p_sds = _tree_sds(params_shape)
    p_sh = _fit(p_sds, tree_to_shardings(pspecs, mesh, mapping))

    if shape.kind == "train":
        opt = _opt_for(cfg)
        opt_shape = jax.eval_shape(opt.init, params_shape)
        s_specs = state_specs_like(pspecs, opt_shape)
        s_sds = _tree_sds(opt_shape)
        s_sh = _fit(s_sds, tree_to_shardings(s_specs, mesh, mapping))
        batch_sh = {
            "tokens": NamedSharding(mesh, P(_dp(mesh), None)),
            "labels": NamedSharding(mesh, P(_dp(mesh), None)),
        }
        batch_sds = {"tokens": _sds((b, s), I32),
                     "labels": _sds((b, s), I32)}
        # No microbatch accumulation: remat + sequence-parallel residuals
        # already bound activations, and the f32 gradient-accumulator tree
        # (2x params, double-buffered through the scan carry) is what blew
        # the 1T config past HBM. Kept available via TrainerConfig.
        microbatch = None
        loss_chunk = 64 if smoke else 512

        def loss_fn(p, batch):
            return tf_lib.lm_loss(p, cfg, batch["tokens"], batch["labels"],
                                  loss_chunk=loss_chunk)

        raw = make_train_step(loss_fn, opt, microbatch=microbatch,
                              donate=False, jit=False)

        def step(params, opt_state, batch):
            with sharding_rules(mesh, mapping):
                return raw(params, opt_state, None, batch)

        fn = jax.jit(step,
                     in_shardings=(p_sh, s_sh, batch_sh),
                     out_shardings=(p_sh, s_sh, None, None),
                     donate_argnums=(0, 1))
        args = (p_sds, s_sds, batch_sds)
    else:
        import dataclasses as dc
        cfg = dc.replace(cfg, remat=False)   # remat is a training trade
        long_ctx = shape.name.startswith("long")
        if long_ctx:
            mapping = _mapping_for(mesh, {
                "dp": None, "sp": _all_axes(mesh)})
            p_sh = _fit(p_sds, tree_to_shardings(pspecs, mesh, mapping))
        cache_len = s if smoke is False else 128
        bb = b if not smoke else max(dp_size, 2)
        if smoke:
            cache_len, s = 128, 128
        c_specs = cache_specs(long_context=long_ctx)
        cache_shape = jax.eval_shape(
            partial(tf_lib.init_cache, cfg, bb, cache_len))
        c_sds = _tree_sds(cache_shape)
        c_sh = _fit(c_sds, tree_to_shardings(c_specs, mesh, mapping))

        if shape.kind == "prefill":
            tok_sds = _sds((bb, s if not smoke else 64), I32)
            tok_sh = NamedSharding(mesh, P(_dp(mesh), None))

            def step(params, tokens, cache):
                with sharding_rules(mesh, mapping):
                    return tf_lib.prefill(params, cfg, tokens, cache)

            fn = jax.jit(step, in_shardings=(p_sh, tok_sh, c_sh),
                         out_shardings=(None, c_sh), donate_argnums=(2,))
            args = (p_sds, tok_sds, c_sds)
        else:                                    # decode (incl. long_500k)
            tok_sds = _sds((bb, 1), I32)
            tok_spec = P(_dp(mesh), None) if not long_ctx else P(None, None)
            tok_sh = NamedSharding(mesh, tok_spec)

            def step(params, cache, tokens):
                with sharding_rules(mesh, mapping):
                    return tf_lib.decode_step(params, cfg, cache, tokens)

            fn = jax.jit(step, in_shardings=(p_sh, c_sh, tok_sh),
                         out_shardings=(None, c_sh), donate_argnums=(1,))
            args = (p_sds, c_sds, tok_sds)

    return Cell(arch, shape.name, fn, args,
                estimate_model_flops("lm", cfg, shape), mapping)


# ----------------------------------------------------------------- GNN cells

def _gnn_batch_sds(cfg, shape: ShapeSpec, smoke: bool, mesh_size: int):
    n = shape.extra("n_nodes")
    e = shape.extra("n_edges")
    b = shape.extra("batch", 1)
    if smoke:
        n, e, b = min(n, 256), min(e, 1024), min(b, 4)
    # pad to mesh-divisible counts so the flat node/edge arrays shard
    # (padding edges target the sentinel node; compile-only stand-ins)
    n = -(-n // mesh_size) * mesh_size
    e = -(-e // mesh_size) * mesh_size
    return n, e, b


def _gnn_cell(arch: str, cfg, shape: ShapeSpec, mesh: Mesh,
              smoke: bool) -> Cell:
    from repro.configs.gnn_archs import with_shape_dims
    cfg = with_shape_dims(cfg, shape.name)
    if smoke:
        import dataclasses as dc
        cfg = dc.replace(cfg, d_in=min(cfg.d_in, 32), n_rbf=16)
    mapping = _mapping_for(mesh)
    n, e, b, = _gnn_batch_sds(cfg, shape, smoke, mesh.size)
    opt = adamw(1e-3)
    params_shape = jax.eval_shape(partial(gnn_lib.init_gnn, cfg),
                                  jax.random.key(0))
    pspecs = gnn_param_specs(params_shape)
    p_sds = _tree_sds(params_shape)
    p_sh = _fit(p_sds, tree_to_shardings(pspecs, mesh, mapping))
    opt_shape = jax.eval_shape(opt.init, params_shape)
    s_sds = _tree_sds(opt_shape)
    s_sh = _fit(s_sds, tree_to_shardings(
        state_specs_like(pspecs, opt_shape), mesh, mapping))
    nodes_spec = NamedSharding(mesh, P(_all_axes(mesh)))
    nodes2_spec = NamedSharding(mesh, P(_all_axes(mesh), None))

    is_schnet = cfg.arch == "schnet"

    if shape.name == "minibatch_lg":
        bn = shape.extra("batch_nodes")
        fanout = shape.extra("fanout")
        if smoke:
            bn, fanout = 8, (3, 2)

        def loss_fn(p, batch):
            key = jax.random.wrap_key_data(batch["seed_key"])
            nodes, blocks = sample_khop(key, batch["row_ptr"], batch["col"],
                                        batch["seeds"], fanout)
            src = jnp.concatenate([bl.src_local + 0 for bl in blocks])
            dst = jnp.concatenate([bl.dst_local for bl in blocks])
            if is_schnet:
                inputs = dict(
                    atom_z=jnp.take(batch["atom_z"], nodes, mode="clip"),
                    pos=jnp.take(batch["pos"], nodes, axis=0, mode="clip"),
                    src=src, dst=dst,
                    mol_id=jnp.zeros((nodes.shape[0],), I32))
                labels = jnp.zeros((1,), F32)
                return gnn_lib.gnn_loss(p, cfg, inputs, labels)
            x = jnp.take(batch["x"], nodes, axis=0, mode="clip")
            out_mask = jnp.zeros((nodes.shape[0],), F32).at[:bn].set(1.0)
            labels = jnp.zeros((nodes.shape[0],), I32).at[:bn].set(
                jnp.take(batch["labels"], batch["seeds"], mode="clip"))
            inputs = dict(x=x, src=src, dst=dst)
            return gnn_lib.gnn_loss(p, cfg, inputs, labels, out_mask)

        batch_sds = {"row_ptr": _sds((n + 1,), I32),
                     "col": _sds((e,), I32),
                     "seeds": _sds((bn,), I32),
                     "labels": _sds((n,), I32),
                     "seed_key": _sds((2,), jnp.uint32)}
        batch_sh = {"row_ptr": nodes_spec, "col": nodes_spec,
                    "seeds": NamedSharding(mesh, P(_dp(mesh))),
                    "labels": nodes_spec,
                    "seed_key": NamedSharding(mesh, P(None))}
        if is_schnet:
            batch_sds.update(atom_z=_sds((n,), I32), pos=_sds((n, 3), F32))
            batch_sh.update(atom_z=nodes_spec, pos=nodes2_spec)
        else:
            batch_sds.update(x=_sds((n, cfg.d_in), F32))
            batch_sh.update(x=nodes2_spec)
    else:
        n_tot = n * b if shape.name == "molecule" else n
        e_tot = e * b if shape.name == "molecule" else e
        n_tot = -(-n_tot // mesh.size) * mesh.size
        e_tot = -(-e_tot // mesh.size) * mesh.size

        def loss_fn(p, batch):
            if is_schnet:
                inputs = dict(atom_z=batch["atom_z"], pos=batch["pos"],
                              src=batch["src"], dst=batch["dst"],
                              mol_id=batch["mol_id"])
                return gnn_lib.gnn_loss(p, cfg, inputs, batch["energy"])
            inputs = dict(x=batch["x"], src=batch["src"], dst=batch["dst"])
            return gnn_lib.gnn_loss(p, cfg, inputs, batch["labels"],
                                    batch["label_mask"])

        edges_spec = nodes_spec
        batch_sds = {"src": _sds((e_tot,), I32), "dst": _sds((e_tot,), I32)}
        batch_sh = {"src": edges_spec, "dst": edges_spec}
        if is_schnet:
            batch_sds.update(atom_z=_sds((n_tot,), I32),
                             pos=_sds((n_tot, 3), F32),
                             mol_id=_sds((n_tot,), I32),
                             energy=_sds((max(b, 1),), F32))
            batch_sh.update(atom_z=nodes_spec, pos=nodes2_spec,
                            mol_id=nodes_spec,
                            energy=NamedSharding(mesh, P(None)))
        else:
            batch_sds.update(x=_sds((n_tot, cfg.d_in), F32),
                             labels=_sds((n_tot,), I32),
                             label_mask=_sds((n_tot,), F32))
            batch_sh.update(x=nodes2_spec, labels=nodes_spec,
                            label_mask=nodes_spec)

    raw = make_train_step(loss_fn, opt, donate=False, jit=False)

    def step(params, opt_state, batch):
        with sharding_rules(mesh, mapping):
            return raw(params, opt_state, None, batch)

    batch_sh = _fit(batch_sds, batch_sh)
    fn = jax.jit(step, in_shardings=(p_sh, s_sh, batch_sh),
                 out_shardings=(p_sh, s_sh, None, None),
                 donate_argnums=(0, 1))
    return Cell(arch, shape.name, fn, (p_sds, s_sds, batch_sds),
                estimate_model_flops("gnn", cfg, shape), mapping)


# -------------------------------------------------------------- recsys cells

def _dlrm_cell(arch: str, cfg, shape: ShapeSpec, mesh: Mesh,
               smoke: bool) -> Cell:
    mapping = _mapping_for(mesh)
    b = shape.global_batch
    dp_size = 1
    for a in _dp(mesh):
        dp_size *= mesh.shape[a]
    if smoke:
        b = max(dp_size, 4)
    params_shape = jax.eval_shape(partial(dlrm_lib.init_dlrm, cfg),
                                  jax.random.key(0))
    pspecs = dlrm_param_specs(params_shape)
    p_sds = _tree_sds(params_shape)
    p_sh = _fit(p_sds, tree_to_shardings(pspecs, mesh, mapping))
    dp_spec = P(_dp(mesh))

    dense_sds = _sds((b, cfg.n_dense), F32)
    sparse_sds = _sds((b, cfg.n_sparse), I32)
    dense_sh = NamedSharding(mesh, P(_dp(mesh), None))
    sparse_sh = NamedSharding(mesh, P(_dp(mesh), None))

    if shape.kind == "train":
        opt = adamw(1e-3)
        opt_shape = jax.eval_shape(opt.init, params_shape)
        s_sds = _tree_sds(opt_shape)
        s_sh = _fit(s_sds, tree_to_shardings(
            state_specs_like(pspecs, opt_shape), mesh, mapping))

        def loss_fn(p, batch):
            return dlrm_lib.dlrm_loss(p, cfg, batch["dense"],
                                      batch["sparse"], batch["labels"])

        raw = make_train_step(loss_fn, opt, donate=False, jit=False)

        def step(params, opt_state, batch):
            with sharding_rules(mesh, mapping):
                return raw(params, opt_state, None, batch)

        fn = jax.jit(step,
                     in_shardings=(p_sh, s_sh,
                                   {"dense": dense_sh, "sparse": sparse_sh,
                                    "labels": NamedSharding(mesh, dp_spec)}),
                     out_shardings=(p_sh, s_sh, None, None),
                     donate_argnums=(0, 1))
        args = (p_sds, s_sds, {"dense": dense_sds, "sparse": sparse_sds,
                               "labels": _sds((b,), F32)})
    elif shape.name == "retrieval_cand":
        nc = shape.extra("n_candidates")
        if smoke:
            nc = 512
        mapping = _mapping_for(mesh, {"dp": None})
        p_sh = _fit(p_sds, tree_to_shardings(pspecs, mesh, mapping))
        cand_sds = _sds((nc, cfg.embed_dim), F32)
        cand_sh = NamedSharding(mesh, P("model", None))

        def step(params, dense, sparse, cand):
            with sharding_rules(mesh, mapping):
                return dlrm_lib.retrieval_score(params, cfg, dense, sparse,
                                                cand, top_k=100)

        fn = jax.jit(step, in_shardings=(
            p_sh, NamedSharding(mesh, P(None, None)),
            NamedSharding(mesh, P(None, None)), cand_sh))
        args = (p_sds, _sds((b, cfg.n_dense), F32),
                _sds((b, cfg.n_sparse), I32), cand_sds)
    else:                                        # serve_p99 / serve_bulk
        def step(params, dense, sparse):
            with sharding_rules(mesh, mapping):
                return dlrm_lib.apply_dlrm(params, cfg, dense, sparse)

        fn = jax.jit(step, in_shardings=(p_sh, dense_sh, sparse_sh))
        args = (p_sds, dense_sds, sparse_sds)

    return Cell(arch, shape.name, fn, args,
                estimate_model_flops("recsys", cfg, shape), mapping)


# ---------------------------------------------------------------- SSSP cells

def _sssp_cell(arch: str, cfg, shape: ShapeSpec, mesh: Mesh,
               smoke: bool) -> Cell:
    n = cfg.n_nodes if not smoke else 2048
    deg = cfg.avg_degree if not smoke else 6
    dp_prod = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
    # sources shard over the batch axes: round up to a full multiple
    n_sources = max(cfg.n_sources, dp_prod) if not smoke else dp_prod
    n_sources = -(-n_sources // dp_prod) * dp_prod
    p_model = mesh.shape["model"]
    shard_nodes = -(-n // p_model)
    edges = n * deg
    cap = -(-(-(-edges // p_model) // 128) * 128 * 5 // 4)  # 1.25x slack
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dcfg = DistDeltaConfig(delta=cfg.delta, combine=cfg.combine,
                           local_steps=cfg.local_steps,
                           batch_axes=batch_axes)
    solve = build_solver_from_meta(n_nodes=n, shard_nodes=shard_nodes,
                                   mesh=mesh, cfg=dcfg)
    args = (
        _sds((n_sources,), I32),
        _sds((p_model, cap), I32),   # src
        _sds((p_model, cap), I32),   # dst
        _sds((p_model, cap), I32),   # w
        _sds((p_model,), I32),       # vstart
    )
    return Cell(arch, shape.name, solve, args,
                estimate_model_flops("sssp", cfg, shape),
                {"batch_axes": batch_axes, "model": "model"},
                note=f"combine={cfg.combine} n={n} deg={deg}")


# ------------------------------------------------------------------ dispatch

def build_cell(arch: str, shape_name: str, mesh: Mesh,
               smoke: bool = False) -> Cell:
    family = family_of(arch)
    cfg = get_config(arch, smoke=smoke)
    shape = get_shape(arch, shape_name)
    if family == "lm":
        return _lm_cell(arch, cfg, shape, mesh, smoke)
    if family == "gnn":
        return _gnn_cell(arch, cfg, shape, mesh, smoke)
    if family == "recsys":
        return _dlrm_cell(arch, cfg, shape, mesh, smoke)
    if family == "sssp":
        return _sssp_cell(arch, cfg, shape, mesh, smoke)
    raise ValueError(family)
