"""Empirical (Δ, backend, frontier-packing) tuner (DESIGN.md §7).

The search space is the engine's perf-hillclimb axes: a geometric Δ grid
centred on the heuristic estimate (``estimator.estimate_delta``), the
relaxation backend (``edge`` / ``ell`` / optionally ``pallas``), and the
frontier packing — the compaction capacity of the ELL-family backends
(full |V| vs a fraction; candidates whose measured solve trips the
``overflow`` flag are rejected, never returned).

Pruning is successive halving: every surviving candidate is measured
with a short budget (one timed solve after a compile warm-up), the
slower half is dropped, the budget doubles, repeat until one remains.
Total measured work is ~2× the cost of timing every candidate once,
instead of the full-sweep cost of the paper's by-hand Fig. 1 protocol.

The result is a ``TuningRecord`` — a plain serializable fact about one
graph fingerprint — which ``cache.TuningCache`` persists and
``resolve_config`` turns back into a concrete ``DeltaConfig``.
Correctness does not depend on the tuner: every candidate config is
exact (tested bitwise-equal to the hand-picked engine), so tuning only
ever moves time, not answers.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional, Sequence, Tuple

import jax
import numpy as np

from repro.core.delta_stepping import DeltaConfig, DeltaSteppingSolver
from repro.graphs.structures import COOGraph
from repro.tune.estimator import (
    GraphStats,
    estimate_delta,
    fingerprint,
    graph_stats,
)

# Δ grid: estimate × these factors (deduplicated, floored at 1).
_DELTA_FACTORS = (0.25, 0.5, 1.0, 2.0, 4.0)

# Frontier packing: compaction capacity as a fraction of |V| (ELL-family
# backends only; 1.0 is the always-safe full-width buffer).
_CAP_FRACTIONS = (1.0, 0.25)

_MIN_CAP = 32


@dataclasses.dataclass(frozen=True)
class TuningRecord:
    """One tuned operating point for one graph fingerprint.

    ``source`` records provenance: ``'heuristic'`` (estimator only, zero
    measurement), ``'measured'`` (successive-halving search), or
    ``'cache'`` (loaded from a ``TuningCache``). ``trials`` keeps the
    latest (best-sampled) measurement of every viable candidate, sorted
    fastest-first — the evidence trail the cache file exposes for
    why-did-this-win inspection.
    """

    fingerprint: str
    delta: int
    strategy: str
    frontier_cap: Optional[int]
    source: str
    us_per_solve: Optional[float] = None
    trials: Tuple[Tuple[int, str, int, str, float], ...] = ()
    n_shards: Optional[int] = None
    # query-time axis (repro.landmarks): the measured point-to-point
    # algorithm choice of tune_p2p; None = never measured (early_exit)
    p2p_mode: Optional[str] = None
    # the algorithm axis (DESIGN.md §15): which frontier policy won.
    # Records predating the axis deserialize as 'delta' (the only
    # policy they could have measured).
    policy: str = "delta"

    def to_config(self, base: Optional[DeltaConfig] = None) -> DeltaConfig:
        """Concrete engine config: tuned (Δ, strategy, cap, mesh shape,
        policy, p2p mode) over the caller's base for everything else
        (pred_mode, ...)."""
        base = base if base is not None else DeltaConfig()
        return dataclasses.replace(
            base,
            delta=self.delta,
            strategy=self.strategy,
            frontier_cap=self.frontier_cap,
            n_shards=self.n_shards if self.n_shards is not None else base.n_shards,
            p2p_mode=self.p2p_mode if self.p2p_mode is not None else base.p2p_mode,
            policy=self.policy,
        )

    def to_json(self) -> dict:
        return {
            "fingerprint": self.fingerprint,
            "delta": self.delta,
            "strategy": self.strategy,
            "frontier_cap": self.frontier_cap,
            "source": self.source,
            "us_per_solve": self.us_per_solve,
            "trials": [list(t) for t in self.trials],
            "n_shards": self.n_shards,
            "p2p_mode": self.p2p_mode,
            "policy": self.policy,
        }

    @classmethod
    def from_json(cls, d: dict) -> "TuningRecord":
        def trial(row):
            # pre-policy records carried (Δ, strategy, cap, µs) rows
            if len(row) == 4:
                a, b, c, t = row
                p = "delta"
            else:
                a, b, c, p, t = row
            return (int(a), str(b), int(c), str(p), float(t))

        return cls(
            fingerprint=d["fingerprint"],
            delta=int(d["delta"]),
            strategy=d["strategy"],
            frontier_cap=(
                None if d.get("frontier_cap") is None else int(d["frontier_cap"])
            ),
            source=d.get("source", "cache"),
            us_per_solve=d.get("us_per_solve"),
            trials=tuple(trial(row) for row in d.get("trials", [])),
            n_shards=(None if d.get("n_shards") is None else int(d["n_shards"])),
            p2p_mode=d.get("p2p_mode"),
            policy=str(d.get("policy", "delta")),
        )


def heuristic_record(
    graph: COOGraph,
    base: Optional[DeltaConfig] = None,
    stats: Optional[GraphStats] = None,
) -> TuningRecord:
    """Zero-measurement record: estimator Δ, base strategy/packing.
    ``fingerprint`` is empty when the stats skipped the hop-radius probe
    (pure-heuristic path: nothing to key a cache with)."""
    base = base if base is not None else DeltaConfig()
    stats = stats if stats is not None else graph_stats(graph)
    return TuningRecord(
        fingerprint=fingerprint(stats) if stats.ecc0 >= 0 else "",
        delta=estimate_delta(stats),
        strategy=base.strategy,
        frontier_cap=base.frontier_cap,
        source="heuristic",
        policy=base.policy,
    )


def default_strategies() -> Tuple[str, ...]:
    """The tuner's default strategy axis: the single-device backends
    always, plus the mesh-sharded backends whenever the process actually
    has a mesh to shard over (>1 local device) — the mesh shape itself
    is pinned by the fingerprint's ``dev=`` term (DESIGN.md §9)."""
    import jax

    if jax.device_count() > 1:
        return (
            "edge",
            "ell",
            "fused",
            "sharded_edge",
            "sharded_ell",
            "sharded_fused",
        )
    return ("edge", "ell", "fused")


def default_policies() -> Tuple[str, ...]:
    """The tuner's algorithm axis (DESIGN.md §15): every frontier policy
    competes by default — each one is exact, so the axis only ever moves
    time."""
    return ("delta", "rho", "radius")


def candidate_configs(
    stats: GraphStats,
    strategies: Optional[Sequence[str]] = None,
    deltas: Optional[Sequence[int]] = None,
    cap_fractions: Sequence[float] = _CAP_FRACTIONS,
    policies: Optional[Sequence[str]] = None,
) -> list:
    """The (Δ, strategy, frontier_cap, policy) grid the tuner searches.
    Edge strategy ignores packing (no compaction), so it contributes one
    candidate per Δ; ELL-family strategies get one per cap fraction.
    The sharded strategies contribute one candidate per Δ at full mesh
    width (``sharded_ell``'s per-shard buffer is already |V|/P wide —
    fractional caps would mostly re-measure overflow rejections).

    The non-delta policies do not bucket, so Δ only moves their
    light/heavy edge-phase split — they enter at the single central Δ of
    the grid rather than multiplying the whole Δ axis."""
    if strategies is None:
        strategies = default_strategies()
    if deltas is None:
        est = estimate_delta(stats)
        deltas = sorted({max(1, int(round(est * f))) for f in _DELTA_FACTORS})
    if policies is None:
        policies = default_policies()
    n = stats.n_nodes
    out = []
    for policy in policies:
        pol_deltas = (
            deltas if policy == "delta"
            else [sorted(deltas)[len(deltas) // 2]]
        )
        for delta in pol_deltas:
            for strat in strategies:
                if strat in ("ell", "pallas", "fused"):
                    for frac in cap_fractions:
                        cap = (None if frac >= 1.0
                               else max(_MIN_CAP, int(n * frac)))
                        out.append((delta, strat, cap, policy))
                else:
                    out.append((delta, strat, None, policy))
    return out


def _solver_no_warn(graph, cfg, free_mask=None):
    """Internal solver construction: the tuner measures through the
    legacy solver shape on purpose (one warmed object per candidate),
    which must not surface the shim's DeprecationWarning to callers of
    the supported tuning entry points."""
    import warnings

    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        return DeltaSteppingSolver(graph, cfg, free_mask=free_mask)


def _candidate_solver(graph, cfg, sources, free_mask=None):
    """Build + warm up + validate one candidate's solver; ``None`` when
    the config is unusable for *any* probe source (overflow or build
    failure) — an overflowed run is a wrong-answer run and its time
    must never compete."""
    try:
        solver = _solver_no_warn(graph, cfg, free_mask=free_mask)
        for s in sources:  # warm up / compile + validate every source
            if bool(solver.solve(int(s)).overflow):
                return None
    except Exception:
        return None
    return solver


def _time_solver(solver, sources, reps: int) -> float:
    """Median seconds per solve on an already-warm solver."""
    times = []
    for _ in range(reps):
        for s in sources:
            t0 = time.perf_counter()
            jax.block_until_ready(solver.solve(int(s)).dist)
            times.append(time.perf_counter() - t0)
    return float(np.median(times))


def build_safe_solver(
    graph: COOGraph,
    cfg: DeltaConfig,
    *,
    sources: Sequence[int] = (0,),
    free_mask=None,
):
    """Build a solver whose ``frontier_cap`` is validated against the
    caller's actual sources, not just the tuner's probe sources — the
    one shared enforcement point of the 'tuning may move time, never
    answers' invariant (a cached record can come from a same-fingerprint
    graph, and a capped winner was only ever validated on the sources it
    was measured with). Returns ``(config, solver)``; on overflow the
    cap is dropped and the solver rebuilt full-width. Consumers with a
    *dynamic* source stream (serve.SSSPServer) instead re-check the
    overflow flag per batch at serve time."""

    def build(c):
        return _solver_no_warn(
            graph,
            c,
            free_mask=free_mask if c.strategy == "pallas" else None,
        )

    solver = build(cfg)
    if cfg.frontier_cap is not None:
        srcs = [int(s) for s in sources]
        if len(srcs) > 1:
            over = solver.solve_many(np.asarray(srcs, np.int32)).overflow
            tripped = bool(np.any(np.asarray(over)))
        else:
            tripped = bool(solver.solve(srcs[0]).overflow)
        if tripped:
            cfg = dataclasses.replace(cfg, frontier_cap=None)
            solver = build(cfg)
    return cfg, solver


def tune(
    graph: COOGraph,
    base: Optional[DeltaConfig] = None,
    *,
    sources: Sequence[int] = (0,),
    strategies: Optional[Sequence[str]] = None,
    deltas: Optional[Sequence[int]] = None,
    cap_fractions: Sequence[float] = _CAP_FRACTIONS,
    policies: Optional[Sequence[str]] = None,
    cache=None,
    free_mask=None,
    measure_fn=None,
) -> TuningRecord:
    """Measured search with successive-halving pruning.

    ``base`` supplies the non-searched config fields (pred_mode is
    forced to ``'none'`` during measurement — predecessor recovery is
    off the timed path — and restored by ``TuningRecord.to_config``).
    ``strategies=None`` searches ``default_strategies()``: the mesh-
    sharded backends join the space whenever >1 device is present.
    ``policies=None`` searches ``default_policies()`` — the algorithm
    axis rides the same halving loop as (Δ, strategy, cap).
    ``cache`` (a ``TuningCache``-shaped object) is consulted before the
    search and updated — and saved — after it. ``measure_fn`` overrides
    the timing primitive (tests inject deterministic costs); its
    signature is ``(delta, strategy, cap, policy, reps) -> seconds``.
    """
    base = base if base is not None else DeltaConfig()
    stats = graph_stats(graph)
    fp = fingerprint(stats)
    if cache is not None:
        hit = cache.get(fp)
        if hit is not None:
            return dataclasses.replace(hit, source="cache")

    bench_cfg = dataclasses.replace(base, pred_mode="none")
    if measure_fn is None:
        # one solver (and one compile + overflow validation) per
        # candidate for the whole search: later halving rounds re-time
        # the warm solver instead of re-paying the build
        solvers = {}

        def measure_fn(delta, strat, cap, policy, reps):
            key = (delta, strat, cap, policy)
            if key not in solvers:
                cfg = dataclasses.replace(
                    bench_cfg, delta=delta, strategy=strat,
                    frontier_cap=cap, policy=policy,
                )
                solvers[key] = _candidate_solver(
                    graph, cfg, sources, free_mask=free_mask
                )
            if solvers[key] is None:
                return float("inf")
            return _time_solver(solvers[key], sources, reps)

    survivors = candidate_configs(
        stats, strategies=strategies, deltas=deltas,
        cap_fractions=cap_fractions, policies=policies,
    )
    reps = 1
    evidence = {}  # candidate -> its latest (best-sampled) measurement
    timed = []
    while True:
        timed = [(measure_fn(d, s, c, p, reps), (d, s, c, p))
                 for d, s, c, p in survivors]
        timed.sort(key=lambda x: x[0])
        timed = [t for t in timed if np.isfinite(t[0])]
        evidence.update({cand: t for t, cand in timed})
        if not timed:
            # every candidate overflowed/failed: fall back to heuristic
            return heuristic_record(graph, base, stats)
        if len(timed) == 1:
            break
        survivors = [cand for _, cand in timed[: (len(timed) + 1) // 2]]
        if len(survivors) == 1:
            # one final, better-sampled measurement of the winner
            reps *= 2
            d, s, c, p = survivors[0]
            timed = [(measure_fn(d, s, c, p, reps), (d, s, c, p))]
            evidence[(d, s, c, p)] = timed[0][0]
            break
        reps *= 2

    best_t, (delta, strat, cap, policy) = timed[0]
    if strat.startswith("sharded"):
        # pin the mesh width the winner was actually measured on
        from repro.core.backends import resolve_n_shards

        shards = resolve_n_shards(base.n_shards)
    else:
        shards = None
    record = TuningRecord(
        fingerprint=fp,
        delta=delta,
        strategy=strat,
        frontier_cap=cap,
        source="measured",
        us_per_solve=round(best_t * 1e6, 1),
        trials=tuple(
            (d, s, -1 if c is None else c, p, round(t * 1e6, 1))
            for (d, s, c, p), t in sorted(evidence.items(), key=lambda kv: kv[1])
        ),
        n_shards=shards,
        policy=policy,
    )
    if cache is not None:
        cache.put(record)
        cache.save()
    return record


def resolve_record(
    graph: COOGraph,
    base: Optional[DeltaConfig] = None,
    *,
    free_mask=None,
    cache_path: Optional[str] = None,
    measure: bool = False,
    sources: Optional[Sequence[int]] = (0,),
) -> Tuple[DeltaConfig, TuningRecord]:
    """The ``config="auto"`` resolution path: cache hit → tuned config;
    otherwise the zero-measurement estimator (or, with ``measure=True``,
    the successive-halving search, persisted when a cache path is
    given). Returns ``(config, record)`` — the concrete operating point
    plus the ``TuningRecord`` it came from, so the caller (a
    ``repro.api.Plan``) can attach the tuning evidence to the unit that
    serves with it.

    A tuning-chosen ``frontier_cap`` never reaches the engine
    unvalidated (cache records can come from a same-fingerprint graph
    the cap was never checked on): with ``sources`` given, the cap is
    re-validated against exactly those sources (one warm solve on the
    shared ``build_safe_solver`` path) and dropped on overflow; with
    ``sources=None`` — a caller that cannot know its future sources,
    like the core ``config="auto"`` path — the cap is dropped outright.
    Tuning may move time, never answers."""
    base = base if base is not None else DeltaConfig()
    if cache_path is not None or measure:
        from repro.tune.cache import TuningCache

        cache = TuningCache(cache_path)
        probe = sources if sources is not None else (0,)
        if measure:
            rec = tune(
                graph, base, sources=probe, cache=cache, free_mask=free_mask
            )
        else:
            stats = graph_stats(graph)
            rec = cache.get(fingerprint(stats))
            if rec is None:
                rec = heuristic_record(graph, base, stats)
        cfg = rec.to_config(base)
        # only a record fresh from THIS call's measured search was
        # already validated against these probe sources (tune() re-marks
        # its own cache hits source="cache"; a measure=False cache.get
        # returns the stored record with its original source, which
        # proves nothing about this graph). sources=None callers can
        # never trust a cap: the probe covered (0,) only.
        tuned_cap = (
            cfg.frontier_cap is not None and cfg.frontier_cap != base.frontier_cap
        )
        validated = measure and rec.source == "measured" and sources is not None
        if tuned_cap and not validated:
            if sources is None:
                cfg = dataclasses.replace(cfg, frontier_cap=None)
            else:
                cfg, _ = build_safe_solver(
                    graph, cfg, sources=sources, free_mask=free_mask
                )
        return cfg, rec
    # pure-heuristic path: degrees and weights are enough — skip the
    # O(diameter·|E|) hop-radius probe (no cache key to build)
    stats = graph_stats(graph, probe_ecc=False)
    rec = heuristic_record(graph, base, stats)
    return rec.to_config(base), rec


def resolve_config(
    graph: COOGraph,
    base: Optional[DeltaConfig] = None,
    *,
    free_mask=None,
    cache_path: Optional[str] = None,
    measure: bool = False,
    sources: Optional[Sequence[int]] = (0,),
) -> DeltaConfig:
    """Config-only wrapper of :func:`resolve_record` (the original
    ``config="auto"`` entry point; kept for callers that do not track
    tuning evidence)."""
    cfg, _ = resolve_record(
        graph,
        base,
        free_mask=free_mask,
        cache_path=cache_path,
        measure=measure,
        sources=sources,
    )
    return cfg


def tune_p2p(
    graph: COOGraph,
    record: Optional[TuningRecord] = None,
    *,
    pairs: Optional[Sequence[Tuple[int, int]]] = None,
    modes: Optional[Sequence[str]] = None,
    reps: int = 3,
    landmarks: Optional[dict] = None,
    free_mask=None,
    cache=None,
    measure_fn=None,
) -> TuningRecord:
    """Measured query-time search over the point-to-point algorithm
    (``DeltaConfig.p2p_mode``, DESIGN.md §14): times each candidate mode
    on representative ``pairs`` through a real Plan and records the
    winner on the tuning record, so cached workloads pick their p2p
    algorithm the same way they pick (Δ, strategy, cap).

    ``record`` is the operating point to extend (default: the
    zero-measurement heuristic record); ``modes=None`` searches
    ``early_exit`` plus — on canonical (w >= 1) graphs — the three
    landmark modes; ``landmarks`` are ``Plan.prepare_landmarks`` kwargs
    (table precompute runs once, before the clock starts — ALT query
    time is what is being measured, exactly the preprocessing/query
    split of the goal-directed literature). ``cache`` (a ``TuningCache``
    or path) persists the extended record under the same fingerprint.
    ``measure_fn(mode) -> seconds`` overrides the timing primitive
    (tests inject deterministic costs). Every mode returns bitwise-equal
    distances, so — like the rest of the tuner — this only ever moves
    time, not answers.
    """
    from repro.api import Engine, PointToPoint  # lazy: api builds on tune
    from repro.core.backends import graph_is_canonical

    if record is None:
        record = heuristic_record(graph)
    base = record.to_config(DeltaConfig(pred_mode="none"))
    if modes is None:
        modes = ("early_exit",)
        if graph_is_canonical(graph):
            from repro.landmarks import LANDMARK_MODES

            modes = modes + LANDMARK_MODES
    n = graph.n_nodes
    if pairs is None:
        rng = np.random.default_rng(0)
        pairs = tuple(
            (int(rng.integers(n)), int(rng.integers(n))) for _ in range(4)
        )
    plan = Engine(graph, base, free_mask=free_mask).plan()
    if landmarks:
        plan.prepare_landmarks(**landmarks)
    best_mode, best_t = None, None
    for mode in modes:
        if measure_fn is not None:
            elapsed = float(measure_fn(mode))
        else:

            def run(m=mode):
                for s, t in pairs:
                    plan.solve(PointToPoint(s, t, mode=m))

            run()  # compile warm-up (and lazy table build) off the clock
            elapsed = None
            for _ in range(max(1, reps)):
                t0 = time.perf_counter()
                run()
                dt = time.perf_counter() - t0
                elapsed = dt if elapsed is None else min(elapsed, dt)
        if best_t is None or elapsed < best_t:
            best_mode, best_t = mode, elapsed
    record = dataclasses.replace(record, p2p_mode=best_mode)
    if cache is not None:
        if isinstance(cache, str):
            from repro.tune.cache import TuningCache

            cache = TuningCache(cache)
        cache.put(record)
        cache.save()
    return record
