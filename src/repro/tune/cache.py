"""Persistent JSON tuning cache (DESIGN.md §7).

One JSON file maps graph fingerprints (``estimator.fingerprint``) to
serialized ``TuningRecord``s, so a repeat workload — the serving path
reloading the same graph family, CI re-running a bench — skips the
measured search entirely. The file is human-readable and committed-able
(benchmark baselines ride the same idea one level up).

Writes are atomic (tmp file + ``os.replace``) so a crashed tuner never
leaves a truncated cache behind.
"""

from __future__ import annotations

import json
import os
import tempfile
from typing import Dict, Optional

from repro.tune.search import TuningRecord

_VERSION = 1


class TuningCache:
    """Dict-like fingerprint → ``TuningRecord`` store backed by one JSON
    file. ``path=None`` gives a purely in-memory cache (same interface,
    nothing persisted) — handy for tests and one-shot scripts."""

    def __init__(self, path: Optional[str] = None):
        self.path = path
        self._records: Dict[str, TuningRecord] = {}
        if path is not None and os.path.exists(path):
            self._load(path)

    def _load(self, path: str) -> None:
        # any unreadable file (truncated write, hand edit, stale schema)
        # means "start fresh rather than misread" — a tuning cache is
        # always safe to lose, never safe to crash on
        try:
            with open(path) as f:
                data = json.load(f)
            if data.get("version") != _VERSION:
                return
            records = {
                key: TuningRecord.from_json(rec)
                for key, rec in data.get("records", {}).items()
            }
        except (OSError, ValueError, KeyError, TypeError):
            return
        self._records.update(records)

    def get(self, fp: str) -> Optional[TuningRecord]:
        return self._records.get(fp)

    def put(self, record: TuningRecord) -> None:
        self._records[record.fingerprint] = record

    def __len__(self) -> int:
        return len(self._records)

    def __contains__(self, fp: str) -> bool:
        return fp in self._records

    def save(self) -> None:
        """Atomically rewrite the backing file (no-op when in-memory)."""
        if self.path is None:
            return
        payload = {
            "version": _VERSION,
            "records": {
                fp: rec.to_json() for fp, rec in sorted(self._records.items())
            },
        }
        d = os.path.dirname(os.path.abspath(self.path))
        os.makedirs(d, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(payload, f, indent=1, sort_keys=True)
            os.replace(tmp, self.path)
        except BaseException:
            if os.path.exists(tmp):
                os.unlink(tmp)
            raise
