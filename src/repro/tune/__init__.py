"""Auto-tuning subsystem for the Δ-stepping engine (DESIGN.md §7).

The paper sweeps Δ per graph family and picks winners by hand (Fig. 1);
Dong et al. 2021 and Blelloch et al. 2016 show the step parameter can —
and should — be chosen from graph structure instead. Three layers:

* ``estimator`` — zero-measurement heuristic: graph statistics (degree
  distribution, weight range) → Δ ≈ c·w̄/d̄, plus the graph fingerprint
  that keys the cache.
* ``search`` — empirical tuner: short measured solves over the
  (Δ, backend, frontier-packing) space with successive-halving pruning,
  returning a ``TuningRecord``.
* ``cache`` — persistent JSON store of ``TuningRecord``s keyed by
  fingerprint, so repeat workloads skip the search.

``resolve_record`` is the single resolution point the Query/Plan façade
consults when a caller passes ``config="auto"`` or a tuning base
(``repro.api.Engine.plan`` — through which the deprecated shims in
core.delta_stepping, serve.SSSPServer and launch.sssp all route); it
returns the concrete config *and* the ``TuningRecord`` it came from, so
tuning evidence attaches to the ``Plan`` that serves with it.
``resolve_config`` is the config-only wrapper.
"""

from repro.tune.cache import TuningCache
from repro.tune.estimator import (
    GraphStats,
    estimate_delta,
    fingerprint,
    graph_stats,
)
from repro.tune.search import (
    TuningRecord,
    build_safe_solver,
    candidate_configs,
    default_policies,
    default_strategies,
    heuristic_record,
    resolve_config,
    resolve_record,
    tune,
    tune_p2p,
)

__all__ = [
    "GraphStats",
    "TuningCache",
    "TuningRecord",
    "build_safe_solver",
    "candidate_configs",
    "default_policies",
    "default_strategies",
    "estimate_delta",
    "fingerprint",
    "graph_stats",
    "heuristic_record",
    "resolve_config",
    "resolve_record",
    "tune",
    "tune_p2p",
]
