"""Heuristic Δ estimation from graph statistics (DESIGN.md §7).

Meyer & Sanders' analysis (and the follow-ups this subsystem cites:
Dong et al. 2021, Blelloch et al. 2016) put the useful bucket width at
Δ = Θ(w̄/d̄): wide enough that a bucket holds real parallel work, narrow
enough that re-relaxation stays bounded. The constant is calibrated on
the paper's Fig. 1 families — ``DELTA_C = 12`` lands on the paper's
hand-picked Δ = 10 for both small-world (w̄ ≈ 10.5, d̄ = 12) and R-MAT
(w̄ ≈ 10.5, d̄ ≈ 13) instances.

Everything here is host-side numpy over the COO arrays: zero measured
solves, cheap enough to run at graph-load time. ``GraphStats`` doubles
as the tuning-cache key material (``fingerprint``): two graphs with the
same vertex/edge counts, log-degree histogram and weight range get the
same tuned record.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

from repro.graphs.structures import COOGraph

# Calibration constant of the Δ ≈ c·w̄/d̄ rule (see module docstring).
DELTA_C = 12.0

# Fallback Δ when the graph has no edges at all (any value is correct:
# a single bucket settles the source and the loop terminates).
DEFAULT_DELTA = 10

# Log-degree histogram buckets: deg 0, 1, 2-3, 4-7, ..., >= 128.
_HIST_BUCKETS = 9


# BFS level cap for the eccentricity probe: beyond this the hop-radius
# bucket saturates (keeps the probe O(cap·|E|) on huge long graphs).
_ECC_CAP = 1024


@dataclasses.dataclass(frozen=True)
class GraphStats:
    """Host-side summary of a ``COOGraph`` — the estimator's input and
    the tuning-cache key material."""

    n_nodes: int
    n_edges: int
    mean_degree: float
    max_degree: int
    degree_hist: Tuple[int, ...]  # log2-bucketed out-degree counts
    w_min: int
    w_max: int
    w_mean: float
    ecc0: int  # BFS eccentricity of vertex 0 (hop-diameter proxy);
    #            -1 = not probed (heuristic-only stats, no cache key)


def _bfs_eccentricity(src, dst, n: int, start: int = 0) -> int:
    """Unweighted BFS level count from ``start``: a cheap hop-diameter
    proxy. Degree statistics alone cannot tell a long-diameter graph
    from a short one (a Watts-Strogatz ring at p=1e-4 vs p=1e-2 has the
    identical degree histogram) — and diameter is exactly the property
    that moves Δ's optimum (paper Fig. 1), so the fingerprint must see
    it or the tuning cache cross-contaminates the two."""
    visited = np.zeros(n, bool)
    visited[start] = True
    frontier = visited.copy()
    levels = 0
    while levels < _ECC_CAP:
        nxt = np.zeros(n, bool)
        nxt[dst[frontier[src]]] = True
        nxt &= ~visited
        if not nxt.any():
            break
        visited |= nxt
        frontier = nxt
        levels += 1
    return levels


def graph_stats(graph: COOGraph, probe_ecc: bool = True) -> GraphStats:
    """Compute ``GraphStats`` on the host (numpy; not a jit path).
    ``probe_ecc=False`` skips the O(diameter·|E|) BFS probe — enough for
    the Δ estimate (which reads only degrees and weights) but not for a
    cache fingerprint."""
    src = np.asarray(graph.src)
    dst = np.asarray(graph.dst)
    w = np.asarray(graph.w)
    n, m = graph.n_nodes, int(src.shape[0])
    if m == 0:
        hist = [n] + [0] * (_HIST_BUCKETS - 1)
        return GraphStats(n, 0, 0.0, 0, tuple(hist), 0, 0, 0.0, 0)
    deg = np.bincount(src, minlength=n)
    # bucket index: 0 for deg 0, else 1 + floor(log2(deg)), capped
    idx = np.zeros(n, np.int64)
    nz = deg > 0
    idx[nz] = 1 + np.log2(deg[nz]).astype(np.int64)
    idx = np.minimum(idx, _HIST_BUCKETS - 1)
    hist = np.bincount(idx, minlength=_HIST_BUCKETS)
    return GraphStats(
        n_nodes=n,
        n_edges=m,
        mean_degree=float(m) / max(n, 1),
        max_degree=int(deg.max()),
        degree_hist=tuple(int(c) for c in hist),
        w_min=int(w.min()),
        w_max=int(w.max()),
        w_mean=float(w.mean()),
        ecc0=_bfs_eccentricity(src, dst, n) if probe_ecc else -1,
    )


def estimate_delta(stats: GraphStats, c: float = DELTA_C) -> int:
    """Zero-measurement bucket width: Δ ≈ c·w̄/d̄, clamped to
    [1, 4·w_max]. Always finite, even on degenerate graphs (no edges,
    zero weights, isolated vertices)."""
    if stats.n_edges == 0 or stats.mean_degree <= 0:
        return DEFAULT_DELTA
    delta = c * stats.w_mean / stats.mean_degree
    hi = max(1, 4 * stats.w_max)
    return int(np.clip(round(delta), 1, hi))


def fingerprint(stats: GraphStats) -> str:
    """Stable cache key: structural statistics only (not edge identity),
    so isomorphic-in-distribution workloads share tuned records. The
    hop-radius term is log2-bucketed: graphs whose diameters differ by
    less than 2x share records, order-of-magnitude differences (the
    Fig. 1 p-sweep regimes) do not.

    The mesh shape (local device count) is part of the key: the tuner's
    search space includes the mesh-sharded backends (DESIGN.md §9), and
    a winner measured on an 8-device mesh proves nothing about a
    1-device host — records must not cross-contaminate across hardware
    widths.

    The leading version tag is bumped whenever the search space itself
    changes shape (v5: the frontier-policy algorithm axis, DESIGN.md
    §15) — records from an older space silently miss the cache and
    trigger a fresh resolve instead of pinning a winner that never
    competed against the new candidates."""
    if stats.ecc0 < 0:
        raise ValueError(
            "stats were computed with probe_ecc=False — no cache key "
            "without the hop-radius term"
        )
    import jax  # local: the estimator is otherwise host-side numpy only

    hist = ",".join(str(c) for c in stats.degree_hist)
    ecc = 0 if stats.ecc0 == 0 else 1 + int(np.log2(stats.ecc0))
    return (
        f"v5:n={stats.n_nodes}:m={stats.n_edges}"
        f":deg={hist}:w={stats.w_min}-{stats.w_max}:ecc={ecc}"
        f":dev={jax.device_count()}"
    )
