"""Deterministic synthetic data pipelines (offline container — no
dataset downloads). Every source is seeded and step-indexed so a
restarted run (fault tolerance) resumes with identical batches: batch i
is a pure function of (seed, i), never of pipeline state.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np



@dataclasses.dataclass(frozen=True)
class SyntheticTokens:
    """Zipfian token stream for LM training/serving."""
    vocab: int
    batch: int
    seq_len: int
    seed: int = 0

    def batch_at(self, step: int):
        key = jax.random.fold_in(jax.random.key(self.seed), step)
        # Zipf-ish marginal via exponentiated uniform
        u = jax.random.uniform(key, (self.batch, self.seq_len + 1),
                               minval=1e-6)
        toks = (self.vocab * u ** 3).astype(jnp.int32) % self.vocab
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


@dataclasses.dataclass(frozen=True)
class SyntheticClicks:
    """Criteo-like batches for DLRM."""
    vocab_sizes: tuple
    n_dense: int
    batch: int
    seed: int = 0

    def batch_at(self, step: int):
        key = jax.random.fold_in(jax.random.key(self.seed + 1), step)
        kd, ks, kl = jax.random.split(key, 3)
        dense = jax.random.normal(kd, (self.batch, self.n_dense))
        us = jax.random.uniform(ks, (self.batch, len(self.vocab_sizes)))
        vocab = jnp.asarray(self.vocab_sizes)
        sparse = (us ** 2 * vocab).astype(jnp.int32) % vocab  # skewed ids
        labels = (jax.random.uniform(kl, (self.batch,)) < 0.03).astype(
            jnp.float32)
        return {"dense": dense, "sparse": sparse, "labels": labels}


def gnn_full_batch(n_nodes: int, d_feat: int, n_classes: int, seed: int = 0):
    """Node features + labels + train mask for full-graph training."""
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((n_nodes, d_feat), dtype=np.float32)
    y = rng.integers(0, n_classes, n_nodes).astype(np.int32)
    mask = (rng.random(n_nodes) < 0.1).astype(np.float32)
    return {"x": jnp.asarray(x), "labels": jnp.asarray(y),
            "label_mask": jnp.asarray(mask)}


def molecule_batch(batch: int, n_atoms: int, n_edges: int, seed: int = 0):
    """Batched small molecules for SchNet: positions, atomic numbers,
    intra-molecule radius edges, per-molecule energy target."""
    rng = np.random.default_rng(seed)
    pos = rng.standard_normal((batch * n_atoms, 3)).astype(np.float32) * 2
    z = rng.integers(1, 18, batch * n_atoms).astype(np.int32)
    # edges within each molecule only
    src = rng.integers(0, n_atoms, (batch, n_edges))
    dst = rng.integers(0, n_atoms, (batch, n_edges))
    off = (np.arange(batch) * n_atoms)[:, None]
    src, dst = (src + off).ravel(), (dst + off).ravel()
    mol_id = np.repeat(np.arange(batch), n_atoms).astype(np.int32)
    energy = rng.standard_normal(batch).astype(np.float32)
    return {"pos": jnp.asarray(pos), "atom_z": jnp.asarray(z),
            "src": jnp.asarray(src.astype(np.int32)),
            "dst": jnp.asarray(dst.astype(np.int32)),
            "mol_id": jnp.asarray(mol_id),
            "energy": jnp.asarray(energy)}
