from repro.data.synthetic import (
    SyntheticClicks,
    SyntheticTokens,
    gnn_full_batch,
    molecule_batch,
)

__all__ = ["SyntheticTokens", "SyntheticClicks", "gnn_full_batch",
           "molecule_batch"]
