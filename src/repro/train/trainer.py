"""Training orchestrator: checkpoint/restart fault tolerance, straggler
watchdog, deterministic data resume.

Fault model (designed for 1000+ nodes, exercised here on one host):

* **Crash/restart** — the loop checkpoints every ``ckpt_interval`` steps
  (atomic rename, see checkpoint/), and ``run()`` always begins by
  restoring the latest snapshot; the data pipeline is step-indexed so
  the restored run replays identical batches. Tests inject a
  ``SimulatedFailure`` mid-run and assert bit-identical convergence with
  an uninterrupted run.
* **Straggler mitigation** — a step-time EMA watchdog flags steps slower
  than ``straggler_factor``× the running mean. On a real pod the hook
  triggers the elastic re-mesh path (distributed/elastic.py); here it
  records events for inspection. Synchronous SPMD means one slow host
  drags the step, so detection + re-mesh *is* the mitigation.
* **Elastic scaling** — ``on_straggler``/``on_failure`` callbacks may
  return a new (mesh, state) via distributed.elastic.remesh.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax

from repro.checkpoint import AsyncWriter, restore
from repro.train.steps import init_residuals, make_train_step


class SimulatedFailure(RuntimeError):
    """Raised by the failure-injection hook to exercise the restart path."""


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_dir: Optional[str] = None
    ckpt_interval: int = 20
    ckpt_keep: int = 3
    async_ckpt: bool = True
    log_interval: int = 10
    microbatch: Optional[int] = None
    compression: str = "none"
    straggler_factor: float = 3.0
    straggler_warmup: int = 5


class StepWatchdog:
    """EMA step-time tracker; flags stragglers."""

    def __init__(self, factor: float, warmup: int):
        self.factor = factor
        self.warmup = warmup
        self.ema = None
        self.count = 0
        self.events = []

    def observe(self, step: int, dt: float) -> bool:
        self.count += 1
        if self.ema is None:
            self.ema = dt
            return False
        is_straggler = (self.count > self.warmup
                        and dt > self.factor * self.ema)
        if is_straggler:
            self.events.append((step, dt, self.ema))
        else:  # don't poison the EMA with straggler steps
            self.ema = 0.9 * self.ema + 0.1 * dt
        return is_straggler


class Trainer:
    def __init__(self, loss_fn, optimizer, params, data, cfg: TrainerConfig,
                 on_straggler: Optional[Callable] = None,
                 failure_at_step: Optional[int] = None):
        self.cfg = cfg
        self.data = data
        self.step_fn = make_train_step(
            loss_fn, optimizer, microbatch=cfg.microbatch,
            compression=cfg.compression)
        self.params = params
        self.opt_state = optimizer.init(params)
        self.residuals = init_residuals(params, cfg.compression)
        self.start_step = 0
        self.watchdog = StepWatchdog(cfg.straggler_factor,
                                     cfg.straggler_warmup)
        self.on_straggler = on_straggler
        self.failure_at_step = failure_at_step
        self.writer = (AsyncWriter(cfg.ckpt_dir, cfg.ckpt_keep)
                       if cfg.ckpt_dir and cfg.async_ckpt else None)
        self.history = []
        self._maybe_restore()

    # ------------------------------------------------------------- restore
    def _state(self):
        return {"params": self.params, "opt": self.opt_state,
                "residuals": self.residuals}

    def _maybe_restore(self):
        if not self.cfg.ckpt_dir:
            return
        state, step = restore(self.cfg.ckpt_dir, self._state())
        if state is not None:
            self.params = state["params"]
            self.opt_state = state["opt"]
            self.residuals = state["residuals"]
            self.start_step = step
            print(f"[trainer] restored checkpoint at step {step}")

    def _checkpoint(self, step: int):
        if not self.cfg.ckpt_dir:
            return
        if self.writer is not None:
            self.writer.submit(step, self._state())
        else:
            from repro.checkpoint import save
            save(self.cfg.ckpt_dir, step, self._state(), self.cfg.ckpt_keep)

    # ----------------------------------------------------------------- run
    def run(self):
        step = self.start_step
        while step < self.cfg.total_steps:
            batch = self.data.batch_at(step)
            if self.failure_at_step is not None \
                    and step == self.failure_at_step:
                self.failure_at_step = None    # fail exactly once
                raise SimulatedFailure(f"injected failure at step {step}")
            t0 = time.perf_counter()
            self.params, self.opt_state, self.residuals, metrics = \
                self.step_fn(self.params, self.opt_state, self.residuals,
                             batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            step += 1
            if self.watchdog.observe(step, dt) and self.on_straggler:
                self.on_straggler(self, step, dt)
            if step % self.cfg.log_interval == 0 or step == 1:
                loss = float(metrics["loss"])
                self.history.append((step, loss))
                print(f"[trainer] step {step} loss {loss:.4f} "
                      f"({dt * 1e3:.1f} ms)")
            if step % self.cfg.ckpt_interval == 0:
                self._checkpoint(step)
        self._checkpoint(self.cfg.total_steps)
        if self.writer:
            self.writer.wait()
        return self.params


def run_with_restarts(make_trainer: Callable[[], Trainer],
                      max_restarts: int = 3):
    """Production driver: (re)build the trainer — which restores the
    latest checkpoint — after every failure, up to ``max_restarts``."""
    for attempt in range(max_restarts + 1):
        trainer = make_trainer()
        try:
            return trainer.run(), trainer
        except SimulatedFailure as e:
            # drain the failed incarnation's in-flight async checkpoint
            # before rebuilding: a submit() the trainer already accepted
            # must be durable by the time the restart restores, or the
            # resume races the background write thread (an in-process
            # restart supervisor keeps the writer thread alive, so
            # waiting is both possible and required)
            if trainer.writer is not None:
                trainer.writer.wait()
            print(f"[trainer] {e}; restarting ({attempt + 1})")
    raise RuntimeError("exceeded max restarts")
