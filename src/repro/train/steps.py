"""Train-step factory: value_and_grad + optimizer + optional microbatch
gradient accumulation (lax.scan) and gradient compression with error
feedback. Returns a single jitted function with donated state so the
update is in-place on device.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.distributed.compression import compress_grads
from repro.optim.optimizers import Optimizer


def make_train_step(loss_fn: Callable, optimizer: Optimizer, *,
                    microbatch: Optional[int] = None,
                    compression: str = "none", topk_frac: float = 0.01,
                    donate: bool = True, jit: bool = True):
    """loss_fn(params, batch) -> (loss, metrics dict).

    microbatch: number of accumulation chunks — every array in the batch
    is split along axis 0 and gradients are averaged with a lax.scan
    (bounds activation memory; the 1T config requires it)."""

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def compute_grads(params, batch):
        if not microbatch or microbatch <= 1:
            (loss, metrics), grads = grad_fn(params, batch)
            return loss, metrics, grads

        def split(x):
            b = x.shape[0]
            assert b % microbatch == 0, (b, microbatch)
            return x.reshape(microbatch, b // microbatch, *x.shape[1:])

        chunks = jax.tree.map(split, batch)
        zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                            params)

        def body(carry, mb):
            acc, loss_acc = carry
            (loss, metrics), grads = grad_fn(params, mb)
            acc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32),
                               acc, grads)
            return (acc, loss_acc + loss), metrics

        (grads, loss), metrics = jax.lax.scan(
            body, (zero, jnp.zeros((), jnp.float32)), chunks)
        inv = 1.0 / microbatch
        grads = jax.tree.map(lambda g: g * inv, grads)
        metrics = jax.tree.map(lambda m: m[-1], metrics)
        return loss * inv, metrics, grads

    def step(params, opt_state, residuals, batch):
        loss, metrics, grads = compute_grads(params, batch)
        if compression != "none":
            grads, residuals = compress_grads(
                grads, residuals, scheme=compression, topk_frac=topk_frac)
        params, opt_state = optimizer.update(grads, opt_state, params)
        metrics = dict(metrics, loss=loss,
                       grad_norm=opt_state.get("grad_norm", 0.0))
        return params, opt_state, residuals, metrics

    if not jit:
        return step
    return jax.jit(step, donate_argnums=(0, 1, 2) if donate else ())


def init_residuals(params, compression: str):
    if compression == "none":
        return None
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
