from repro.train.steps import init_residuals, make_train_step
from repro.train.trainer import (
    SimulatedFailure,
    StepWatchdog,
    Trainer,
    TrainerConfig,
    run_with_restarts,
)

__all__ = ["make_train_step", "init_residuals", "Trainer", "TrainerConfig",
           "SimulatedFailure", "StepWatchdog", "run_with_restarts"]
