"""Pallas TPU kernel: fused frontier scan + compaction + ELL row gather.

The paper's bucket-fusion deviation taken one step further (DESIGN.md
§12): the ``bucket_scan`` pass (frontier mask / any-reduce / next-bucket
min), the frontier compaction (``jnp.nonzero``) and the ELL row gather
of ``ell_sweep`` are three separate XLA ops in the ``ell`` strategy —
three full passes over HBM-resident arrays per inner iteration. This
kernel runs all of them in ONE ``pallas_call`` over a VMEM-resident
tent/explored slice:

  phase A (vector): frontier flags of bucket ``i`` restricted to
      unsettled vertices (``dist < explored``), their any-reduce, and
      the next-bucket minimum — the exact ``scan_bucket`` formulas, so
      the scalar outputs are bitwise those of the jnp twin;
  phase B (scalar loop): ascending-order compaction of the flag vector
      into an SMEM index buffer of static capacity ``cap`` — the same
      first-``cap`` truncation order as ``jnp.nonzero(size=cap)``, so
      the compacted buffer is bitwise the ``_FrontierCompactMixin``
      one. The *total* population is counted past the cap (the
      overflow signal);
  phase C (scalar loop): per-slot dynamic DMA of the compacted rows
      out of the resident ELL neighbor/weight blocks; empty slots read
      the all-sentinel row ``n_rows`` (INF weights — the 'benign
      garbage' idiom every consumer already handles).

The kernel stays int32-distance-only like ``ell_relax``: packing and
the C4 filter happen in XLA on the gathered rows (the shared
``ell_relax_words`` path), which is what keeps ``fused`` bitwise
interchangeable with ``edge``/``ell`` for packed (cost, pred) words.

Layout: dist/explored arrive as a padded (R, 128) lane reshape
(padding = INF, so padded slots can neither enter the frontier nor the
next-bucket min); the ELL blocks are fully VMEM-resident, sized for the
sub-million-vertex slices this engine shards at (the ops wrapper is
where a multi-block grid would slot in). ``base`` lifts slice-local
compacted indices to global vertex ids for the sharded variant; the
global padding sentinel is ``sent``. grid=(1,): with every input
resident there is nothing to pipeline, and a single step keeps the
scalar-accumulation idiom of ``bucket_scan`` trivially race-free.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.graphs.structures import INF32

_INF = int(INF32)  # python int: pallas kernels cannot capture traced constants
_IMAX = 2**31 - 1
_LANE = 128


def frontier_relax_kernel(i_ref, base_ref, dist_ref, explored_ref, nbr_ref,
                          w_ref, fidx_ref, rows_n_ref, rows_w_ref, count_ref,
                          any_ref, next_ref, idx_smem, cnt_smem, *,
                          delta: int, cap: int, n_rows: int, sent: int):
    i = i_ref[0, 0]
    base = base_ref[0, 0]

    # -- phase A: the scan_bucket formulas on the resident lane layout --
    t = dist_ref[...]                       # (R, 128), padding = INF
    e = explored_ref[...]
    fin = t < _INF
    b = jnp.where(fin, t // delta, _IMAX)
    f = fin & (b == i) & (t < e)
    any_ref[0, 0] = f.any().astype(jnp.int32)
    nb = jnp.where((b > i) & (t < e), b, _IMAX).min()
    next_ref[0, 0] = nb.astype(jnp.int32)

    # -- phase B: ascending compaction into SMEM (jnp.nonzero order) --
    cnt_smem[0] = jnp.int32(0)
    flat = f.reshape(-1)

    def compact_body(k, carry):
        def hit():
            pos = cnt_smem[0]

            @pl.when(pos < cap)
            def _():
                idx_smem[pos] = jnp.int32(k)

            cnt_smem[0] = pos + 1           # count past cap: overflow signal

        pl.when(flat[k])(hit)
        return carry

    jax.lax.fori_loop(0, flat.shape[0], compact_body, 0)
    total = cnt_smem[0]
    count_ref[0, 0] = total

    # -- phase C: dynamic row gather of the compacted frontier --
    def gather_body(j, carry):
        lidx = jnp.where(j < total, idx_smem[j], jnp.int32(n_rows))
        lidx = jnp.minimum(lidx, jnp.int32(n_rows))   # all-sentinel row
        gidx = jnp.where(lidx < n_rows, lidx + base, jnp.int32(sent))
        fidx_ref[j, 0] = gidx.astype(jnp.int32)
        rows_n_ref[pl.ds(j, 1), :] = nbr_ref[pl.ds(lidx, 1), :]
        rows_w_ref[pl.ds(j, 1), :] = w_ref[pl.ds(lidx, 1), :]
        return carry

    jax.lax.fori_loop(0, cap, gather_body, 0)


def frontier_relax_pallas(dist2d, explored2d, bucket_i, base, nbr, w_ell, *,
                          delta: int, cap: int, n_rows: int, sent: int,
                          interpret: bool = False):
    """dist2d/explored2d: int32[R, 128] padded lane reshape of the tent
    slice (padding = INF); nbr/w_ell: int32[n_rows + 1, D] resident ELL
    block (row ``n_rows`` all-sentinel). Returns ``(fidx int32[cap, 1],
    rows_n int32[cap, D], rows_w int32[cap, D], count int32[1, 1],
    any int32[1, 1], next int32[1, 1])``."""
    r, lanes = dist2d.shape
    assert lanes == _LANE
    d = w_ell.shape[1]
    i_arr = jnp.full((1, 1), bucket_i, jnp.int32)
    base_arr = jnp.full((1, 1), base, jnp.int32)
    kernel = functools.partial(frontier_relax_kernel, delta=delta, cap=cap,
                               n_rows=n_rows, sent=sent)
    full = lambda shape: pl.BlockSpec(shape, lambda i: (0, 0))
    return pl.pallas_call(
        kernel,
        grid=(1,),
        in_specs=[full((1, 1)), full((1, 1)), full((r, lanes)),
                  full((r, lanes)), full(nbr.shape), full(w_ell.shape)],
        out_specs=[full((cap, 1)), full((cap, d)), full((cap, d)),
                   full((1, 1)), full((1, 1)), full((1, 1))],
        out_shape=[
            jax.ShapeDtypeStruct((cap, 1), jnp.int32),
            jax.ShapeDtypeStruct((cap, d), jnp.int32),
            jax.ShapeDtypeStruct((cap, d), jnp.int32),
            jax.ShapeDtypeStruct((1, 1), jnp.int32),
            jax.ShapeDtypeStruct((1, 1), jnp.int32),
            jax.ShapeDtypeStruct((1, 1), jnp.int32),
        ],
        scratch_shapes=[pltpu.SMEM((cap,), jnp.int32),
                        pltpu.SMEM((1,), jnp.int32)],
        interpret=interpret,
    )(i_arr, base_arr, dist2d, explored2d, nbr, w_ell)
