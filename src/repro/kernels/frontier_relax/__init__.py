from repro.kernels.frontier_relax.ops import frontier_relax
from repro.kernels.frontier_relax.ref import frontier_relax_ref

__all__ = ["frontier_relax", "frontier_relax_ref"]
