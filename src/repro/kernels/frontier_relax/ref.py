"""Pure-jnp oracle for frontier_relax: the three fused phases as the
separate XLA ops they replace, composed bitwise-identically."""
from __future__ import annotations

import jax.numpy as jnp

from repro.graphs.structures import INF32

_INF = jnp.int32(INF32)
_IMAX = jnp.int32(2**31 - 1)


def frontier_relax_ref(dist, explored, bucket_i, nbr, w_ell, *, delta: int,
                       cap: int, base=0, sent=None):
    """dist/explored: int32[S] (a tent slice); nbr/w_ell: int32[S+1, D]
    ELL block with all-sentinel row S. Returns ``(fidx int32[cap],
    rows_n int32[cap, D], rows_w int32[cap, D], count int32, any bool,
    next int32)`` — ``fidx`` carries *global* ids (``base`` + local,
    padding sentinel ``sent``; default ``S``), exactly the layout
    ``ell_relax_words`` consumes.

    Compaction is ``jnp.nonzero(size=cap)``: ascending local order,
    truncated at ``cap``; ``count`` is the untruncated frontier
    population, so ``count > cap`` is the overflow signal."""
    s = dist.shape[0]
    sent = s if sent is None else sent
    fin = dist < _INF
    b = jnp.where(fin, dist // delta, _IMAX)
    f = fin & (b == bucket_i) & (dist < explored)
    nxt = jnp.where((b > bucket_i) & (dist < explored), b, _IMAX).min()
    lidx = jnp.nonzero(f, size=cap, fill_value=s)[0].astype(jnp.int32)
    fidx = jnp.where(lidx < s, lidx + jnp.int32(base),
                     jnp.int32(sent)).astype(jnp.int32)
    rows_n = nbr[lidx]                      # row s is all-sentinel
    rows_w = w_ell[lidx]
    return (fidx, rows_n, rows_w, f.sum().astype(jnp.int32), f.any(),
            nxt.astype(jnp.int32))
