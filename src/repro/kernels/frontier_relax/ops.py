"""Wrapper for frontier_relax: pads the tent slice into (R, 128) lanes,
dispatches kernel or oracle, flattens/reshapes the outputs."""
from __future__ import annotations

import jax.numpy as jnp

from repro.graphs.structures import INF32
from repro.kernels.frontier_relax.frontier_relax import frontier_relax_pallas
from repro.kernels.frontier_relax.ref import frontier_relax_ref

_LANE = 128
_BLOCK_ROWS = 8


def frontier_relax(dist, explored, bucket_i, nbr, w_ell, *, delta: int,
                   cap: int, base=0, sent=None, backend: str = "pallas",
                   interpret: bool = False):
    """Fused frontier scan + compaction + ELL row gather of one bucket.

    dist/explored: int32[S] (the whole tent, or one shard's owned
    slice); nbr/w_ell: int32[S + 1, D] ELL adjacency block (row S
    all-sentinel). Returns ``(fidx int32[cap], rows_n int32[cap, D],
    rows_w int32[cap, D], count int32, any bool, next int32)`` with
    ``fidx`` in global ids (``base`` + local index; padding slots carry
    the global sentinel ``sent``, default S). ``count`` is the full
    bucket population — ``count > cap`` is the caller's overflow flag.

    A zero-width ELL block (D == 0: no edges on this side of the
    light/heavy split) routes to the jnp oracle — zero-size Pallas
    blocks have no TPU layout, and there is nothing to gather anyway."""
    s = dist.shape[0]
    sent = s if sent is None else sent
    if backend == "ref" or w_ell.shape[1] == 0:
        return frontier_relax_ref(dist, explored, bucket_i, nbr, w_ell,
                                  delta=delta, cap=cap, base=base, sent=sent)
    per = _LANE * _BLOCK_ROWS
    pad = -(-s // per) * per - s
    d2 = jnp.pad(dist, (0, pad), constant_values=INF32).reshape(-1, _LANE)
    e2 = jnp.pad(explored, (0, pad), constant_values=INF32).reshape(-1, _LANE)
    fidx, rows_n, rows_w, count, any_, nxt = frontier_relax_pallas(
        d2, e2, bucket_i, base, nbr, w_ell, delta=delta, cap=cap,
        n_rows=s, sent=sent, interpret=interpret)
    return (fidx[:, 0], rows_n, rows_w, count[0, 0], any_[0, 0] > 0,
            nxt[0, 0])
