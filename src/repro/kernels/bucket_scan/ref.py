"""Pure-jnp oracle for bucket_scan."""
from __future__ import annotations

import jax.numpy as jnp

from repro.graphs.structures import INF32

_INF = jnp.int32(INF32)
_IMAX = jnp.int32(2**31 - 1)


def bucket_scan_ref(tent, explored, bucket_i, *, delta: int):
    """tent/explored int32[n] → (frontier bool[n], any bool, next int32).

    The next-bucket minimum only counts unsettled vertices
    (``tent < explored``) — identical on cold solves (future buckets are
    always unexplored, DESIGN.md §11) and the bucket-skipping rule warm
    re-solves rely on; must stay in lockstep with ``core.backends
    .scan_bucket`` and the Pallas kernel."""
    fin = tent < _INF
    b = jnp.where(fin, tent // delta, _IMAX)
    frontier = fin & (b == bucket_i) & (tent < explored)
    nxt = jnp.where((b > bucket_i) & (tent < explored), b, _IMAX).min()
    return frontier, frontier.any(), nxt
