from repro.kernels.bucket_scan.ops import bucket_scan
from repro.kernels.bucket_scan.ref import bucket_scan_ref

__all__ = ["bucket_scan", "bucket_scan_ref"]
