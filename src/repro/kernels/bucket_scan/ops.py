"""Wrapper for bucket_scan: reshapes the 1-D tent vector into padded
(R, 128) lanes, dispatches kernel or oracle, flattens the result."""
from __future__ import annotations

import jax.numpy as jnp

from repro.graphs.structures import INF32
from repro.kernels.bucket_scan.bucket_scan import bucket_scan_pallas
from repro.kernels.bucket_scan.ref import bucket_scan_ref

_LANE = 128


def bucket_scan(tent, explored, bucket_i, *, delta: int,
                block_rows: int = 8, backend: str = "pallas",
                interpret: bool = False):
    """Fused frontier mask + frontier-any + next-bucket scan.

    tent, explored: int32[n]. Returns (frontier bool[n], any bool,
    next_bucket int32 scalar)."""
    if backend == "ref":
        return bucket_scan_ref(tent, explored, bucket_i, delta=delta)
    n = tent.shape[0]
    per = _LANE * block_rows
    npad = -(-n // per) * per
    pad = npad - n
    t2 = jnp.pad(tent, (0, pad), constant_values=INF32).reshape(-1, _LANE)
    e2 = jnp.pad(explored, (0, pad), constant_values=INF32).reshape(-1, _LANE)
    f2, any_, nxt = bucket_scan_pallas(t2, e2, bucket_i, delta=delta,
                                       block_rows=block_rows,
                                       interpret=interpret)
    frontier = (f2.reshape(-1) != 0)[:n]
    return frontier, any_[0, 0] > 0, nxt[0, 0]
