"""Pallas TPU kernel: fused bucket-array scan.

The paper's C1 trade: the dense bucket array must be *scanned in full*
at every inner iteration to find the members of the current bucket. In
the JAX engine that scan shows up three times per iteration (frontier
mask, frontier-any termination flag, next-bucket minimum). This kernel
fuses all three into one pass over ``tent``/``explored``:

    frontier[v]   = tent[v] < INF  &  tent[v]//Δ == i  &  tent[v] < explored[v]
    any_frontier  = OR-reduce(frontier)
    next_bucket   = min over v of tent[v]//Δ restricted to buckets > i
                    and unsettled vertices (tent[v] < explored[v]) —
                    bitwise identical on cold solves, and the rule that
                    lets warm re-solves (repro.dynamic) skip buckets the
                    repair never touched (DESIGN.md §11)

Grid is 1-D over row blocks of the (padded) column-major tent layout;
the two scalar outputs accumulate across sequential grid steps into a
(1, 1) block (TPU grid execution is sequential, making the accumulation
race-free — the same argument the paper makes for benign writes).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.graphs.structures import INF32

_INF = int(INF32)  # python int: pallas kernels cannot capture traced constants
_IMAX = 2**31 - 1


def bucket_scan_kernel(i_ref, tent_ref, explored_ref, frontier_ref,
                       any_ref, next_ref, *, delta: int):
    pid = pl.program_id(0)
    i = i_ref[0, 0]
    t = tent_ref[...]
    e = explored_ref[...]
    fin = t < _INF
    b = jnp.where(fin, t // delta, _IMAX)
    f = fin & (b == i) & (t < e)
    frontier_ref[...] = f.astype(jnp.int8)

    @pl.when(pid == 0)
    def _init():
        # explicit int32: under x64 a weak python int would store as int64
        any_ref[0, 0] = jnp.int32(0)
        next_ref[0, 0] = jnp.int32(_IMAX)

    any_ref[0, 0] = jnp.maximum(any_ref[0, 0], f.any().astype(jnp.int32))
    nb = jnp.where((b > i) & (t < e), b, _IMAX).min().astype(jnp.int32)
    next_ref[0, 0] = jnp.minimum(next_ref[0, 0], nb)


def bucket_scan_pallas(tent2d, explored2d, bucket_i, *, delta: int,
                       block_rows: int, interpret: bool = False):
    """tent2d/explored2d: int32[R, 128] padded row-major reshape of tent
    (padding = INF). Returns (frontier int8[R,128], any int32[1,1],
    next_bucket int32[1,1])."""
    r, lanes = tent2d.shape
    assert r % block_rows == 0
    n_blocks = r // block_rows
    i_arr = jnp.full((1, 1), bucket_i, jnp.int32)
    kernel = functools.partial(bucket_scan_kernel, delta=delta)
    blk = lambda: pl.BlockSpec((block_rows, lanes), lambda i: (i, 0))
    scalar = lambda: pl.BlockSpec((1, 1), lambda i: (0, 0))
    return pl.pallas_call(
        kernel,
        grid=(n_blocks,),
        in_specs=[scalar(), blk(), blk()],
        out_specs=[blk(), scalar(), scalar()],
        out_shape=[
            jax.ShapeDtypeStruct((r, lanes), jnp.int8),
            jax.ShapeDtypeStruct((1, 1), jnp.int32),
            jax.ShapeDtypeStruct((1, 1), jnp.int32),
        ],
        interpret=interpret,
    )(i_arr, tent2d, explored2d)
