"""Pallas TPU kernel: masked min-plus stencil for game-map Δ-stepping.

The paper's game-map scenario (Sec. 4) is an occupancy grid whose SSSP
relaxation is an 8-neighbour stencil: straight moves cost 10 (light under
Δ=13), diagonal moves cost 14 (heavy). The paper notes the regular
structure admits SIMD vectorization; the TPU-native version is a
VMEM-tiled stencil sweep over the tentative-distance grid:

    out[r,c] = free[r,c] ? min(tent[r,c],
                min_{(dr,dc) in phase} frontier(tent[r+dr,c+dc])
                                       ? tent[r+dr,c+dc] + cost : INF)
             : INF
    frontier(v) = v < INF and v // Δ == i     (dense bucket array, C1)

Halo handling: the grid is row-blocked; each program instance receives
the block above and below via clamped BlockSpec index maps and masks the
clamp garbage at the grid boundary with ``program_id`` predicates. Column
halos stay inside the block (full-width strips) with INF fill at the
grid edge. The VMEM working set is 5 strips of (block_rows x W) int32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.graphs.structures import INF32

_INF = int(INF32)  # python int: pallas kernels cannot capture traced constants


def _shift_cols(x, dc):
    """Value of the horizontal neighbour at column offset ``dc``; cells
    past the grid edge contribute INF."""
    if dc == 0:
        return x
    fill = jnp.full((x.shape[0], 1), _INF, x.dtype)
    if dc == -1:  # neighbour at c-1
        return jnp.concatenate([fill, x[:, :-1]], axis=1)
    return jnp.concatenate([x[:, 1:], fill], axis=1)


def grid_relax_kernel(i_ref, above_ref, center_ref, below_ref, free_ref,
                      out_ref, *, delta: int, cost_straight: int,
                      cost_diag: int, light: bool, n_blocks: int):
    i = i_ref[0, 0]
    pid = pl.program_id(0)
    a = above_ref[...]
    c = center_ref[...]
    b = below_ref[...]

    # Rows adjacent to the strip; clamp garbage at the grid boundary → INF.
    top = jnp.where(pid == 0, _INF, a[-1:, :])
    bot = jnp.where(pid == n_blocks - 1, _INF, b[:1, :])
    up = jnp.concatenate([top, c[:-1, :]], axis=0)      # value at (r-1, c)
    down = jnp.concatenate([c[1:, :], bot], axis=0)     # value at (r+1, c)

    # Phase membership is static: a move class is relaxed in the light
    # phase iff its cost is <= delta (paper Alg. 1 lines 3-5).
    moves = []
    if (cost_straight <= delta) == light:
        moves += [(up, 0, cost_straight), (down, 0, cost_straight),
                  (c, -1, cost_straight), (c, 1, cost_straight)]
    if (cost_diag <= delta) == light:
        moves += [(up, -1, cost_diag), (up, 1, cost_diag),
                  (down, -1, cost_diag), (down, 1, cost_diag)]

    best = jnp.full_like(c, _INF)
    for base, dc, cost in moves:
        v = _shift_cols(base, dc)
        in_frontier = (v < _INF) & (v // delta == i)    # C1 bucket scan
        cand = jnp.where(in_frontier, v, 0) + cost      # overflow guard
        best = jnp.minimum(best, jnp.where(in_frontier, cand, _INF))

    free = free_ref[...] != 0
    out_ref[...] = jnp.where(free, jnp.minimum(c, best), _INF)


def grid_relax_pallas(tent, free_i8, bucket_i, *, delta: int,
                      cost_straight: int, cost_diag: int, light: bool,
                      block_rows: int, interpret: bool = False):
    """Low-level entry; shapes must already be padded (rows % block_rows
    == 0, cols % 128 == 0). Use ops.grid_relax for the padded wrapper."""
    h, w = tent.shape
    assert h % block_rows == 0, (h, block_rows)
    n_blocks = h // block_rows
    i_arr = jnp.full((1, 1), bucket_i, jnp.int32)
    kernel = functools.partial(
        grid_relax_kernel, delta=delta, cost_straight=cost_straight,
        cost_diag=cost_diag, light=light, n_blocks=n_blocks)
    strip = lambda f: pl.BlockSpec((block_rows, w), f)
    return pl.pallas_call(
        kernel,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            strip(lambda i: (jnp.maximum(i - 1, 0), 0)),   # above
            strip(lambda i: (i, 0)),                       # center
            strip(lambda i: (jnp.minimum(i + 1, n_blocks - 1), 0)),  # below
            strip(lambda i: (i, 0)),                       # free mask
        ],
        out_specs=strip(lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((h, w), jnp.int32),
        interpret=interpret,
    )(i_arr, tent, tent, tent, free_i8)
