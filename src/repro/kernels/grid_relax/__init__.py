from repro.kernels.grid_relax.ops import grid_relax
from repro.kernels.grid_relax.ref import grid_relax_ref

__all__ = ["grid_relax", "grid_relax_ref"]
