"""Jit-friendly wrapper around the grid_relax Pallas kernel: pads the
grid to TPU-aligned tiles (rows → block_rows multiple, cols → 128 lanes)
with INF / blocked cells, dispatches kernel or oracle, and crops."""
from __future__ import annotations


import jax.numpy as jnp

from repro.graphs.structures import INF32
from repro.kernels.grid_relax.grid_relax import grid_relax_pallas
from repro.kernels.grid_relax.ref import grid_relax_ref

_LANE = 128


def _pad_to(x, rows, cols, fill):
    h, w = x.shape
    return jnp.pad(x, ((0, rows - h), (0, cols - w)), constant_values=fill)


def grid_relax(tent, free, bucket_i, *, delta: int = 13,
               cost_straight: int = 10, cost_diag: int = 14, light: bool,
               block_rows: int = 64, backend: str = "pallas",
               interpret: bool = False):
    """One Δ-stepping relaxation sweep over a game-map grid.

    tent: int32[H, W] tentative distances (INF32 = unreached/blocked).
    free: bool[H, W] occupancy mask.
    bucket_i: traced int32 — current bucket index.
    backend: 'pallas' | 'ref'.
    """
    if backend == "ref":
        return grid_relax_ref(tent, free, bucket_i, delta=delta,
                              cost_straight=cost_straight,
                              cost_diag=cost_diag, light=light)
    h, w = tent.shape
    hp = -(-h // block_rows) * block_rows
    wp = -(-w // _LANE) * _LANE
    tent_p = _pad_to(tent, hp, wp, INF32)
    free_p = _pad_to(free.astype(jnp.int8), hp, wp, 0)
    out = grid_relax_pallas(tent_p, free_p, bucket_i, delta=delta,
                            cost_straight=cost_straight, cost_diag=cost_diag,
                            light=light, block_rows=block_rows,
                            interpret=interpret)
    return out[:h, :w]
