"""Pure-jnp oracle for the grid_relax kernel."""
from __future__ import annotations

import jax.numpy as jnp

from repro.graphs.structures import INF32

_INF = jnp.int32(INF32)


def _neighbor(tent, dr, dc):
    """tent value of the (dr, dc) neighbour, INF past the grid edge."""
    v = tent
    if dr == -1:
        v = jnp.concatenate([jnp.full((1, v.shape[1]), _INF, v.dtype),
                             v[:-1]], axis=0)
    elif dr == 1:
        v = jnp.concatenate([v[1:],
                             jnp.full((1, v.shape[1]), _INF, v.dtype)], axis=0)
    if dc == -1:
        v = jnp.concatenate([jnp.full((v.shape[0], 1), _INF, v.dtype),
                             v[:, :-1]], axis=1)
    elif dc == 1:
        v = jnp.concatenate([v[:, 1:],
                             jnp.full((v.shape[0], 1), _INF, v.dtype)], axis=1)
    return v


def grid_relax_ref(tent, free, bucket_i, *, delta: int, cost_straight: int,
                   cost_diag: int, light: bool):
    """One masked min-plus sweep; free is bool[H, W]."""
    best = jnp.full_like(tent, _INF)
    moves = []
    if (cost_straight <= delta) == light:
        moves += [(-1, 0, cost_straight), (1, 0, cost_straight),
                  (0, -1, cost_straight), (0, 1, cost_straight)]
    if (cost_diag <= delta) == light:
        moves += [(-1, -1, cost_diag), (-1, 1, cost_diag),
                  (1, -1, cost_diag), (1, 1, cost_diag)]
    for dr, dc, cost in moves:
        v = _neighbor(tent, dr, dc)
        f = (v < _INF) & (v // delta == bucket_i)
        cand = jnp.where(f, v, 0) + cost
        best = jnp.minimum(best, jnp.where(f, cand, _INF))
    return jnp.where(free, jnp.minimum(tent, best), _INF)
