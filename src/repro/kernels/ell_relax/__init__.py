from repro.kernels.ell_relax.ops import ell_relax
from repro.kernels.ell_relax.ref import ell_relax_ref

__all__ = ["ell_relax", "ell_relax_ref"]
