"""Pallas TPU kernel: frontier-row expansion for the ELL strategy.

The frontier-centric Δ-stepping sweep gathers the ELL adjacency rows of
the compacted frontier and produces relaxation candidates
``tent[v] + w(v, u)`` (paper's request-set computation, with the C4
deviation of evaluating costs during generation). The gather is
irregular; on TPU the idiomatic form is **scalar-prefetch indexing**:
the compacted frontier indices are prefetched to SMEM and drive the
BlockSpec index maps, so each grid step DMAs exactly the ELL row block
and tent row it needs from HBM into VMEM — no host-side gather
materialization.

Grid: one step per block of ``rows_per_block`` frontier entries; padding
entries point at the all-sentinel row ``n`` and yield INF candidates.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.graphs.structures import INF32

_INF = int(INF32)  # python int: pallas kernels cannot capture traced constants


def ell_relax_kernel(fidx_ref, dist_ref, w_ref, out_ref):
    """dist_ref: (R, 1) tent distances of this block's frontier rows;
    w_ref: (R, D) edge weights (INF = padding slot); out: candidates."""
    d = dist_ref[...]                       # (R, 1)
    w = w_ref[...]                          # (R, D)
    valid = (w < _INF) & (d < _INF)
    cand = jnp.where(valid, d, 0) + jnp.where(valid, w, 0)
    out_ref[...] = jnp.where(valid, cand, _INF)


def ell_relax_pallas(fidx, dist_col, w_ell, *, rows_per_block: int,
                     interpret: bool = False):
    """fidx: int32[cap] compacted frontier (sentinel n for padding);
    dist_col: int32[n+1, 1] tent distances (row n = INF sentinel);
    w_ell: int32[n+1, D] ELL weights. Returns candidates int32[cap, D].

    cap % rows_per_block == 0 is required (ops.py pads).
    """
    cap = fidx.shape[0]
    d = w_ell.shape[1]
    assert cap % rows_per_block == 0
    n_blocks = cap // rows_per_block

    def dist_map(i, fidx_ref):
        del fidx_ref
        return (i, 0)

    # Scalar-prefetch: block index maps read the frontier indices. With
    # rows_per_block == 1 each step DMAs exactly row fidx[i]; for larger
    # blocks we fall back to a gathered layout prepared by ops.py.
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((rows_per_block, 1), dist_map),
            pl.BlockSpec((rows_per_block, d), dist_map),
        ],
        out_specs=pl.BlockSpec((rows_per_block, d), dist_map),
    )
    return pl.pallas_call(
        ell_relax_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((cap, d), jnp.int32),
        interpret=interpret,
    )(fidx, dist_col, w_ell)


def ell_relax_row_gather_pallas(fidx, dist, w_ell, *, interpret: bool = False):
    """Fully-fused variant (rows_per_block=1): the scalar-prefetched
    frontier index drives the DMA of each ELL row directly, so the
    gather itself happens inside the kernel pipeline."""
    cap = fidx.shape[0]
    d = w_ell.shape[1]

    def row_map(i, fidx_ref):
        return (fidx_ref[i], 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(cap,),
        in_specs=[
            pl.BlockSpec((1, 1), row_map),
            pl.BlockSpec((1, d), row_map),
        ],
        out_specs=pl.BlockSpec((1, d), lambda i, _: (i, 0)),
    )
    return pl.pallas_call(
        ell_relax_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((cap, d), jnp.int32),
        interpret=interpret,
    )(fidx, dist, w_ell)
