"""Wrapper for the ell_relax kernel: prepares the (n+1, 1) distance
column (sentinel row INF), pads the frontier capacity to a block
multiple, dispatches kernel or oracle."""
from __future__ import annotations

import jax.numpy as jnp

from repro.graphs.structures import INF32
from repro.kernels.ell_relax.ell_relax import (
    ell_relax_pallas,
    ell_relax_row_gather_pallas,
)
from repro.kernels.ell_relax.ref import ell_relax_ref


def ell_relax(fidx, dist, w_ell, *, backend: str = "pallas",
              rows_per_block: int = 8, interpret: bool = False):
    """Relaxation candidates for a compacted frontier.

    fidx: int32[cap] frontier vertex ids (n = padding sentinel).
    dist: int32[n] tentative distances.
    w_ell: int32[n+1, D] ELL weights (row n all-INF).
    Returns int32[cap, D] candidate distances (INF where invalid).
    """
    if backend == "ref":
        return ell_relax_ref(fidx, dist, w_ell)
    n = dist.shape[0]
    cap = fidx.shape[0]
    dist_col = jnp.concatenate(
        [dist, jnp.full((1,), INF32, dist.dtype)])[:, None]   # (n+1, 1)
    if backend == "pallas_row":
        return ell_relax_row_gather_pallas(fidx, dist_col, w_ell,
                                           interpret=interpret)
    # blocked variant: gather rows first (XLA), kernel fuses mask+add
    pad = (-cap) % rows_per_block
    fidx_p = jnp.concatenate(
        [fidx, jnp.full((pad,), n, fidx.dtype)]) if pad else fidx
    d_rows = jnp.take(dist_col[:, 0], fidx_p, mode="fill",
                      fill_value=INF32)[:, None]
    w_rows = w_ell[fidx_p]
    out = ell_relax_pallas(fidx_p, d_rows, w_rows,
                           rows_per_block=rows_per_block, interpret=interpret)
    return out[:cap]
