"""Pure-jnp oracle for ell_relax: candidate generation for a compacted
frontier over an ELL adjacency block."""
from __future__ import annotations

import jax.numpy as jnp

from repro.graphs.structures import INF32

_INF = int(INF32)


def ell_relax_ref(fidx, dist, w_ell):
    """fidx int32[cap] (sentinel n = padding), dist int32[n] tent,
    w_ell int32[n+1, D] (INF = padding slot) → candidates int32[cap, D]."""
    d_f = jnp.take(dist, fidx, mode="fill", fill_value=_INF)   # [cap]
    rows_w = w_ell[fidx]                                       # [cap, D]
    valid = (rows_w < _INF) & (d_f[:, None] < _INF)
    cand = jnp.where(valid, d_f[:, None], 0) + jnp.where(valid, rows_w, 0)
    return jnp.where(valid, cand, _INF)
